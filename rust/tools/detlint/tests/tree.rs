//! End-to-end: the checked-in tree is clean and the committed R4 ratchet
//! matches the census exactly (`cargo run -p detlint` would exit 0).

use std::path::Path;

use detlint::{parse_ratchet, ratchet_findings, scan_tree};

#[test]
fn repo_tree_is_clean_and_ratchet_is_current() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..");
    let root = root.canonicalize().unwrap();
    let (mut findings, census, n_files) = scan_tree(&root).unwrap();
    assert!(n_files > 50, "scan missed the tree: only {n_files} files");

    let ratchet_path = root.join("rust/tools/detlint/ratchet.txt");
    let baseline = parse_ratchet(&std::fs::read_to_string(&ratchet_path).unwrap()).unwrap();
    findings.extend(ratchet_findings(&baseline, &census));

    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "detlint findings:\n{}", rendered.join("\n"));
}
