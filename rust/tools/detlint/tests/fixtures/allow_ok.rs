//! Suppression fixture: both allow placements (line above, trailing)
//! silence R5 with a justification; zero findings expected.

pub fn total(xs: &[f64]) -> f64 {
    // lint:allow(R5): sequential reduction over one slice; order is fixed.
    xs.iter().sum::<f64>()
}

pub fn total_trailing(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() // lint:allow(R5): sequential; order is fixed.
}
