//! R6 fixture: exactly one raw thread spawn outside the sanctioned
//! modules. `thread::sleep` is deliberately unrestricted (it creates no
//! concurrency), and so is naming the `thread` module itself.

pub fn t() {
    let h = std::thread::spawn(|| 1 + 1);
    std::thread::sleep(std::time::Duration::from_millis(1));
    let _ = h.join();
}
