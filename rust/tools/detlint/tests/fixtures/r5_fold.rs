//! R5 fixture: exactly one float fold in a deterministic path.

pub fn peak(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(0.0f64, f64::max)
}
