//! R5 fixture: exactly one float reduction in a deterministic path.

pub fn total(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}
