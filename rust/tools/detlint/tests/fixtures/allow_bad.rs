//! Malformed-suppression fixture: a justification-free allow comment is
//! itself a finding and does NOT silence the R5 underneath it.

pub fn total(xs: &[f64]) -> f64 {
    // lint:allow(R5)
    xs.iter().sum::<f64>()
}
