//! R2 fixture: exactly one hash container in a deterministic path.

pub fn first_key(m: &std::collections::HashMap<u32, u32>) -> Option<u32> {
    m.keys().next().copied()
}
