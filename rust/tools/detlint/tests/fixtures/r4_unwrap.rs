//! R4 fixture: one library-code unwrap; the test-mod unwrap is excluded
//! from the census.

pub fn double(x: Option<u32>) -> u32 {
    2 * x.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_not_counted() {
        assert_eq!(super::double(Some(2)), Some(4).unwrap());
    }
}
