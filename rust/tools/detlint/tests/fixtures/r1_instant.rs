//! R1 fixture: exactly one wall-clock read outside util::clock.

pub fn elapsed() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
