//! R3 fixture: exactly one raw environment read outside util::env.

pub fn threads() -> Option<String> {
    std::env::var("LOBRA_NUM_THREADS").ok()
}
