//! Fixture-tree tests: each rule fires exactly once on its fixture, the
//! confinement modules are exempt, and the allow grammar is enforced.

use detlint::{scan_file, Rule};

const RESTRICTED: &str = "rust/src/solver/fixture.rs";

fn rule_count(path: &str, src: &str, rule: Rule) -> usize {
    scan_file(path, src).findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn r1_fires_exactly_once() {
    let src = include_str!("fixtures/r1_instant.rs");
    let scan = scan_file("rust/src/exec/fixture.rs", src);
    assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
    assert_eq!(scan.findings[0].rule, Rule::R1);
    assert_eq!(scan.findings[0].line, 4);
}

#[test]
fn r1_exempt_inside_clock_module() {
    let src = include_str!("fixtures/r1_instant.rs");
    assert_eq!(rule_count("rust/src/util/clock.rs", src, Rule::R1), 0);
}

#[test]
fn r2_fires_exactly_once_in_restricted_paths_only() {
    let src = include_str!("fixtures/r2_hashmap.rs");
    assert_eq!(rule_count(RESTRICTED, src, Rule::R2), 1);
    // hash containers are fine outside deterministic paths
    assert_eq!(rule_count("rust/src/data/fixture.rs", src, Rule::R2), 0);
}

#[test]
fn r3_fires_exactly_once() {
    let src = include_str!("fixtures/r3_env.rs");
    let scan = scan_file("rust/src/config/fixture.rs", src);
    assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
    assert_eq!(scan.findings[0].rule, Rule::R3);
}

#[test]
fn r3_exempt_inside_env_module_and_for_snapshot_calls() {
    let src = include_str!("fixtures/r3_env.rs");
    assert_eq!(rule_count("rust/src/util/env.rs", src, Rule::R3), 0);
    // calls into the snapshot module do not fire
    let snap = "pub fn t() -> Option<&'static str> { crate::util::env::var(\"LOBRA_X\") }\n";
    assert_eq!(rule_count("rust/src/config/fixture.rs", snap, Rule::R3), 0);
}

#[test]
fn r4_counts_library_sites_but_not_test_mods() {
    let src = include_str!("fixtures/r4_unwrap.rs");
    let scan = scan_file("rust/src/train/fixture.rs", src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
    assert_eq!(scan.unwrap_sites, Some(1));
}

#[test]
fn r4_census_is_none_outside_library_code() {
    let src = include_str!("fixtures/r4_unwrap.rs");
    let scan = scan_file("rust/tests/fixture.rs", src);
    assert_eq!(scan.unwrap_sites, None);
}

#[test]
fn r5_sum_and_fold_each_fire_exactly_once() {
    let sum = include_str!("fixtures/r5_sum.rs");
    let fold = include_str!("fixtures/r5_fold.rs");
    assert_eq!(rule_count(RESTRICTED, sum, Rule::R5), 1);
    assert_eq!(rule_count(RESTRICTED, fold, Rule::R5), 1);
    // sequential float math outside restricted paths is not flagged
    assert_eq!(rule_count("rust/src/metrics/fixture.rs", sum, Rule::R5), 0);
}

#[test]
fn r6_fires_exactly_once() {
    let src = include_str!("fixtures/r6_thread.rs");
    let scan = scan_file("rust/src/exec/fixture.rs", src);
    assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
    assert_eq!(scan.findings[0].rule, Rule::R6);
    assert_eq!(scan.findings[0].line, 6, "sleep and module naming are exempt");
}

#[test]
fn r6_exempt_inside_par_and_service_modules() {
    let src = include_str!("fixtures/r6_thread.rs");
    assert_eq!(rule_count("rust/src/util/par.rs", src, Rule::R6), 0);
    assert_eq!(rule_count("rust/src/coordinator/service.rs", src, Rule::R6), 0);
}

#[test]
fn r6_catches_builder_and_scope_too() {
    let builder = "pub fn t() { let _ = std::thread::Builder::new(); }\n";
    let scope = "pub fn t() { std::thread::scope(|_| {}); }\n";
    assert_eq!(rule_count("rust/src/exec/fixture.rs", builder, Rule::R6), 1);
    assert_eq!(rule_count("rust/tests/fixture.rs", scope, Rule::R6), 1);
}

#[test]
fn allow_with_justification_suppresses_both_placements() {
    let src = include_str!("fixtures/allow_ok.rs");
    let scan = scan_file(RESTRICTED, src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
}

#[test]
fn allow_without_justification_is_rejected_and_does_not_suppress() {
    let src = include_str!("fixtures/allow_bad.rs");
    let scan = scan_file(RESTRICTED, src);
    let syntax = scan.findings.iter().filter(|f| f.rule == Rule::AllowSyntax).count();
    let r5 = scan.findings.iter().filter(|f| f.rule == Rule::R5).count();
    assert_eq!(syntax, 1, "{:?}", scan.findings);
    assert_eq!(r5, 1, "justification-free allow must not suppress");
}

#[test]
fn allow_for_the_wrong_rule_does_not_suppress() {
    let src = "pub fn t(xs: &[f64]) -> f64 {\n\
               // lint:allow(R1): wrong rule on purpose.\n\
               xs.iter().sum::<f64>()\n}\n";
    assert_eq!(rule_count(RESTRICTED, src, Rule::R5), 1);
}

#[test]
fn strings_and_comments_are_not_code() {
    let src = "pub const DOC: &str = \"uses Instant and HashMap and env::var\";\n\
               // Instant in a comment\n\
               /* HashMap in a block comment */\n";
    let scan = scan_file(RESTRICTED, src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
}
