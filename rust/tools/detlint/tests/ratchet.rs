//! Ratchet semantics: the baseline may only shrink, improvements must be
//! locked in, and `--update-ratchet` output round-trips.

use detlint::{format_ratchet, parse_ratchet, ratchet_findings, Ratchet, Rule};

fn one(path: &str, count: usize) -> Ratchet {
    let mut r = Ratchet::new();
    r.insert(path.to_string(), count);
    r
}

#[test]
fn growth_is_a_regression() {
    let findings = ratchet_findings(&one("rust/src/a.rs", 3), &one("rust/src/a.rs", 4));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::R4);
    assert!(findings[0].message.contains("ratchet allows 3"), "{}", findings[0].message);
}

#[test]
fn new_file_with_sites_is_a_regression() {
    let findings = ratchet_findings(&Ratchet::new(), &one("rust/src/new.rs", 1));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::R4);
}

#[test]
fn improvement_must_be_locked_in() {
    let findings = ratchet_findings(&one("rust/src/a.rs", 3), &one("rust/src/a.rs", 2));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("stale"), "{}", findings[0].message);
}

#[test]
fn matching_census_is_clean_and_update_restores_monotonicity() {
    let baseline = one("rust/src/a.rs", 3);
    assert!(ratchet_findings(&baseline, &baseline).is_empty());
    // after an improvement, regenerating the baseline makes check clean again
    let improved = one("rust/src/a.rs", 2);
    let regenerated = parse_ratchet(&format_ratchet(&improved)).unwrap();
    assert!(ratchet_findings(&regenerated, &improved).is_empty());
}

#[test]
fn format_round_trips_and_drops_zero_counts() {
    let mut census = Ratchet::new();
    census.insert("rust/src/a.rs".to_string(), 2);
    census.insert("rust/src/b.rs".to_string(), 0);
    let parsed = parse_ratchet(&format_ratchet(&census)).unwrap();
    assert_eq!(parsed, one("rust/src/a.rs", 2));
}

#[test]
fn malformed_baselines_are_rejected() {
    assert!(parse_ratchet("rust/src/a.rs not-a-number").is_err());
    assert!(parse_ratchet("too many fields here 3").is_err());
    assert!(parse_ratchet("# comments and\n\n  # blanks are fine\n").unwrap().is_empty());
}
