//! detlint: determinism/concurrency static analysis for the lobra tree.
//!
//! A token-level scan (comments and string literals stripped, no AST) that
//! enforces the determinism invariants the certificate tests rely on:
//!
//! - **R1** — `Instant`/`SystemTime` only inside `util::clock`: wall time
//!   must flow through the `Clock` trait so sim runs stay bit-identical.
//! - **R2** — no `HashMap`/`HashSet` in planner/solver/dispatch/runtime
//!   paths: iteration order must be stable across processes.
//! - **R3** — process environment reads only inside `util::env`, which
//!   snapshots `LOBRA_*` once per process.
//! - **R4** — `.unwrap()`/`.expect()` in library code is ratcheted: a
//!   checked-in per-file baseline may only shrink.
//! - **R5** — float `sum`/`fold` reductions in deterministic paths must go
//!   through `util::par::tree_reduce` (fixed reduction order) or carry an
//!   annotation saying why order cannot vary.
//! - **R6** — raw `std::thread` spawning (`spawn`/`Builder`/`scope`) only
//!   inside `util::par` and the planner service (`coordinator::service`):
//!   ad-hoc threads elsewhere could reorder float reductions or leak
//!   nondeterministic timing into certified paths.
//!
//! Suppressions use `// lint:allow(R?): <justification>` on the offending
//! line or the line above; a missing justification is itself a finding.
//!
//! The scanner is deliberately dataflow-free: it cannot tell a sequential
//! `iter().sum()` from a parallel one (R5) and it matches names, not
//! resolved paths. The rules are tuned so every false positive in-tree is
//! either fixed or carries a one-line justification.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// Lint rules. `AllowSyntax` covers malformed `lint:allow` comments and is
/// never suppressible.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    AllowSyntax,
}

impl Rule {
    pub fn code(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
            Rule::AllowSyntax => "allow-syntax",
        }
    }

    fn from_code(s: &str) -> Option<Rule> {
        match s {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            "R6" => Some(Rule::R6),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.code())
    }
}

/// One lint violation. `line == 0` marks a file-level finding (ratchet).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.path, self.rule, self.message)
        } else {
            write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
        }
    }
}

// --- lexer -----------------------------------------------------------------

#[derive(Debug, Clone)]
struct Tok {
    text: String,
    line: usize,
}

#[derive(Debug, Clone)]
struct AllowNote {
    rule: Rule,
    line: usize,
    /// Code tokens precede the comment on its line (trailing comment).
    code_before: bool,
}

#[derive(Debug, Default)]
struct Lexed {
    toks: Vec<Tok>,
    allows: Vec<AllowNote>,
    /// (line, reason) for `lint:allow` comments that fail to parse.
    bad_allows: Vec<(usize, String)>,
}

/// Token text at `i`, or `""` past the end (makes lookahead patterns total).
fn t_at(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            let code_before = out.toks.last().is_some_and(|t| t.line == line);
            scan_allow(&src[start..i], line, code_before, &mut out);
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
        } else if c == b'"' {
            i = consume_string(b, i, &mut line);
        } else if c == b'\'' {
            i = consume_quote(b, i, &mut line);
        } else if c == b'_' || c.is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            let ident = &src[start..i];
            let next = b.get(i).copied();
            if (ident == "r" || ident == "br") && matches!(next, Some(b'"') | Some(b'#')) {
                i = consume_raw_or_ident(b, i, &mut line, src, &mut out.toks);
            } else if ident == "b" && next == Some(b'"') {
                i = consume_string(b, i, &mut line);
            } else if ident == "b" && next == Some(b'\'') {
                i = consume_quote(b, i, &mut line);
            } else {
                out.toks.push(Tok { text: ident.to_string(), line });
            }
        } else if c.is_ascii_digit() {
            let start = i;
            i = consume_number(b, i);
            out.toks.push(Tok { text: src[start..i].to_string(), line });
        } else if c == b':' && b.get(i + 1) == Some(&b':') {
            out.toks.push(Tok { text: "::".to_string(), line });
            i += 2;
        } else {
            out.toks.push(Tok { text: (c as char).to_string(), line });
            i += 1;
        }
    }
    out
}

/// Past a `"..."` literal (with escapes); `i` is at the opening quote.
fn consume_string(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Past a raw (byte) string `r#"..."#` / raw identifier `r#name`; `i` is
/// just after the `r`/`br` prefix.
fn consume_raw_or_ident(
    b: &[u8],
    mut i: usize,
    line: &mut usize,
    src: &str,
    toks: &mut Vec<Tok>,
) -> usize {
    let mut hashes = 0usize;
    while b.get(i + hashes) == Some(&b'#') {
        hashes += 1;
    }
    if b.get(i + hashes) != Some(&b'"') {
        // raw identifier (`r#fn`): emit the identifier itself
        i += hashes;
        let start = i;
        while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
            i += 1;
        }
        toks.push(Tok { text: src[start..i].to_string(), line: *line });
        return i;
    }
    i += hashes + 1;
    while i < b.len() {
        let tail = &b[i + 1..];
        if b[i] == b'"' && tail.len() >= hashes && tail[..hashes].iter().all(|&h| h == b'#') {
            return i + 1 + hashes;
        }
        if b[i] == b'\n' {
            *line += 1;
        }
        i += 1;
    }
    i
}

/// Past a `'` that starts either a char/byte-char literal or a lifetime;
/// `i` is at the quote.
fn consume_quote(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    let next = b.get(i + 1).copied();
    let is_char = match next {
        Some(b'\\') => true,
        Some(c) if c >= 0x80 => true,
        Some(_) => b.get(i + 2) == Some(&b'\''),
        None => false,
    };
    if !is_char {
        // lifetime: skip the quote; the identifier lexes normally
        return i + 1;
    }
    i += 1;
    if b.get(i) == Some(&b'\\') {
        i += 2; // the backslash and the escaped byte (covers `'\''`)
    }
    while i < b.len() && b[i] != b'\'' {
        if b[i] == b'\n' {
            *line += 1;
        }
        i += 1;
    }
    i + 1
}

/// Past a numeric literal (int, float, exponent, suffix); `i` is at the
/// first digit.
fn consume_number(b: &[u8], mut i: usize) -> usize {
    if b[i] == b'0' && matches!(b.get(i + 1).copied(), Some(b'x' | b'o' | b'b')) {
        i += 2;
        while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
            i += 1;
        }
        return i;
    }
    while i < b.len() && (b[i] == b'_' || b[i].is_ascii_digit()) {
        i += 1;
    }
    if i < b.len() && b[i] == b'.' && b.get(i + 1).is_some_and(|c| c.is_ascii_digit()) {
        i += 1;
        while i < b.len() && (b[i] == b'_' || b[i].is_ascii_digit()) {
            i += 1;
        }
    }
    while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
        if matches!(b[i], b'e' | b'E') && matches!(b.get(i + 1).copied(), Some(b'+' | b'-')) {
            i += 2;
        } else {
            i += 1;
        }
    }
    i
}

/// Parse `lint:allow(R?): justification` out of one line comment.
fn scan_allow(comment: &str, line: usize, code_before: bool, out: &mut Lexed) {
    let Some(pos) = comment.find("lint:allow") else {
        return;
    };
    let rest = &comment[pos + "lint:allow".len()..];
    let Some(rest) = rest.strip_prefix('(') else {
        out.bad_allows.push((line, "expected `(rule)` after lint:allow".to_string()));
        return;
    };
    let Some(close) = rest.find(')') else {
        out.bad_allows.push((line, "unclosed `(` in lint:allow".to_string()));
        return;
    };
    let rule_code = rest[..close].trim();
    let Some(rule) = Rule::from_code(rule_code) else {
        out.bad_allows.push((line, format!("unknown rule `{rule_code}` in lint:allow")));
        return;
    };
    let after = rest[close + 1..].trim_start();
    let justification = after.strip_prefix(':').map(str::trim);
    match justification {
        Some(j) if !j.is_empty() => {
            out.allows.push(AllowNote { rule, line, code_before });
        }
        _ => {
            let why = format!("lint:allow({rule_code}) needs `: <justification>`");
            out.bad_allows.push((line, why));
        }
    }
}

// --- path classification ---------------------------------------------------

/// Directories scanned, relative to the repo root.
pub const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

const CLOCK_MODULE: &str = "rust/src/util/clock.rs";
const ENV_MODULE: &str = "rust/src/util/env.rs";
/// The two modules sanctioned to spawn raw threads (R6): the data-parallel
/// primitives and the async planner service.
const PAR_MODULE: &str = "rust/src/util/par.rs";
const SERVICE_MODULE: &str = "rust/src/coordinator/service.rs";

/// Paths where R2/R5 apply: everything feeding plan identity, dispatch,
/// or training numerics.
const RESTRICTED_PREFIXES: [&str; 6] = [
    "rust/src/coordinator/",
    "rust/src/solver/",
    "rust/src/exec/",
    "rust/src/runtime/",
    "rust/src/costmodel/",
    "rust/src/train/",
];

fn is_restricted(path: &str) -> bool {
    path == "rust/src/main.rs" || RESTRICTED_PREFIXES.iter().any(|p| path.starts_with(p))
}

fn is_library(path: &str) -> bool {
    path.starts_with("rust/src/")
}

// --- per-file scan ---------------------------------------------------------

/// Scan result for one file: rule findings plus the R4 site count
/// (`Some` for library files, which feed the ratchet).
#[derive(Debug)]
pub struct FileScan {
    pub findings: Vec<Finding>,
    pub unwrap_sites: Option<usize>,
}

const ENV_READ_FNS: [&str; 6] = ["var", "var_os", "vars", "vars_os", "set_var", "remove_var"];

/// Run all token rules over one file. `rel_path` must be repo-root
/// relative with forward slashes (e.g. `rust/src/solver/mod.rs`).
pub fn scan_file(rel_path: &str, src: &str) -> FileScan {
    let lexed = lex(src);
    let mut findings: Vec<Finding> = Vec::new();
    for (line, reason) in &lexed.bad_allows {
        findings.push(Finding {
            path: rel_path.to_string(),
            line: *line,
            rule: Rule::AllowSyntax,
            message: format!("{reason}; grammar: `// lint:allow(R1): <justification>`"),
        });
    }

    // resolve each allow note to the line it suppresses
    let mut allowed: BTreeMap<Rule, BTreeSet<usize>> = BTreeMap::new();
    for note in &lexed.allows {
        let target = if note.code_before {
            Some(note.line)
        } else {
            lexed.toks.iter().map(|t| t.line).find(|&l| l > note.line)
        };
        if let Some(t) = target {
            allowed.entry(note.rule).or_default().insert(t);
        }
    }
    let is_allowed = |rule: Rule, line: usize| -> bool {
        allowed.get(&rule).is_some_and(|lines| lines.contains(&line))
    };

    let toks = &lexed.toks;
    let t = |i: usize| t_at(toks, i);
    let restricted = is_restricted(rel_path);

    for i in 0..toks.len() {
        let line = toks[i].line;
        // R1: wall-clock types outside util::clock
        if rel_path != CLOCK_MODULE
            && matches!(t(i), "Instant" | "SystemTime")
            && !is_allowed(Rule::R1, line)
        {
            findings.push(Finding {
                path: rel_path.to_string(),
                line,
                rule: Rule::R1,
                message: format!(
                    "`{}` outside util::clock: take timestamps through the \
                     Clock trait (util::clock::Stopwatch) so sim runs stay \
                     bit-identical",
                    t(i)
                ),
            });
        }
        // R2: hash containers in deterministic paths
        if restricted && matches!(t(i), "HashMap" | "HashSet") && !is_allowed(Rule::R2, line) {
            findings.push(Finding {
                path: rel_path.to_string(),
                line,
                rule: Rule::R2,
                message: format!(
                    "`{}` in a deterministic path: iteration order varies per \
                     process — use BTreeMap/BTreeSet",
                    t(i)
                ),
            });
        }
        // R3: process-environment access outside util::env. The pattern is
        // `env::<read fn>` where the path is not `util::env` (so calls into
        // our snapshot module don't fire).
        if rel_path != ENV_MODULE
            && t(i) == "env"
            && t(i + 1) == "::"
            && ENV_READ_FNS.contains(&t(i + 2))
            && !(i >= 2 && t(i - 1) == "::" && t(i - 2) == "util")
            && !is_allowed(Rule::R3, line)
        {
            findings.push(Finding {
                path: rel_path.to_string(),
                line,
                rule: Rule::R3,
                message: format!(
                    "`env::{}` outside util::env: read configuration through \
                     the one-shot util::env snapshot (LOBRA_* only)",
                    t(i + 2)
                ),
            });
        }
        // R6: raw thread spawning outside util::par / coordinator::service
        if rel_path != PAR_MODULE
            && rel_path != SERVICE_MODULE
            && t(i) == "thread"
            && t(i + 1) == "::"
            && matches!(t(i + 2), "spawn" | "Builder" | "scope")
            && !is_allowed(Rule::R6, line)
        {
            findings.push(Finding {
                path: rel_path.to_string(),
                line,
                rule: Rule::R6,
                message: format!(
                    "`thread::{}` outside util::par / coordinator::service: \
                     route parallelism through par_map/par_fold (ordered \
                     reduction) or the planner service so certified paths \
                     never see ad-hoc thread timing",
                    t(i + 2)
                ),
            });
        }
        // R5: float reductions in deterministic paths
        if restricted && t(i) == "." && !is_allowed(Rule::R5, line) {
            let sum_like = matches!(t(i + 1), "sum" | "product")
                && t(i + 2) == "::"
                && t(i + 3) == "<"
                && matches!(t(i + 4), "f32" | "f64");
            let fold_like = t(i + 1) == "fold" && t(i + 2) == "(" && is_float_start(t(i + 3));
            if sum_like || fold_like {
                findings.push(Finding {
                    path: rel_path.to_string(),
                    line,
                    rule: Rule::R5,
                    message: format!(
                        "float `{}` reduction in a deterministic path: reduce \
                         in fixed order via util::par::tree_reduce, or \
                         annotate why evaluation order cannot vary",
                        t(i + 1)
                    ),
                });
            }
        }
    }

    // R4: unwrap/expect census for the ratchet (library code, test mods
    // excluded)
    let unwrap_sites = if is_library(rel_path) {
        let skip = test_ranges(toks);
        let in_test = |idx: usize| skip.iter().any(|&(a, b)| idx >= a && idx < b);
        let mut count = 0usize;
        for i in 0..toks.len() {
            if t(i) == "."
                && matches!(t(i + 1), "unwrap" | "expect")
                && t(i + 2) == "("
                && !in_test(i)
                && !is_allowed(Rule::R4, toks[i].line)
            {
                count += 1;
            }
        }
        Some(count)
    } else {
        None
    };

    FileScan { findings, unwrap_sites }
}

/// Float-literal-ish token opening a `fold` accumulator (`0.0`, `0.0f64`,
/// `f64::MAX`, ...).
fn is_float_start(tok: &str) -> bool {
    if matches!(tok, "f32" | "f64") {
        return true;
    }
    let Some(first) = tok.chars().next() else {
        return false;
    };
    if !first.is_ascii_digit() {
        return false;
    }
    if tok.starts_with("0x") || tok.starts_with("0o") || tok.starts_with("0b") {
        return false;
    }
    tok.contains('.') || tok.ends_with("f32") || tok.ends_with("f64") || tok.contains(['e', 'E'])
}

/// Token-index ranges covered by `#[cfg(test)]` items (the attribute plus
/// the following `{...}` block or `;`-terminated item).
fn test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let t = |i: usize| t_at(toks, i);
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let is_attr = t(i) == "#"
            && t(i + 1) == "["
            && t(i + 2) == "cfg"
            && t(i + 3) == "("
            && t(i + 4) == "test"
            && t(i + 5) == ")"
            && t(i + 6) == "]";
        if !is_attr {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        let mut depth = 0usize;
        let mut end = toks.len();
        while j < toks.len() {
            match t(j) {
                "{" if depth == 0 => {
                    let mut braces = 1usize;
                    j += 1;
                    while j < toks.len() && braces > 0 {
                        match t(j) {
                            "{" => braces += 1,
                            "}" => braces -= 1,
                            _ => {}
                        }
                        j += 1;
                    }
                    end = j;
                    break;
                }
                ";" if depth == 0 => {
                    end = j + 1;
                    break;
                }
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth = depth.saturating_sub(1),
                _ => {}
            }
            j += 1;
        }
        out.push((start, end));
        i = end.max(start + 1);
    }
    out
}

// --- ratchet ---------------------------------------------------------------

/// Per-file R4 site counts (paths repo-root relative, forward slashes).
pub type Ratchet = BTreeMap<String, usize>;

/// Parse the checked-in baseline (`<path> <count>` lines, `#` comments).
pub fn parse_ratchet(text: &str) -> Result<Ratchet, String> {
    let mut out = Ratchet::new();
    for (n, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(path), Some(count), None) = (parts.next(), parts.next(), parts.next()) else {
            return Err(format!("ratchet line {}: expected `<path> <count>`", n + 1));
        };
        let count: usize = count
            .parse()
            .map_err(|_| format!("ratchet line {}: bad count `{count}`", n + 1))?;
        out.insert(path.to_string(), count);
    }
    Ok(out)
}

/// Render the baseline file (sorted, self-describing header).
pub fn format_ratchet(current: &Ratchet) -> String {
    let mut s = String::from(
        "# detlint R4 ratchet: `.unwrap()`/`.expect()` sites per library file\n\
         # (rust/src, #[cfg(test)] blocks excluded). CI fails if any count\n\
         # grows; regenerate with `cargo run -p detlint -- --update-ratchet`\n\
         # only to lock in a decrease.\n",
    );
    for (path, count) in current {
        if *count > 0 {
            s.push_str(&format!("{path} {count}\n"));
        }
    }
    s
}

/// Compare the census against the baseline. Counts may only fall; a fallen
/// count must be locked in (keeps the baseline honest).
pub fn ratchet_findings(baseline: &Ratchet, current: &Ratchet) -> Vec<Finding> {
    let mut findings = Vec::new();
    let paths: BTreeSet<&String> = baseline.keys().chain(current.keys()).collect();
    for path in paths {
        let base = baseline.get(path).copied().unwrap_or(0);
        let cur = current.get(path).copied().unwrap_or(0);
        if cur > base {
            findings.push(Finding {
                path: path.clone(),
                line: 0,
                rule: Rule::R4,
                message: format!(
                    "{cur} `.unwrap()`/`.expect()` site(s) in library code but \
                     the ratchet allows {base}: return a contextual error \
                     (anyhow + Context) instead"
                ),
            });
        } else if cur < base {
            findings.push(Finding {
                path: path.clone(),
                line: 0,
                rule: Rule::R4,
                message: format!(
                    "ratchet is stale ({base} recorded, {cur} present): run \
                     `cargo run -p detlint -- --update-ratchet` to lock in \
                     the improvement"
                ),
            });
        }
    }
    findings
}

// --- tree walking ----------------------------------------------------------

/// All `.rs` files under [`SCAN_ROOTS`], sorted for deterministic output.
pub fn collect_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, &mut files)?;
        }
    }
    files.sort();
    Ok(files)
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, files)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    Ok(())
}

/// Scan the whole tree: returns rule findings plus the current R4 census.
/// Ratchet comparison is the caller's job (the CLI and tests differ in
/// where the baseline comes from).
pub fn scan_tree(root: &Path) -> std::io::Result<(Vec<Finding>, Ratchet, usize)> {
    let files = collect_files(root)?;
    let n_files = files.len();
    let mut findings = Vec::new();
    let mut census = Ratchet::new();
    for file in files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&file)?;
        let scan = scan_file(&rel, &src);
        findings.extend(scan.findings);
        if let Some(count) = scan.unwrap_sites {
            if count > 0 {
                census.insert(rel, count);
            }
        }
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok((findings, census, n_files))
}
