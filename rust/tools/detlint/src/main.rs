//! detlint CLI: scan the tree, compare the R4 census against the
//! checked-in ratchet, report findings, exit nonzero on any violation.
//!
//! ```bash
//! cargo run -p detlint                     # check (CI mode)
//! cargo run -p detlint -- --update-ratchet # lock in a lower R4 baseline
//! cargo run -p detlint -- --root ../..     # explicit repo root
//! ```

// The lint report is this binary's product; it goes to stdout by design.
#![allow(clippy::print_stdout)]

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{format_ratchet, parse_ratchet, ratchet_findings, scan_tree, Finding};

const RATCHET_REL: &str = "rust/tools/detlint/ratchet.txt";

struct Args {
    root: Option<PathBuf>,
    update_ratchet: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { root: None, update_ratchet: false };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--update-ratchet" => args.update_ratchet = true,
            "--root" => {
                let v = it.next().ok_or("--root needs a path")?;
                args.root = Some(PathBuf::from(v));
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

/// Walk up from the current directory to the checkout root (the directory
/// containing `rust/src/lib.rs`) so the tool works from the repo root, the
/// `rust/` workspace, or anywhere below.
fn discover_root() -> Result<PathBuf, String> {
    let mut dir = std::env::current_dir().map_err(|e| format!("cwd: {e}"))?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            return Err("repo root not found (no rust/src/lib.rs above cwd); pass --root".into());
        }
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("detlint: error: {e}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<ExitCode, String> {
    let args = parse_args()?;
    let root = match args.root {
        Some(r) => r,
        None => discover_root()?,
    };
    let (mut findings, census, n_files) =
        scan_tree(&root).map_err(|e| format!("scanning {}: {e}", root.display()))?;

    let ratchet_path = root.join(RATCHET_REL);
    if args.update_ratchet {
        std::fs::write(&ratchet_path, format_ratchet(&census))
            .map_err(|e| format!("writing {}: {e}", ratchet_path.display()))?;
        let sites: usize = census.values().sum();
        println!(
            "detlint: ratchet updated at {} ({} sites across {} files)",
            ratchet_path.display(),
            sites,
            census.len()
        );
    } else {
        let text = std::fs::read_to_string(&ratchet_path).map_err(|e| {
            format!(
                "reading {}: {e} (run `cargo run -p detlint -- --update-ratchet` \
                 to create it)",
                ratchet_path.display()
            )
        })?;
        let baseline = parse_ratchet(&text)?;
        findings.extend(ratchet_findings(&baseline, &census));
    }

    report(&findings, &census, n_files);
    if findings.is_empty() {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::FAILURE)
    }
}

fn report(findings: &[Finding], census: &detlint::Ratchet, n_files: usize) {
    for f in findings {
        println!("{f}");
    }
    let sites: usize = census.values().sum();
    if findings.is_empty() {
        println!(
            "detlint: clean — {n_files} files scanned, R4 ratchet at {sites} \
             unwrap/expect sites"
        );
    } else {
        println!("detlint: {} finding(s) across {n_files} files", findings.len());
    }
}
