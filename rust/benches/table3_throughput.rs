//! Appendix A Table 3: throughput (tokens/GPU/s) of each candidate parallel
//! configuration across sequence lengths and GPU counts (7B, A100-40G) —
//! the empirical basis of Observation 1 (the partial order behind the
//! configuration-proposal pruning).
//!
//! "✗" marks OOM (the configuration cannot hold the sequence), "-" marks
//! configurations that don't exist at that GPU count.
//!
//! ```bash
//! cargo bench --bench table3_throughput
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::costmodel::CostModel;
use lobra::util::bench::Table;

fn main() {
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &ClusterSpec::a100_40g(16));
    let configs = [
        (1, 1), (2, 1), (1, 2), (4, 1), (2, 2), (1, 4),
        (8, 1), (4, 2), (2, 4), (1, 8),
    ];
    let seq_lens = [2048u64, 4096, 8192, 16384];

    println!("== Table 3: tokens/GPU/s per configuration (7B, A100-40G) ==\n");
    let mut t = Table::new(&["config", "n", "max_len", "2K", "4K", "8K", "16K"]);
    for (tp, pp) in configs {
        let c = ParallelConfig::new(tp, pp);
        let cap = cost.max_chunk_tokens(c);
        let mut row = vec![
            c.to_string(),
            c.n().to_string(),
            cost.max_seq_len(c).to_string(),
        ];
        for &s in &seq_lens {
            if cap < s {
                row.push("X".into());
            } else {
                let b = (cap / s).max(1);
                row.push(format!("{:.0}", cost.throughput(c, b, s)));
            }
        }
        t.row(&row);
    }
    t.print();

    // Observation 1 validation: winners at long s stay winners at shorter s
    // (same token budget).
    println!("\n== Observation 1 check (same-n pairs) ==");
    let pairs = [
        ((1, 8), (2, 4)), ((2, 4), (4, 2)), ((4, 2), (8, 1)),
        ((1, 2), (2, 1)), ((1, 4), (4, 1)),
    ];
    let mut ok = true;
    for ((a_tp, a_pp), (b_tp, b_pp)) in pairs {
        let a = ParallelConfig::new(a_tp, a_pp);
        let b = ParallelConfig::new(b_tp, b_pp);
        let cap = cost.max_chunk_tokens(a).min(cost.max_chunk_tokens(b));
        let s0 = cap.min(8192);
        let thr_a0 = cost.throughput(a, 1, s0);
        let thr_b0 = cost.throughput(b, 1, s0);
        let winner_long = thr_a0 > thr_b0;
        let mut consistent = true;
        let mut s = s0 / 2;
        while s >= 512 {
            let bsz = s0 / s;
            let wins = cost.throughput(a, bsz, s) > cost.throughput(b, bsz, s);
            if wins != winner_long {
                consistent = false;
            }
            s /= 2;
        }
        println!(
            "  {a} vs {b}: winner@{s0}={} consistent_at_shorter={consistent}",
            if winner_long { a.to_string() } else { b.to_string() }
        );
        ok &= consistent;
    }
    println!("Observation 1 holds: {ok}");
}
