//! Appendix C Table 11: executor parity on homogeneous configurations.
//!
//! The paper shows LobRA's executor matches NeMo when both run the same
//! homogeneous parallel configuration with uniform dispatch. Here the
//! "NeMo-like reference" is the idealized executor — pure compute + comm
//! time from the cost model with no coordinator on top — and the LobRA
//! number is the full coordinator path (bucketing, dispatch solve, sync,
//! per-step accounting) on the same fixed-length workload. Parity means
//! the coordinator adds only noise-level overhead.
//!
//! ```bash
//! cargo bench --bench table11_homogeneous
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::bucketing::Buckets;
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::DeploymentPlan;
use lobra::costmodel::{BucketLoad, CostModel};
use lobra::util::bench::Table;

fn main() {
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    // (config, replicas, max_seq_len) rows of Table 11 (global batch 64).
    let rows: Vec<(ParallelConfig, u32, u64)> = vec![
        (ParallelConfig::new(1, 1), 16, 2048),
        (ParallelConfig::new(1, 2), 8, 2048),
        (ParallelConfig::new(1, 4), 4, 2048),
        (ParallelConfig::new(1, 4), 4, 4096),
        (ParallelConfig::new(1, 8), 2, 2048),
        (ParallelConfig::new(1, 8), 2, 4096),
        (ParallelConfig::new(2, 1), 8, 2048),
        (ParallelConfig::new(2, 1), 8, 4096),
        (ParallelConfig::new(2, 2), 4, 4096),
        (ParallelConfig::new(2, 4), 2, 8192),
        (ParallelConfig::new(4, 1), 4, 8192),
        (ParallelConfig::new(4, 2), 2, 8192),
        (ParallelConfig::new(8, 1), 2, 8192),
        (ParallelConfig::new(8, 1), 2, 16384),
    ];
    let global_batch = 64u64;

    println!("== Table 11: homogeneous-configuration executor parity (7B, 16 GPUs, batch 64) ==\n");
    let mut t = Table::new(&[
        "config", "replicas", "seq len", "LobRA path (s)", "reference (s)", "overhead",
    ]);
    for (cfg, replicas, seqlen) in rows {
        if cost.max_seq_len(cfg) < seqlen {
            continue; // OOM row (the paper only lists feasible cells)
        }
        // reference: ideal executor — replicas share the batch evenly,
        // time = exact replica time without any coordinator involvement.
        let per_replica = global_batch.div_ceil(replicas as u64);
        let reference = cost.replica_time(
            cfg,
            &[BucketLoad { count: per_replica, padded_len: seqlen }],
        );
        // LobRA path: full dispatcher machinery on the same uniform batch.
        let plan = DeploymentPlan::homogeneous(cfg, replicas, 6);
        let dispatcher = Dispatcher::new(&cost, &plan);
        let buckets = Buckets {
            boundaries: vec![seqlen as u32],
            counts: vec![global_batch],
            padding_tokens: 0,
        };
        let dp = dispatcher.dispatch(&buckets, DispatchPolicy::Balanced).unwrap();
        let lobra = dp.predicted_step_time;
        t.row(&[
            cfg.to_string(),
            replicas.to_string(),
            seqlen.to_string(),
            format!("{lobra:.3}"),
            format!("{reference:.3}"),
            format!("{:+.1}%", (lobra / reference - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("\nparity check: overhead should stay within a few percent (sync + dispatch only).");
}
