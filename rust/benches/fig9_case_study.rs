//! Figure 9 case study (7B, 16 A100-40G): per-replica-kind step time and
//! the composition of dispatched data (tokens per bucket), under
//! length-based dispatch / balanced dispatch / balanced + dynamic
//! bucketing. Shows the skew-induced imbalance and how LobRA closes it.
//!
//! ```bash
//! cargo bench --bench fig9_case_study
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::coordinator::bucketing::{bucketize, buckets_from_boundaries, BucketingOptions};
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::Planner;
use lobra::data::MultiTaskSampler;
use lobra::experiments::Scenario;
use lobra::util::bench::Table;

fn main() {
    let sc = Scenario::paper_7b_16();
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    println!("== Figure 9 case study: {} ==", sc.label);
    println!("plan: [{}]\n", plan.notation());

    // one representative fused batch
    let mut sampler = MultiTaskSampler::new(&sc.tasks, 42);
    let batch = sampler.next_batch();
    let lengths = batch.lengths();

    // fixed boundaries from a calibration sample (for the first two arms)
    let mut calib_sampler = MultiTaskSampler::new(&sc.tasks, 7);
    let calib = calib_sampler.calibration_lengths(20);
    let opts = BucketingOptions::default();
    let fixed = bucketize(&calib, &opts).boundaries;

    let arms: [(&str, DispatchPolicy, bool); 3] = [
        ("length-based dispatch", DispatchPolicy::LengthBased, false),
        ("workload-balanced", DispatchPolicy::Balanced, false),
        ("balanced + dynamic bucketing", DispatchPolicy::Balanced, true),
    ];

    let dispatcher = Dispatcher::new(&cost, &plan);
    for (label, policy, dynb) in arms {
        let buckets = if dynb {
            bucketize(&lengths, &opts)
        } else {
            buckets_from_boundaries(&lengths, &fixed)
        };
        let dp = dispatcher.dispatch(&buckets, policy).unwrap();
        println!("--- {label} ---");
        let mut t = Table::new(&["replica kind", "time (s)", "tokens by bucket (padded)"]);
        for (i, &(cfg, p)) in dp.groups.iter().enumerate() {
            // per-group time = max over that group's replicas
            let times: Vec<f64> = dp
                .replica_times
                .iter()
                .filter(|&&(c, _)| c == cfg)
                .map(|&(_, x)| x)
                .collect();
            let tmax = times.iter().cloned().fold(0.0f64, f64::max);
            let composition: Vec<String> = dp.d[i]
                .iter()
                .zip(&buckets.boundaries)
                .filter(|&(&d, _)| d > 0)
                .map(|(&d, &b)| format!("{}x<={}", d, b))
                .collect();
            t.row(&[
                format!("{cfg} x{p}"),
                format!("{tmax:.2}"),
                composition.join(" "),
            ]);
        }
        t.print();
        let max_t = dp.predicted_step_time;
        let min_t = dp
            .replica_times
            .iter()
            .map(|&(_, x)| x)
            .fold(f64::INFINITY, f64::min);
        println!(
            "step time {max_t:.2}s; fastest replica busy {min_t:.2}s ({:.0}% idle at the barrier)\n",
            (1.0 - min_t / max_t) * 100.0
        );
    }
}
