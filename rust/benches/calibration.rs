//! Calibration fit quality (ours): sim-backed in-situ calibration of the
//! cost model (ROADMAP "real profiling hooks", paper Appendix D).
//!
//! Runs `lobra calibrate`'s loop twice over: first dispatch steps through
//! the planner's own deployment, then a multi-GPU **cell sweep** — one
//! homogeneous deployment per power-of-two `(tp, pp)` cell that fits the
//! fleet — so every parallel configuration the planner could pick gets
//! profiled, not just the ones it did. The `SimExecutor` tags every
//! executed microbatch with an exact `(b, s, seconds, comm, bubble)`
//! observation; the store fits `t_compute(b,s) = β₀ + β₁·bs + β₂·bs²`
//! per configuration and the bench reports, per `(tp, pp)` cell:
//!
//!  * **rms_rel_error** — the fit's error against its own observations;
//!  * **max_rel_divergence** — worst-case relative gap between the
//!    profiled cost model's `t_microbatch` (fitted compute + analytic
//!    tp/pp communication) and the analytic `t_microbatch` over the
//!    observed shapes. The sim's chunk times are exactly in the fitted
//!    family, so both numbers measure end-to-end calibration fidelity
//!    across the whole (tp, pp) matrix (target: ~1e-6);
//!  * whether a deployment plan computed from the measured profile
//!    reproduces the analytic plan.
//!
//! Results go to `BENCH_calibration.json` (path override:
//! `LOBRA_BENCH_JSON`; knobs: `LOBRA_BENCH_GPUS`, `LOBRA_BENCH_STEPS`).
//!
//! `LOBRA_BENCH_BASELINE=path` gates the run's JSON against a checked-in
//! baseline (the `*_seconds` wall-clocks are host-dependent and skipped;
//! the observation counts, fit errors, and divergences are sim-exact and
//! locked) and exits nonzero on drift. A baseline holding a
//! `"bless": true` line is overwritten with this run instead — how the
//! first CI run locks in real numbers from a toolchain-less commit.
//!
//! ```bash
//! cargo bench --bench calibration
//! LOBRA_BENCH_GPUS=32 LOBRA_BENCH_STEPS=32 cargo bench --bench calibration
//! LOBRA_BENCH_BASELINE=benches/baselines/BENCH_calibration.json \
//!     cargo bench --bench calibration                  # drift gate
//! ```


// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use lobra::costmodel::{CalibrationStore, CostModel};
use lobra::exec::profile_sim_steps;
use lobra::prelude::TaskSet;
use lobra::util::bench::{fmt_secs, gate_against_baseline, BaselineGate, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

/// JSON-safe float: non-finite values become `null`.
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}

/// Wall-clock lines (`profiling_seconds`, `fit_seconds`) vary per host;
/// everything else — observation counts, fit errors, divergences — is
/// sim-exact and locked by the baseline gate.
fn host_dependent(line: &str) -> bool {
    line.contains("seconds")
}

/// Render the shared baseline gate's outcome; exits nonzero on drift so
/// CI fails loudly when the fit-quality metrics change.
fn render_gate(path: &str, current: &str) {
    match gate_against_baseline(path, current, &host_dependent) {
        BaselineGate::Blessed => println!("baseline {path} blessed from this run"),
        BaselineGate::Ok(n) => println!("baseline {path}: OK ({n} deterministic lines)"),
        BaselineGate::Unreadable(e) => {
            eprintln!("ERROR: baseline {path} unreadable: {e}");
            std::process::exit(1);
        }
        BaselineGate::WriteFailed(e) => {
            eprintln!("ERROR: blessing baseline {path}: {e}");
            std::process::exit(1);
        }
        BaselineGate::Drift(diff) => {
            eprintln!("ERROR: calibration metrics drifted from baseline {path}:");
            for (w, g) in diff {
                eprintln!("  - {w}");
                eprintln!("  + {g}");
            }
            std::process::exit(1);
        }
    }
}

fn main() {
    let gpus: u32 = benv::parse_or("LOBRA_BENCH_GPUS", 16);
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 16);
    let json_path =
        benv::var("LOBRA_BENCH_JSON").unwrap_or("BENCH_calibration.json").to_string();
    let baseline_path = benv::var("LOBRA_BENCH_BASELINE");

    let cluster = ClusterSpec::a100_40g(gpus);
    let model = ModelDesc::llama2_7b();
    let tasks = TaskSet::paper_7b_subset();
    let n_tasks = tasks.tasks.len() as u32;
    let cost = CostModel::calibrated(&model, &cluster);
    let planner = Planner::new(&cost, &cluster);
    let plan = planner
        .plan(&tasks, PlannerOptions::default())
        .expect("no feasible analytic plan");

    println!(
        "== Calibration: sim-backed fit of t(b,s), 7B / {gpus} GPUs, {steps} profiling steps ==\n"
    );
    let t0 = Stopwatch::start();
    let mut store = CalibrationStore::new(&cost);
    // First the planner's own deployment, then one homogeneous deployment
    // per power-of-two (tp, pp) cell that fits the fleet, so the fit
    // matrix covers every configuration the planner could have picked.
    let mut n_obs = profile_sim_steps(&cost, &plan, &tasks, steps, 7, &mut store);
    let mut cells = 0u32;
    let mut pp = 1u32;
    while pp <= gpus && pp <= model.n_layers {
        let mut tp = 1u32;
        while tp * pp <= gpus {
            let config = ParallelConfig::new(tp, pp);
            let replicas = gpus / (tp * pp);
            let cell_plan = DeploymentPlan::homogeneous(config, replicas, n_tasks);
            let seed = 1000 + u64::from(pp) * 64 + u64::from(tp);
            n_obs += profile_sim_steps(&cost, &cell_plan, &tasks, steps, seed, &mut store);
            cells += 1;
            tp *= 2;
        }
        pp *= 2;
    }
    let profile_s = t0.elapsed_secs();
    let t1 = Stopwatch::start();
    let n_fitted = store.refit();
    let fit_s = t1.elapsed_secs();

    // The end-to-end check: attach the measured profile to a fresh cost
    // model and compare its t_microbatch — fitted compute plus analytic
    // communication — against the purely analytic one, per cell.
    let profile = store.profile();
    let profiled = CostModel::from_profile(&model, &cluster, profile)
        .expect("freshly measured profile must attach to its own world");

    let mut t = Table::new(&["config", "obs", "shapes", "rms_rel_error", "max_rel_divergence"]);
    let mut rows_json = String::new();
    let mut worst_divergence = 0.0f64;
    for (i, e) in store.entries().iter().enumerate() {
        let mut shapes: Vec<(u64, u64)> =
            e.observations.iter().map(|o| (o.b, o.s)).collect();
        shapes.sort_unstable();
        shapes.dedup();
        let (rms, max_div) = if e.fitted.is_some() {
            let rms = e.rms_rel_error().unwrap_or(f64::NAN);
            let mut d = 0.0f64;
            for &(b, s) in &shapes {
                let analytic = cost.t_microbatch(e.config, b, s);
                if analytic > 0.0 {
                    d = d.max(
                        ((profiled.t_microbatch(e.config, b, s) - analytic) / analytic).abs(),
                    );
                }
            }
            (rms, d)
        } else {
            (f64::NAN, f64::NAN)
        };
        if max_div.is_finite() {
            worst_divergence = worst_divergence.max(max_div);
        }
        t.row(&[
            e.config.to_string(),
            e.observations.len().to_string(),
            shapes.len().to_string(),
            if rms.is_finite() { format!("{rms:.3e}") } else { "n/a".to_string() },
            if max_div.is_finite() { format!("{max_div:.3e}") } else { "n/a".to_string() },
        ]);
        rows_json.push_str(&format!(
            "{}\n    {{\"tp\": {}, \"pp\": {}, \"observations\": {}, \"shapes\": {}, \
             \"rms_rel_error\": {}, \"max_rel_divergence\": {}}}",
            if i > 0 { "," } else { "" },
            e.config.tp,
            e.config.pp,
            e.observations.len(),
            shapes.len(),
            json_f64(rms),
            json_f64(max_div),
        ));
    }
    t.print();

    // Close the loop: plan from the measured profile and compare.
    let replan = Planner::new(&profiled, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .expect("no feasible plan from the measured profile");
    let plans_agree = replan.groups == plan.groups;

    println!(
        "\n{n_obs} observations over {cells} swept cells; {n_fitted}/{} configs fitted; \
         profiling {} + fit {}; worst divergence {worst_divergence:.3e}",
        store.entries().len(),
        fmt_secs(profile_s),
        fmt_secs(fit_s),
    );
    println!(
        "plan from measured profile: [{}]  analytic: [{}]  agree: {plans_agree}",
        replan.notation(),
        plan.notation()
    );

    let json = format!(
        "{{\n  \"bench\": \"calibration\",\n  \"gpus\": {gpus},\n  \"steps\": {steps},\n  \
         \"cells\": {cells},\n  \"observations\": {n_obs},\n  \"configs_fitted\": {n_fitted},\n  \
         \"configs_total\": {},\n  \"profile_generation\": {},\n  \
         \"profiling_seconds\": {profile_s:.6},\n  \"fit_seconds\": {fit_s:.6},\n  \
         \"worst_rel_divergence\": {},\n  \"plans_agree\": {plans_agree},\n  \
         \"configs\": [{rows_json}\n  ]\n}}\n",
        store.entries().len(),
        store.generation(),
        json_f64(worst_divergence),
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nfit quality recorded to {json_path}"),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }
    if let Some(p) = baseline_path {
        render_gate(p, &json);
    }
}
