//! Appendix B.2 Table 5: configuration-planning cost (70B) across GPU
//! counts, under three pruning regimes —
//!
//!   (1) w/o configuration proposal, w/o lower-bound filtering
//!   (2) w/  configuration proposal, w/o lower-bound filtering
//!   (3) w/  configuration proposal, w/  lower-bound filtering
//!
//! Paper: (1) times out beyond 32 GPUs, (2) beyond 48; (3) finishes in
//! minutes at 256 GPUs, with identical plans where all complete.
//! A per-cell time budget (`LOBRA_BENCH_TIMEOUT`, default 120 s — the
//! paper used 3600 s) marks cells "X" via plan-cap detection.
//!
//! Knobs: `LOBRA_BENCH_MAX_GPUS` caps the cluster sweep (default 128; set
//! 256 to reproduce the paper's full Table 5 — the opt-in CI job does);
//! `LOBRA_BENCH_JSON` records per-cell wall-clocks to the given path.
//!
//! ```bash
//! cargo bench --bench table5_pruning
//! LOBRA_BENCH_MAX_GPUS=256 LOBRA_BENCH_JSON=BENCH_table5.json \
//!   cargo bench --bench table5_pruning
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::costmodel::CostModel;
use lobra::prelude::TaskSet;
use lobra::util::bench::Table;
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn main() {
    let timeout: f64 = benv::parse_or("LOBRA_BENCH_TIMEOUT", 120.0);
    let max_gpus: u32 = benv::parse_or("LOBRA_BENCH_MAX_GPUS", 128);
    let json_path = benv::var("LOBRA_BENCH_JSON").map(str::to_string);
    let tasks = TaskSet::paper_scalability_subset();
    println!(
        "== Table 5: planning cost, 70B, 4 tasks (timeout {timeout:.0}s/cell, \
         up to {max_gpus} GPUs) ==\n"
    );

    let regimes: [(&str, bool, bool); 3] = [
        ("w/o proposal, w/o filter", false, false),
        ("w/ proposal, w/o filter", true, false),
        ("w/ proposal, w/ filter", true, true),
    ];

    let mut t = Table::new(&[
        "# GPUs", regimes[0].0, regimes[1].0, regimes[2].0, "plan (w/ both)",
    ]);
    // which regimes already exceeded the budget at a smaller scale — the
    // paper marks larger scales X without re-running.
    let mut dead = [false; 3];
    let mut json_rows: Vec<String> = Vec::new();

    for gpus in [16u32, 24, 32, 40, 48, 64, 128, 256].into_iter().filter(|&g| g <= max_gpus) {
        let cluster = ClusterSpec::a800_80g(gpus);
        let cost = CostModel::calibrated(&ModelDesc::llama2_70b(), &cluster);
        let planner = Planner::new(&cost, &cluster);
        let mut cells = vec![gpus.to_string()];
        let mut final_plan = String::new();
        // per-regime wall-clock for the JSON record; NaN → null (cell
        // skipped or over budget)
        let mut walls = [f64::NAN; 3];
        for (ri, &(_, proposal, filter)) in regimes.iter().enumerate() {
            if dead[ri] {
                cells.push("X".into());
                continue;
            }
            let mut opts = PlannerOptions::default();
            opts.config_proposal = proposal;
            opts.lower_bound_filter = filter;
            opts.max_plans = 5_000_000;
            // pre-estimate: without the filter every plan pays a full
            // dispatch solve (~1 ms with robustness batches); skip cells
            // that cannot finish inside the budget instead of hanging.
            if !filter {
                let candidates = if proposal {
                    let pl = Planner::new(&cost, &cluster);
                    pl.propose_configs(&[512, 2048, 8192, 16384], true)
                } else {
                    Planner::new(&cost, &cluster).feasible_configs(true)
                };
                let est = lobra::solver::partition::count_plans(
                    &candidates,
                    gpus,
                    gpus.saturating_sub(3),
                );
                if est as f64 * 1e-3 > timeout {
                    cells.push(format!("X (~{est} plans)"));
                    dead[ri] = true;
                    continue;
                }
            }
            let t0 = Stopwatch::start();
            let result = planner.plan_with_stats(&tasks, opts);
            let dt = t0.elapsed_secs();
            match result {
                Some((plan, stats)) => {
                    if dt > timeout || stats.hit_plan_cap {
                        cells.push(format!("X (>{dt:.0}s)"));
                        dead[ri] = true;
                    } else {
                        cells.push(format!("{dt:.2}"));
                        walls[ri] = dt;
                    }
                    if filter {
                        final_plan = plan.notation();
                        eprintln!(
                            "    {gpus} GPUs w/ filter: {} enumerated, {} survivors, \
                             peak storage {}",
                            stats.n_plans_enumerated,
                            stats.n_plans_after_filter,
                            stats.peak_plan_storage
                        );
                    }
                }
                None => cells.push("-".into()),
            }
        }
        cells.push(final_plan.clone());
        t.row(&cells);
        let cell = |w: f64| {
            if w.is_nan() {
                "null".to_string()
            } else {
                format!("{w:.3}")
            }
        };
        json_rows.push(format!(
            "    {{\"gpus\": {gpus}, \"no_proposal_no_filter\": {}, \
             \"proposal_no_filter\": {}, \"proposal_filter\": {}, \
             \"plan\": \"{final_plan}\"}}",
            cell(walls[0]),
            cell(walls[1]),
            cell(walls[2])
        ));
        eprintln!("  {gpus} GPUs done");
    }
    t.print();
    if let Some(path) = &json_path {
        let json = format!(
            "{{\n  \"bench\": \"table5_pruning\",\n  \"max_gpus\": {max_gpus},\n  \
             \"timeout_seconds\": {timeout},\n  \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n")
        );
        match std::fs::write(path, &json) {
            Ok(()) => println!("\nwall-clocks recorded to {path}"),
            Err(e) => eprintln!("\nWARNING: could not write {path}: {e}"),
        }
    }
    println!("\npaper shape: un-pruned times explode with GPU count; both prunings keep it in minutes.");
}
