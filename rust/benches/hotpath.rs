//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! everything that runs on the per-step critical path of the coordinator —
//! dynamic bucketing DP, dispatch problem construction, the balanced
//! min–max solve — plus the planner's inner loops (lower bound, plan
//! enumeration). The per-step path must stay far below the training step
//! so it fully overlaps (paper Figure 10, left).
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::coordinator::bucketing::{bucketize, BucketingOptions};
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::{LowerBoundScratch, Planner};
use lobra::costmodel::CostTable;
use lobra::data::MultiTaskSampler;
use lobra::experiments::Scenario;
use lobra::solver::{self, partition};
use lobra::util::bench::{fmt_secs, time_fn, Table};

fn main() {
    let sc = Scenario::paper_7b_16();
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    let dispatcher = Dispatcher::new(&cost, &plan);

    let mut sampler = MultiTaskSampler::new(&sc.tasks, 3);
    let batch = sampler.next_batch();
    let lengths = batch.lengths();
    let opts = BucketingOptions::default();
    let buckets = bucketize(&lengths, &opts);
    let problem = dispatcher.problem(&buckets);

    let mut t = Table::new(&["hot path", "median", "mean", "min"]);
    let mut bench = |label: &str, f: &mut dyn FnMut()| {
        let r = time_fn(3, 30, f);
        t.row(&[
            label.to_string(),
            fmt_secs(r.median),
            fmt_secs(r.mean),
            fmt_secs(r.min),
        ]);
    };

    bench("bucketize DP (B=832, R=16)", &mut || {
        std::hint::black_box(bucketize(&lengths, &opts));
    });
    bench("dispatch problem build", &mut || {
        std::hint::black_box(dispatcher.problem(&buckets));
    });
    bench("solve_balanced (Eq.3)", &mut || {
        std::hint::black_box(solver::solve_balanced(&problem));
    });
    bench("solve_length_based", &mut || {
        std::hint::black_box(solver::solve_length_based(&problem));
    });
    bench("full per-step path (bucket+build+solve+eval)", &mut || {
        let b = bucketize(&lengths, &opts);
        std::hint::black_box(dispatcher.dispatch(&b, DispatchPolicy::Balanced));
    });

    // planner-side inner loops (one-shot cost, but Table 5 scales with them)
    let configs = planner.propose_configs(&buckets.boundaries, true);
    let plans = partition::enumerate_plans(&configs, 16, 16, None, 1_000_000);
    bench("plan enumeration (N=16, collected)", &mut || {
        std::hint::black_box(partition::enumerate_plans(&configs, 16, 16, None, 1_000_000));
    });
    bench("plan enumeration (N=16, streaming)", &mut || {
        let mut n = 0u64;
        partition::visit_plans(&configs, 16, 16, None, &mut |_| {
            n += 1;
            true
        });
        std::hint::black_box(n);
    });
    let one = plans[plans.len() / 2].clone();
    bench("Theorem-1 lower bound (uncached)", &mut || {
        std::hint::black_box(planner.lower_bound(&configs, &one, &buckets));
    });
    let table = CostTable::build(&cost, &configs, &buckets.boundaries);
    bench("CostTable build (configs x buckets)", &mut || {
        std::hint::black_box(CostTable::build(&cost, &configs, &buckets.boundaries));
    });
    let mut scratch = LowerBoundScratch::new();
    bench("Theorem-1 lower bound (memoized)", &mut || {
        std::hint::black_box(planner.lower_bound_cached(
            &table,
            &one.counts,
            &buckets,
            &mut scratch,
        ));
    });
    let popts = sc.planner_opts();
    bench("fused streaming search (N=16)", &mut || {
        std::hint::black_box(planner.filtered_plans(&configs, &table, &buckets, &popts));
    });

    println!("== hot-path microbenchmarks ==\n");
    t.print();
    println!("\nfull per-step path must be << simulated step time ({:.1}s)", plan.expected_step_time);
}
