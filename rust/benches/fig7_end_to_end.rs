//! Figure 7 + Table 2: end-to-end joint-FT GPU seconds per step for
//! Task-Fused / Task-Sequential / LobRA-Sequential / LobRA on the paper's
//! three worlds (7B/16×A100, 32B/64×A800, 70B/64×A800).
//!
//! Expected shape (paper): LobRA < LobRA-Seq <= Task-Seq < Task-Fused,
//! with 45.03%–60.67% reduction of LobRA vs Task-Fused, largest on 70B.
//!
//! ```bash
//! cargo bench --bench fig7_end_to_end
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::experiments::{Arm, Scenario};
use lobra::util::bench::Table;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 100);
    println!("== Figure 7: end-to-end evaluation ({steps} steps/arm) ==\n");

    let scenarios = [
        Scenario::paper_7b_16(),
        Scenario::paper_32b_64(),
        Scenario::paper_70b_64(),
    ];
    let arms = [
        Arm::TaskFused,
        Arm::TaskSequential,
        Arm::LobraSequential,
        Arm::Lobra,
    ];

    let mut fig7 = Table::new(&["world", "arm", "GPU·s/step", "±std", "vs Task-Fused"]);
    let mut table2 = Table::new(&["world", "Task-Fused plan", "LobRA plan"]);

    for sc in &scenarios {
        eprintln!("running {} ...", sc.label);
        let mut fused_gs = None;
        let mut fused_plan = String::new();
        let mut lobra_plan = String::new();
        for arm in arms {
            let Some(res) = sc.arm_report(arm, steps) else {
                eprintln!("  {}: infeasible", arm.label());
                continue;
            };
            let gs = res.report.gpu_seconds_per_step;
            let reduction = match (arm, fused_gs) {
                (Arm::TaskFused, _) => {
                    fused_gs = Some(gs);
                    "—".to_string()
                }
                (_, Some(f)) => format!("-{:.2}%", (1.0 - gs / f) * 100.0),
                _ => "?".to_string(),
            };
            match arm {
                Arm::TaskFused => fused_plan = res.plan.as_ref().unwrap().notation(),
                Arm::Lobra => lobra_plan = res.plan.as_ref().unwrap().notation(),
                _ => {}
            }
            fig7.row(&[
                sc.label.clone(),
                arm.label().to_string(),
                format!("{gs:.2}"),
                format!("{:.2}", res.report.gpu_seconds_std),
                reduction,
            ]);
        }
        table2.row(&[sc.label.clone(), fused_plan, lobra_plan]);
    }

    fig7.print();
    println!("\n== Table 2: parallel configurations used ==\n");
    table2.print();
}
