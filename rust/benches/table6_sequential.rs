//! Appendix B.2 Table 6: per-task GPU seconds of Task-Sequential vs
//! LobRA-Sequential (70B, 64 GPUs). LobRA's techniques help most tasks
//! even in single-task FT, but small per-task batches limit (and can
//! invert) the gains — the paper sees two tasks regress.
//!
//! ```bash
//! cargo bench --bench table6_sequential
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::experiments::{Arm, Scenario};
use lobra::util::bench::Table;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 30);
    let sc = Scenario::paper_70b_64();
    println!("== Table 6: per-task sequential comparison, {} ({steps} steps) ==\n", sc.label);

    let seq = sc.arm_report(Arm::TaskSequential, steps).unwrap();
    let lobra_seq = sc.arm_report(Arm::LobraSequential, steps).unwrap();
    for (arm, res) in [("Task-Sequential", &seq), ("LobRA-Sequential", &lobra_seq)] {
        if !res.skipped.is_empty() {
            println!(
                "WARNING: {arm} could not plan {:?} — its total under-counts\n",
                res.skipped
            );
        }
    }

    let mut t = Table::new(&["task", "Task-Sequential (T1)", "LobRA-Sequential (T2)", "(T1-T2)/T1"]);
    let mut improved = 0;
    let mut total = 0;
    for ((name, t1), (_, t2)) in seq.per_task.iter().zip(&lobra_seq.per_task) {
        let red = (t1 - t2) / t1;
        if red > 0.0 {
            improved += 1;
        }
        total += 1;
        t.row(&[
            name.clone(),
            format!("{t1:.1}"),
            format!("{t2:.1}"),
            format!("{:.2}%", red * 100.0),
        ]);
    }
    t.print();
    println!(
        "\n{improved}/{total} tasks improved; totals: {:.1} vs {:.1} GPU·s/step ({:.1}% reduction)",
        seq.report.gpu_seconds_per_step,
        lobra_seq.report.gpu_seconds_per_step,
        (1.0 - lobra_seq.report.gpu_seconds_per_step / seq.report.gpu_seconds_per_step) * 100.0
    );
}
