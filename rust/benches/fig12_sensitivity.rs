//! Figure 12: sensitivity to the number of buckets R (7B, 16×A100):
//! per-step time (scaled by R=4) and padding ratio, R ∈ {4..32}.
//!
//! Paper shape: padding decreases monotonically with R; step time improves
//! until R≈12 then flattens (more buckets → more per-bucket overhead).
//!
//! ```bash
//! cargo bench --bench fig12_sensitivity
//! ```

use lobra::coordinator::bucketing::BucketingOptions;
use lobra::coordinator::planner::Planner;
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::experiments::Scenario;
use lobra::util::bench::Table;

fn main() {
    let steps: usize = std::env::var("LOBRA_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    let sc = Scenario::paper_7b_16();
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    println!("== Figure 12: impact of R ({} steps each) ==", steps);
    println!("plan: [{}]\n", plan.notation());

    let mut baseline_time = None;
    let mut t = Table::new(&["R", "step time (scaled to R=4)", "padding ratio", "solve (ms)"]);
    for r in [4usize, 8, 12, 16, 20, 24, 32] {
        let mut opts = SchedulerOptions::default();
        opts.bucketing = BucketingOptions { max_buckets: r, ..Default::default() };
        let rep = Scheduler::new(&cost, &plan, &sc.tasks, opts).run_steps(steps);
        let st = rep.mean_step_time;
        let base = *baseline_time.get_or_insert(st);
        t.row(&[
            r.to_string(),
            format!("{:.3}", st / base),
            format!("{:.1}%", rep.mean_padding_ratio * 100.0),
            format!("{:.2}", rep.mean_solve_seconds * 1e3),
        ]);
    }
    t.print();
}
