//! Figure 12: sensitivity to the number of buckets R (7B, 16×A100):
//! per-step time (scaled by R=4) and padding ratio, R ∈ {4..32}.
//!
//! Paper shape: padding decreases monotonically with R; step time improves
//! until R≈12 then flattens (more buckets → more per-bucket overhead).
//!
//! ```bash
//! cargo bench --bench fig12_sensitivity
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::coordinator::bucketing::BucketingOptions;
use lobra::coordinator::planner::Planner;
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::experiments::Scenario;
use lobra::util::bench::Table;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 50);
    let sc = Scenario::paper_7b_16();
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    println!("== Figure 12: impact of R ({} steps each) ==", steps);
    println!("plan: [{}]\n", plan.notation());

    let mut baseline_time = None;
    let mut t = Table::new(&["R", "step time (scaled to R=4)", "padding ratio", "solve (ms)"]);
    for r in [4usize, 8, 12, 16, 20, 24, 32] {
        let mut opts = SchedulerOptions::default();
        opts.bucketing = BucketingOptions { max_buckets: r, ..Default::default() };
        let rep = Scheduler::new(&cost, &plan, &sc.tasks, opts).run_steps(steps);
        let st = rep.mean_step_time;
        let base = *baseline_time.get_or_insert(st);
        t.row(&[
            r.to_string(),
            format!("{:.3}", st / base),
            format!("{:.1}%", rep.mean_padding_ratio * 100.0),
            format!("{:.2}", rep.mean_solve_seconds * 1e3),
        ]);
    }
    t.print();
}
