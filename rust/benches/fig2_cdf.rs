//! Figure 2: cumulative distributions of sequence lengths for three FT
//! datasets (databricks-dolly-15k, CommitPackFt, MeetingBank), annotated
//! with the GPU count needed to process each length range (7B, A100-40G).
//!
//! ```bash
//! cargo bench --bench fig2_cdf
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::costmodel::CostModel;
use lobra::data::DatasetProfile;
use lobra::util::bench::Table;
use lobra::util::stats::ecdf;
use lobra::util::Rng;

fn main() {
    let datasets = ["databricks-dolly-15k", "CommitPackFt", "MeetingBank"];
    let points: Vec<f64> = [256, 512, 1024, 2048, 4096, 8192, 16384]
        .iter()
        .map(|&x| x as f64)
        .collect();

    println!("== Figure 2: sequence-length CDFs (100k samples each) ==\n");
    let mut t = Table::new(&[
        "length <=", "dolly-15k", "CommitPackFt", "MeetingBank", "GPUs needed (7B, A100-40G)",
    ]);

    let mut rng = Rng::new(2);
    let cdfs: Vec<Vec<f64>> = datasets
        .iter()
        .map(|name| {
            let d = DatasetProfile::by_name(name).unwrap().distribution();
            let xs: Vec<f64> = d
                .sample_n(&mut rng, 100_000)
                .into_iter()
                .map(|x| x as f64)
                .collect();
            ecdf(&xs, &points)
        })
        .collect();

    // GPUs needed: smallest config n supporting the length (7B / A100-40G)
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &ClusterSpec::a100_40g(16));
    let gpus_needed = |len: u64| -> String {
        for n in [1u32, 2, 4, 8, 16] {
            // the best capacity at n GPUs is the full-TP config
            let c = ParallelConfig::new(n.min(8), n.div_ceil(8).max(1));
            if cost.max_seq_len(c) >= len {
                return format!("{n}");
            }
        }
        ">16".into()
    };

    for (pi, &p) in points.iter().enumerate() {
        t.row(&[
            format!("{p:.0}"),
            format!("{:.1}%", cdfs[0][pi] * 100.0),
            format!("{:.1}%", cdfs[1][pi] * 100.0),
            format!("{:.1}%", cdfs[2][pi] * 100.0),
            gpus_needed(p as u64),
        ]);
    }
    t.print();

    println!("\npaper shape check: >50% of fused data shorter than 2K; few beyond 8K.");
}
