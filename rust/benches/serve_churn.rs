//! Churn-trace serving scenario (ISSUE 5): the event-driven runtime
//! replays tenant arrivals/exits while training overlaps a budgeted
//! anytime replan, and reports tenant-observed serving metrics —
//! time-to-admission, steps trained during replan windows (the
//! no-stop-the-world proof), and GPU-seconds lost to redeploys (charged
//! only for replica groups that actually changed).
//!
//! The budget is metered on a deterministic sim clock (seconds per
//! enumerated plan), so the scenario reproduces bit-for-bit across hosts;
//! host wall-clocks are recorded alongside. Results go to
//! `BENCH_serve.json` (override: `LOBRA_BENCH_JSON`).
//!
//! With `LOBRA_BENCH_PLANNER_THREADS=N` the runtime plans through the
//! async [`coordinator::service`] instead of the in-loop sync path, and
//! the search-time split shows the overlap win: `search_seconds_total` is
//! what the search cost, `search_seconds_unoverlapped` is the part the
//! serving clock actually saw (≈ 0 when every slice hid behind a training
//! step). `LOBRA_BENCH_METER=wall` charges the budget on host wall-clock
//! (the production meter) instead of the deterministic sim meter.
//!
//! `LOBRA_BENCH_BASELINE=path` compares the run's JSON line-by-line
//! against a checked-in baseline (host-wall and async-timing lines are
//! skipped) and exits nonzero on drift; a baseline containing a
//! `"bless": true` line is overwritten in place instead — how the first
//! CI run on a new host locks in real numbers.
//!
//! `LOBRA_BENCH_FLEET=10,100,1000` appends the **fleet-scaling sweep**:
//! each fleet size serves a seeded `gen_churn_trace` twice — globally (1
//! planning shard) and sharded (`LOBRA_BENCH_SHARDS`, default 4) — and the
//! per-event replan search cost (slices and plans enumerated per replan
//! window) goes into the JSON as `fleet_curve`. Sharded localized
//! replanning is the headline: its per-event cost stays flat as the fleet
//! grows, where the global search's grows with every live tenant.
//!
//! `LOBRA_BENCH_AVAIL_TRACE` appends the **availability scenario**: a
//! cluster-churn trace (tenant events mixed with `leave`/`preempt`/`join`
//! lines, grammar v2) replayed through the elastic runtime. `auto`
//! generates a seeded trace with `gen_churn_trace_elastic`; any other
//! value is read as a trace file and validated against the bench fleet.
//! The JSON gains an `avail` block — training throughput across the
//! degraded windows, GPU-seconds charged to interrupted steps, and the
//! time-to-recover curve (seconds from each capacity loss back to a
//! full-capacity plan adoption) — all sim-metered, so the block is
//! baseline-gated like the rest of the file.
//!
//! ```bash
//! cargo bench --bench serve_churn
//! LOBRA_BENCH_GPUS=32 LOBRA_BENCH_BUDGET=60 cargo bench --bench serve_churn
//! LOBRA_BENCH_BUDGET=0 cargo bench --bench serve_churn   # unlimited + certify
//! LOBRA_BENCH_PLANNER_THREADS=2 LOBRA_BENCH_METER=wall \
//!     cargo bench --bench serve_churn                    # overlapped async plan
//! LOBRA_BENCH_FLEET=10,100,1000 cargo bench --bench serve_churn  # fleet scaling
//! LOBRA_BENCH_AVAIL_TRACE=auto cargo bench --bench serve_churn   # elasticity
//! ```


// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::{ClusterSpec, VirtualCluster};
use lobra::config::ModelDesc;
use lobra::coordinator::runtime::{
    default_churn_trace, gen_churn_trace, gen_churn_trace_elastic, parse_trace_for,
    BudgetMeter, ServeOptions, ServeRuntime,
};
use lobra::costmodel::CostModel;
use lobra::prelude::TaskSet;
use lobra::util::bench::{fmt_secs, gate_against_baseline, BaselineGate, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn env_f64(key: &str, default: f64) -> f64 {
    benv::parse_or(key, default)
}

fn main() {
    let gpus: u32 = env_f64("LOBRA_BENCH_GPUS", 16.0) as u32;
    // 0 = unlimited budget (every replan runs to certified completion)
    let budget = env_f64("LOBRA_BENCH_BUDGET", 120.0);
    let spacing = env_f64("LOBRA_BENCH_SPACING", 900.0);
    // 0 = deterministic in-loop sync planning; N > 0 = async planner service
    let planner_threads: usize = benv::parse_or("LOBRA_BENCH_PLANNER_THREADS", 0usize);
    let meter_name = benv::var("LOBRA_BENCH_METER").unwrap_or("sim");
    let json_path = benv::var("LOBRA_BENCH_JSON").unwrap_or("BENCH_serve.json").to_string();
    let baseline_path = benv::var("LOBRA_BENCH_BASELINE");

    let cluster = ClusterSpec::a100_40g(gpus);
    let model = ModelDesc::llama2_7b();
    let cost = CostModel::calibrated(&model, &cluster);
    let pool = TaskSet::paper_7b_subset();
    let trace = default_churn_trace(&pool, spacing);

    let mut opts = ServeOptions::default();
    opts.replan_budget = (budget > 0.0).then_some(budget);
    opts.meter = match meter_name {
        "wall" => BudgetMeter::Wall,
        _ => BudgetMeter::SimPerPlan(1e-4),
    };
    opts.slice_plans = 4096;
    opts.certify_identity = true;
    opts.tail_steps = 8;
    opts.planner_threads = planner_threads;

    println!(
        "== serve churn: {} on {} GPUs, {} events, replan budget {}, {} meter, \
         planner {} ==\n",
        model.name,
        gpus,
        trace.len(),
        if budget > 0.0 { format!("{budget:.0}s") } else { "unlimited".into() },
        meter_name,
        if planner_threads == 0 {
            "sync (in-loop)".into()
        } else {
            format!("async service ({planner_threads} threads)")
        },
    );

    let t0 = Stopwatch::start();
    let mut rt = ServeRuntime::new(&cost, &cluster, opts);
    let report = rt.run_trace(&trace);
    let wall = t0.elapsed_secs();

    let mut t = Table::new(&["tenant", "arrived", "admitted", "tta", "steps", "exited"]);
    for ten in &report.tenants {
        t.row(&[
            ten.name.clone(),
            format!("{:.0}s", ten.arrived_at),
            ten.admitted_at.map_or("-".into(), |a| format!("{a:.0}s")),
            ten.time_to_admission().map_or("-".into(), |d| format!("{d:.1}s")),
            ten.steps_trained.to_string(),
            ten.exited_at.map_or("-".into(), |e| format!("{e:.0}s")),
        ]);
    }
    t.print();

    let min_window_steps = report.min_steps_in_replan_window.unwrap_or(0);
    let mean_tta = report.mean_time_to_admission().unwrap_or(0.0);
    println!(
        "\nsim horizon {:.0}s | {} steps, {} during replan windows (min {} per \
         overlapped window) | {} windows, {} redeploys, {} identical swaps, {} \
         budget-exhausted",
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
    );
    println!(
        "GPU-seconds: {:.1} trained, {:.1} lost to redeploys | mean TTA {mean_tta:.1}s \
         | identity {}/{} | host wall {}",
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks - report.identity_failures,
        report.identity_checks,
        fmt_secs(wall),
    );
    let no_stop_the_world =
        report.min_steps_in_replan_window.map_or(false, |m| m >= 1);
    println!(
        "no stop-the-world (>=1 step in every overlapped replan window): {}",
        if no_stop_the_world { "yes" } else { "NO — BUG" }
    );
    // The overlap split: total is what the search cost, unoverlapped is
    // the part the serving clock was exposed to. With the async service
    // the unoverlapped share collapses toward zero — that is the entire
    // point of planning off-thread.
    let overlapped = report.search_seconds_total - report.search_seconds_unoverlapped;
    println!(
        "search time: {:.3}s total = {:.3}s overlapped with training + {:.3}s \
         unoverlapped (exposed on the serving clock)",
        report.search_seconds_total,
        overlapped.max(0.0),
        report.search_seconds_unoverlapped,
    );

    // --- availability scenario (opt-in): cluster churn elasticity ---
    let avail_json = match benv::var("LOBRA_BENCH_AVAIL_TRACE") {
        Some(spec) => avail_scenario(&model, gpus, spec),
        None => String::new(),
    };

    // --- fleet-scaling sweep (opt-in): replan search cost vs fleet size ---
    let fleet_json = match benv::var("LOBRA_BENCH_FLEET") {
        Some(spec) => {
            let fleets: Vec<usize> =
                spec.split(',').filter_map(|s| s.trim().parse().ok()).collect();
            let shards: usize = benv::parse_or("LOBRA_BENCH_SHARDS", 4usize).max(2);
            let entries = fleet_sweep(&model, &fleets, shards);
            format!(",\n  \"fleet_curve\": [\n    {}\n  ]", entries.join(",\n    "))
        }
        None => String::new(),
    };

    let tenants_json = report
        .tenants
        .iter()
        .map(|ten| {
            format!(
                "{{\"name\": \"{}\", \"tta_seconds\": {}, \"steps\": {}}}",
                ten.name,
                ten.time_to_admission()
                    .map_or("null".into(), |d| format!("{d:.3}")),
                ten.steps_trained
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    let json = format!(
        "{{\n  \"bench\": \"serve_churn\",\n  \"gpus\": {gpus},\n  \
         \"replan_budget_seconds\": {budget},\n  \"planner_threads\": {planner_threads},\n  \
         \"meter\": \"{meter_name}\",\n  \"events\": {},\n  \
         \"sim_seconds\": {:.3},\n  \"steps_total\": {},\n  \
         \"steps_during_replan\": {},\n  \"min_steps_in_replan_window\": {},\n  \
         \"replan_windows\": {},\n  \"redeploys\": {},\n  \
         \"plan_swaps_identical\": {},\n  \"budget_exhausted\": {},\n  \
         \"gpu_seconds_trained\": {:.3},\n  \"gpu_seconds_lost_redeploy\": {:.3},\n  \
         \"mean_tta_seconds\": {mean_tta:.3},\n  \"identity_checks\": {},\n  \
         \"identity_failures\": {},\n  \"no_stop_the_world\": {no_stop_the_world},\n  \
         \"search_seconds_total\": {:.3},\n  \
         \"search_seconds_unoverlapped\": {:.3},\n  \
         \"host_wall_seconds\": {wall:.3},\n  \"tenants\": [\n    {tenants_json}\n  ]{avail_json}{fleet_json}\n}}\n",
        trace.len(),
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks,
        report.identity_failures,
        report.search_seconds_total,
        report.search_seconds_unoverlapped,
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nserving metrics recorded to {json_path}"),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }

    if let Some(baseline) = baseline_path {
        render_gate(baseline, &json);
    }
}

/// Lines whose values depend on host speed or async slice timing — skipped
/// by the baseline diff so the deterministic metrics are what's locked.
/// (`fleet_curve` entries embed their host wall on the same line, so the
/// opt-in fleet sweep is informational, not baseline-gated.)
fn host_dependent(line: &str) -> bool {
    line.contains("host_wall") || line.contains("search_seconds")
}

/// Render the shared baseline gate's outcome; exits nonzero on drift so CI
/// fails loudly instead of shipping silently different serving metrics.
fn render_gate(path: &str, current: &str) {
    match gate_against_baseline(path, current, &host_dependent) {
        BaselineGate::Blessed => println!("baseline {path} blessed from this run"),
        BaselineGate::Ok(n) => println!("baseline {path}: OK ({n} deterministic lines)"),
        BaselineGate::Unreadable(e) => {
            eprintln!("ERROR: baseline {path} unreadable: {e}");
            std::process::exit(1);
        }
        BaselineGate::WriteFailed(e) => {
            eprintln!("ERROR: blessing baseline {path}: {e}");
            std::process::exit(1);
        }
        BaselineGate::Drift(diff) => {
            eprintln!("ERROR: serving metrics drifted from baseline {path}:");
            for (w, g) in diff {
                eprintln!("  - {w}");
                eprintln!("  + {g}");
            }
            std::process::exit(1);
        }
    }
}

/// The availability scenario: replay a cluster-churn trace (tenant
/// arrivals/exits mixed with node leaves, GPU-range preemptions, and
/// restoring joins) through the elastic runtime and report the
/// elasticity headline — throughput across the degraded windows,
/// GPU-seconds charged to steps the preemption interrupted, and the
/// time-to-recover curve. `spec` is either `auto` (seeded
/// `gen_churn_trace_elastic` on the bench fleet) or a grammar-v2 trace
/// file validated against that fleet. Sim-metered, so every emitted
/// metric is host-independent and baseline-gated; only the wall line is
/// skipped by the gate.
fn avail_scenario(model: &ModelDesc, gpus: u32, spec: &str) -> String {
    let cluster = ClusterSpec::a100_40g(gpus);
    let fleet = VirtualCluster::homogeneous(cluster.clone());
    let cost = CostModel::calibrated(model, &cluster);
    let trace = if spec == "auto" {
        gen_churn_trace_elastic(8, 17, &fleet, 0.5, 0.5)
    } else {
        let text = match std::fs::read_to_string(spec) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("ERROR: availability trace {spec} unreadable: {e}");
                std::process::exit(1);
            }
        };
        match parse_trace_for(&text, &fleet) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("ERROR: availability trace {spec}: {e}");
                std::process::exit(1);
            }
        }
    };
    let cluster_events = trace.iter().filter(|e| e.event.is_cluster()).count();

    let mut o = ServeOptions::default();
    o.replan_budget = Some(30.0);
    o.meter = BudgetMeter::SimPerPlan(1e-4);
    o.slice_plans = 4096;
    o.certify_identity = false;
    o.tail_steps = 4;
    o.planner.calibration_multiple = 10;
    o.planner.eval_batches = 1;
    o.planner.max_evaluated = 32;
    o.planner.max_plans = 50_000;
    let t0 = Stopwatch::start();
    let report = ServeRuntime::new(&cost, &cluster, o).run_trace(&trace);
    let wall = t0.elapsed_secs();

    println!(
        "\n== availability ({spec}): {} events ({cluster_events} cluster) on {gpus} \
         GPUs ==\n",
        trace.len(),
    );
    let throughput = if report.sim_seconds > 0.0 {
        report.gpu_seconds_trained / report.sim_seconds
    } else {
        0.0
    };
    let mean_ttr = if report.recoveries.is_empty() {
        None
    } else {
        // lint:allow(R5): fixed-order sum over the recovery episodes
        Some(report.recoveries.iter().sum::<f64>() / report.recoveries.len() as f64)
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["leaves / preempts / joins".into(),
        format!("{} / {} / {}", report.leave_events, report.preempt_events,
            report.join_events)]);
    t.row(&["GPU-seconds trained".into(),
        format!("{:.1}", report.gpu_seconds_trained)]);
    t.row(&["GPU-seconds lost (interrupted steps)".into(),
        format!("{:.1}", report.gpu_seconds_lost_preempt)]);
    t.row(&["GPU-seconds lost (redeploys)".into(),
        format!("{:.1}", report.gpu_seconds_lost_redeploy)]);
    t.row(&["throughput (GPU-s trained / sim-s)".into(),
        format!("{throughput:.3}")]);
    t.row(&["recoveries".into(),
        format!("{:?}", report.recoveries.iter().map(|r| (r * 10.0).round() / 10.0)
            .collect::<Vec<_>>())]);
    t.row(&["mean time-to-recover".into(),
        mean_ttr.map_or("-".into(), |m| format!("{m:.1}s"))]);
    t.print();

    let recoveries = report
        .recoveries
        .iter()
        .map(|r| format!("{r:.3}"))
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        ",\n  \"avail\": {{\n    \"source\": \"{spec}\",\n    \"events\": {},\n    \
         \"cluster_events\": {cluster_events},\n    \"leaves\": {},\n    \
         \"preempts\": {},\n    \"joins\": {},\n    \"steps_total\": {},\n    \
         \"redeploys\": {},\n    \"gpu_seconds_trained\": {:.3},\n    \
         \"gpu_seconds_lost_preempt\": {:.3},\n    \
         \"gpu_seconds_lost_redeploy\": {:.3},\n    \
         \"throughput_gpu_seconds_per_sim_second\": {throughput:.4},\n    \
         \"recoveries_seconds\": [{recoveries}],\n    \
         \"mean_time_to_recover_seconds\": {},\n    \
         \"avail_host_wall_seconds\": {wall:.3}\n  }}",
        trace.len(),
        report.leave_events,
        report.preempt_events,
        report.join_events,
        report.steps_total,
        report.redeploys,
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_preempt,
        report.gpu_seconds_lost_redeploy,
        mean_ttr.map_or("null".into(), |m| format!("{m:.3}")),
    )
}

/// The fleet-scaling sweep: serve `gen_churn_trace(fleet, 17)` once
/// globally (1 planning shard) and once sharded, on a cluster scaled to
/// the fleet, and report the per-replan-window search cost. Budgets are
/// sim-metered so the cost columns reproduce across hosts; the planner is
/// trimmed because the sweep measures search *growth*, not plan quality.
fn fleet_sweep(model: &ModelDesc, fleets: &[usize], shards: usize) -> Vec<String> {
    println!(
        "\n== fleet scaling: per-event replan search cost, global vs {shards} shards ==\n"
    );
    let mut t = Table::new(&[
        "fleet", "gpus", "mode", "events", "windows", "slices/replan",
        "plans/replan", "queued", "preempt", "rejected", "host wall",
    ]);
    let mut entries = Vec::new();
    for &fleet in fleets {
        let gpus: u32 = if fleet <= 10 {
            16
        } else if fleet <= 100 {
            32
        } else {
            64
        };
        let cluster = ClusterSpec::a100_40g(gpus);
        let cost = CostModel::calibrated(model, &cluster);
        let trace = gen_churn_trace(fleet, 17);
        for (mode, n_shards) in [("global", 1usize), ("sharded", shards)] {
            let mut o = ServeOptions::default();
            o.replan_budget = Some(30.0);
            o.meter = BudgetMeter::SimPerPlan(1e-4);
            o.slice_plans = 4096;
            o.certify_identity = false;
            o.tail_steps = 2;
            o.shards = n_shards;
            o.rebalance_every = if n_shards > 1 { 64 } else { 0 };
            o.planner.calibration_multiple = 10;
            o.planner.eval_batches = 1;
            o.planner.max_evaluated = 32;
            o.planner.max_plans = 50_000;
            let t0 = Stopwatch::start();
            let report = ServeRuntime::new(&cost, &cluster, o).run_trace(&trace);
            let wall = t0.elapsed_secs();
            let windows = f64::from(report.replan_windows.max(1));
            let slices_per = report.replan_slices_total as f64 / windows;
            let plans_per = report.plans_enumerated_total as f64 / windows;
            t.row(&[
                fleet.to_string(),
                gpus.to_string(),
                format!("{mode} ({n_shards})"),
                trace.len().to_string(),
                report.replan_windows.to_string(),
                format!("{slices_per:.2}"),
                format!("{plans_per:.1}"),
                report.queued_admissions.to_string(),
                report.preemptions.to_string(),
                report.rejected_arrivals.to_string(),
                fmt_secs(wall),
            ]);
            entries.push(format!(
                "{{\"fleet\": {fleet}, \"gpus\": {gpus}, \"mode\": \"{mode}\", \
                 \"shards\": {n_shards}, \"events\": {}, \"replan_windows\": {}, \
                 \"slices_per_replan\": {slices_per:.2}, \"plans_per_replan\": {plans_per:.1}, \
                 \"queued\": {}, \"preemptions\": {}, \"rebalances\": {}, \"rejected\": {}, \
                 \"mean_tta_seconds\": {}, \"jain\": {}, \"host_wall_seconds\": {wall:.3}}}",
                trace.len(),
                report.replan_windows,
                report.queued_admissions,
                report.preemptions,
                report.rebalances,
                report.rejected_arrivals,
                report
                    .mean_time_to_admission()
                    .map_or("null".into(), |d| format!("{d:.1}")),
                report
                    .jain_fairness()
                    .map_or("null".into(), |j| format!("{j:.4}")),
            ));
        }
    }
    t.print();
    entries
}
