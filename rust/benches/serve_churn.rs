//! Churn-trace serving scenario (ISSUE 5): the event-driven runtime
//! replays tenant arrivals/exits while training overlaps a budgeted
//! anytime replan, and reports tenant-observed serving metrics —
//! time-to-admission, steps trained during replan windows (the
//! no-stop-the-world proof), and GPU-seconds lost to redeploys (charged
//! only for replica groups that actually changed).
//!
//! The budget is metered on a deterministic sim clock (seconds per
//! enumerated plan), so the scenario reproduces bit-for-bit across hosts;
//! host wall-clocks are recorded alongside. Results go to
//! `BENCH_serve.json` (override: `LOBRA_BENCH_JSON`).
//!
//! ```bash
//! cargo bench --bench serve_churn
//! LOBRA_BENCH_GPUS=32 LOBRA_BENCH_BUDGET=60 cargo bench --bench serve_churn
//! LOBRA_BENCH_BUDGET=0 cargo bench --bench serve_churn   # unlimited + certify
//! ```


// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::runtime::{
    default_churn_trace, BudgetMeter, ServeOptions, ServeRuntime,
};
use lobra::costmodel::CostModel;
use lobra::prelude::TaskSet;
use lobra::util::bench::{fmt_secs, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn env_f64(key: &str, default: f64) -> f64 {
    benv::parse_or(key, default)
}

fn main() {
    let gpus: u32 = env_f64("LOBRA_BENCH_GPUS", 16.0) as u32;
    // 0 = unlimited budget (every replan runs to certified completion)
    let budget = env_f64("LOBRA_BENCH_BUDGET", 120.0);
    let spacing = env_f64("LOBRA_BENCH_SPACING", 900.0);
    let json_path = benv::var("LOBRA_BENCH_JSON").unwrap_or("BENCH_serve.json").to_string();

    let cluster = ClusterSpec::a100_40g(gpus);
    let model = ModelDesc::llama2_7b();
    let cost = CostModel::calibrated(&model, &cluster);
    let pool = TaskSet::paper_7b_subset();
    let trace = default_churn_trace(&pool, spacing);

    let mut opts = ServeOptions::default();
    opts.replan_budget = (budget > 0.0).then_some(budget);
    opts.meter = BudgetMeter::SimPerPlan(1e-4);
    opts.slice_plans = 4096;
    opts.certify_identity = true;
    opts.tail_steps = 8;

    println!(
        "== serve churn: {} on {} GPUs, {} events, replan budget {} ==\n",
        model.name,
        gpus,
        trace.len(),
        if budget > 0.0 { format!("{budget:.0}s") } else { "unlimited".into() },
    );

    let t0 = Stopwatch::start();
    let mut rt = ServeRuntime::new(&cost, &cluster, opts);
    let report = rt.run_trace(&trace);
    let wall = t0.elapsed_secs();

    let mut t = Table::new(&["tenant", "arrived", "admitted", "tta", "steps", "exited"]);
    for ten in &report.tenants {
        t.row(&[
            ten.name.clone(),
            format!("{:.0}s", ten.arrived_at),
            ten.admitted_at.map_or("-".into(), |a| format!("{a:.0}s")),
            ten.time_to_admission().map_or("-".into(), |d| format!("{d:.1}s")),
            ten.steps_trained.to_string(),
            ten.exited_at.map_or("-".into(), |e| format!("{e:.0}s")),
        ]);
    }
    t.print();

    let min_window_steps = report.min_steps_in_replan_window.unwrap_or(0);
    let mean_tta = report.mean_time_to_admission().unwrap_or(0.0);
    println!(
        "\nsim horizon {:.0}s | {} steps, {} during replan windows (min {} per \
         overlapped window) | {} windows, {} redeploys, {} identical swaps, {} \
         budget-exhausted",
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
    );
    println!(
        "GPU-seconds: {:.1} trained, {:.1} lost to redeploys | mean TTA {mean_tta:.1}s \
         | identity {}/{} | host wall {}",
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks - report.identity_failures,
        report.identity_checks,
        fmt_secs(wall),
    );
    let no_stop_the_world =
        report.min_steps_in_replan_window.map_or(false, |m| m >= 1);
    println!(
        "no stop-the-world (>=1 step in every overlapped replan window): {}",
        if no_stop_the_world { "yes" } else { "NO — BUG" }
    );

    let tenants_json = report
        .tenants
        .iter()
        .map(|ten| {
            format!(
                "{{\"name\": \"{}\", \"tta_seconds\": {}, \"steps\": {}}}",
                ten.name,
                ten.time_to_admission()
                    .map_or("null".into(), |d| format!("{d:.3}")),
                ten.steps_trained
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    let json = format!(
        "{{\n  \"bench\": \"serve_churn\",\n  \"gpus\": {gpus},\n  \
         \"replan_budget_seconds\": {budget},\n  \"events\": {},\n  \
         \"sim_seconds\": {:.3},\n  \"steps_total\": {},\n  \
         \"steps_during_replan\": {},\n  \"min_steps_in_replan_window\": {},\n  \
         \"replan_windows\": {},\n  \"redeploys\": {},\n  \
         \"plan_swaps_identical\": {},\n  \"budget_exhausted\": {},\n  \
         \"gpu_seconds_trained\": {:.3},\n  \"gpu_seconds_lost_redeploy\": {:.3},\n  \
         \"mean_tta_seconds\": {mean_tta:.3},\n  \"identity_checks\": {},\n  \
         \"identity_failures\": {},\n  \"no_stop_the_world\": {no_stop_the_world},\n  \
         \"host_wall_seconds\": {wall:.3},\n  \"tenants\": [\n    {tenants_json}\n  ]\n}}\n",
        trace.len(),
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks,
        report.identity_failures,
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nserving metrics recorded to {json_path}"),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }
}
