//! Churn-trace serving scenario (ISSUE 5): the event-driven runtime
//! replays tenant arrivals/exits while training overlaps a budgeted
//! anytime replan, and reports tenant-observed serving metrics —
//! time-to-admission, steps trained during replan windows (the
//! no-stop-the-world proof), and GPU-seconds lost to redeploys (charged
//! only for replica groups that actually changed).
//!
//! The budget is metered on a deterministic sim clock (seconds per
//! enumerated plan), so the scenario reproduces bit-for-bit across hosts;
//! host wall-clocks are recorded alongside. Results go to
//! `BENCH_serve.json` (override: `LOBRA_BENCH_JSON`).
//!
//! With `LOBRA_BENCH_PLANNER_THREADS=N` the runtime plans through the
//! async [`coordinator::service`] instead of the in-loop sync path, and
//! the search-time split shows the overlap win: `search_seconds_total` is
//! what the search cost, `search_seconds_unoverlapped` is the part the
//! serving clock actually saw (≈ 0 when every slice hid behind a training
//! step). `LOBRA_BENCH_METER=wall` charges the budget on host wall-clock
//! (the production meter) instead of the deterministic sim meter.
//!
//! `LOBRA_BENCH_BASELINE=path` compares the run's JSON line-by-line
//! against a checked-in baseline (host-wall and async-timing lines are
//! skipped) and exits nonzero on drift; a baseline containing a
//! `"bless": true` line is overwritten in place instead — how the first
//! CI run on a new host locks in real numbers.
//!
//! ```bash
//! cargo bench --bench serve_churn
//! LOBRA_BENCH_GPUS=32 LOBRA_BENCH_BUDGET=60 cargo bench --bench serve_churn
//! LOBRA_BENCH_BUDGET=0 cargo bench --bench serve_churn   # unlimited + certify
//! LOBRA_BENCH_PLANNER_THREADS=2 LOBRA_BENCH_METER=wall \
//!     cargo bench --bench serve_churn                    # overlapped async plan
//! ```


// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::runtime::{
    default_churn_trace, BudgetMeter, ServeOptions, ServeRuntime,
};
use lobra::costmodel::CostModel;
use lobra::prelude::TaskSet;
use lobra::util::bench::{fmt_secs, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn env_f64(key: &str, default: f64) -> f64 {
    benv::parse_or(key, default)
}

fn main() {
    let gpus: u32 = env_f64("LOBRA_BENCH_GPUS", 16.0) as u32;
    // 0 = unlimited budget (every replan runs to certified completion)
    let budget = env_f64("LOBRA_BENCH_BUDGET", 120.0);
    let spacing = env_f64("LOBRA_BENCH_SPACING", 900.0);
    // 0 = deterministic in-loop sync planning; N > 0 = async planner service
    let planner_threads: usize = benv::parse_or("LOBRA_BENCH_PLANNER_THREADS", 0usize);
    let meter_name = benv::var("LOBRA_BENCH_METER").unwrap_or("sim");
    let json_path = benv::var("LOBRA_BENCH_JSON").unwrap_or("BENCH_serve.json").to_string();
    let baseline_path = benv::var("LOBRA_BENCH_BASELINE");

    let cluster = ClusterSpec::a100_40g(gpus);
    let model = ModelDesc::llama2_7b();
    let cost = CostModel::calibrated(&model, &cluster);
    let pool = TaskSet::paper_7b_subset();
    let trace = default_churn_trace(&pool, spacing);

    let mut opts = ServeOptions::default();
    opts.replan_budget = (budget > 0.0).then_some(budget);
    opts.meter = match meter_name {
        "wall" => BudgetMeter::Wall,
        _ => BudgetMeter::SimPerPlan(1e-4),
    };
    opts.slice_plans = 4096;
    opts.certify_identity = true;
    opts.tail_steps = 8;
    opts.planner_threads = planner_threads;

    println!(
        "== serve churn: {} on {} GPUs, {} events, replan budget {}, {} meter, \
         planner {} ==\n",
        model.name,
        gpus,
        trace.len(),
        if budget > 0.0 { format!("{budget:.0}s") } else { "unlimited".into() },
        meter_name,
        if planner_threads == 0 {
            "sync (in-loop)".into()
        } else {
            format!("async service ({planner_threads} threads)")
        },
    );

    let t0 = Stopwatch::start();
    let mut rt = ServeRuntime::new(&cost, &cluster, opts);
    let report = rt.run_trace(&trace);
    let wall = t0.elapsed_secs();

    let mut t = Table::new(&["tenant", "arrived", "admitted", "tta", "steps", "exited"]);
    for ten in &report.tenants {
        t.row(&[
            ten.name.clone(),
            format!("{:.0}s", ten.arrived_at),
            ten.admitted_at.map_or("-".into(), |a| format!("{a:.0}s")),
            ten.time_to_admission().map_or("-".into(), |d| format!("{d:.1}s")),
            ten.steps_trained.to_string(),
            ten.exited_at.map_or("-".into(), |e| format!("{e:.0}s")),
        ]);
    }
    t.print();

    let min_window_steps = report.min_steps_in_replan_window.unwrap_or(0);
    let mean_tta = report.mean_time_to_admission().unwrap_or(0.0);
    println!(
        "\nsim horizon {:.0}s | {} steps, {} during replan windows (min {} per \
         overlapped window) | {} windows, {} redeploys, {} identical swaps, {} \
         budget-exhausted",
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
    );
    println!(
        "GPU-seconds: {:.1} trained, {:.1} lost to redeploys | mean TTA {mean_tta:.1}s \
         | identity {}/{} | host wall {}",
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks - report.identity_failures,
        report.identity_checks,
        fmt_secs(wall),
    );
    let no_stop_the_world =
        report.min_steps_in_replan_window.map_or(false, |m| m >= 1);
    println!(
        "no stop-the-world (>=1 step in every overlapped replan window): {}",
        if no_stop_the_world { "yes" } else { "NO — BUG" }
    );
    // The overlap split: total is what the search cost, unoverlapped is
    // the part the serving clock was exposed to. With the async service
    // the unoverlapped share collapses toward zero — that is the entire
    // point of planning off-thread.
    let overlapped = report.search_seconds_total - report.search_seconds_unoverlapped;
    println!(
        "search time: {:.3}s total = {:.3}s overlapped with training + {:.3}s \
         unoverlapped (exposed on the serving clock)",
        report.search_seconds_total,
        overlapped.max(0.0),
        report.search_seconds_unoverlapped,
    );

    let tenants_json = report
        .tenants
        .iter()
        .map(|ten| {
            format!(
                "{{\"name\": \"{}\", \"tta_seconds\": {}, \"steps\": {}}}",
                ten.name,
                ten.time_to_admission()
                    .map_or("null".into(), |d| format!("{d:.3}")),
                ten.steps_trained
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    let json = format!(
        "{{\n  \"bench\": \"serve_churn\",\n  \"gpus\": {gpus},\n  \
         \"replan_budget_seconds\": {budget},\n  \"planner_threads\": {planner_threads},\n  \
         \"meter\": \"{meter_name}\",\n  \"events\": {},\n  \
         \"sim_seconds\": {:.3},\n  \"steps_total\": {},\n  \
         \"steps_during_replan\": {},\n  \"min_steps_in_replan_window\": {},\n  \
         \"replan_windows\": {},\n  \"redeploys\": {},\n  \
         \"plan_swaps_identical\": {},\n  \"budget_exhausted\": {},\n  \
         \"gpu_seconds_trained\": {:.3},\n  \"gpu_seconds_lost_redeploy\": {:.3},\n  \
         \"mean_tta_seconds\": {mean_tta:.3},\n  \"identity_checks\": {},\n  \
         \"identity_failures\": {},\n  \"no_stop_the_world\": {no_stop_the_world},\n  \
         \"search_seconds_total\": {:.3},\n  \
         \"search_seconds_unoverlapped\": {:.3},\n  \
         \"host_wall_seconds\": {wall:.3},\n  \"tenants\": [\n    {tenants_json}\n  ]\n}}\n",
        trace.len(),
        report.sim_seconds,
        report.steps_total,
        report.steps_during_replan,
        min_window_steps,
        report.replan_windows,
        report.redeploys,
        report.plan_swaps_identical,
        report.budget_exhausted,
        report.gpu_seconds_trained,
        report.gpu_seconds_lost_redeploy,
        report.identity_checks,
        report.identity_failures,
        report.search_seconds_total,
        report.search_seconds_unoverlapped,
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nserving metrics recorded to {json_path}"),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }

    if let Some(baseline) = baseline_path {
        compare_against_baseline(baseline, &json);
    }
}

/// Lines whose values depend on host speed or async slice timing — skipped
/// by the baseline diff so the deterministic metrics are what's locked.
fn host_dependent(line: &str) -> bool {
    line.contains("host_wall") || line.contains("search_seconds")
}

/// Gate the deterministic serving metrics against a checked-in baseline.
///
/// The committed baseline may hold `"bless": true` instead of numbers: the
/// bench then rewrites it with this run's JSON (minus the sentinel) and
/// succeeds, so a toolchain-less commit can still check in the file and
/// the first CI run locks in real values. Any later drift on a
/// non-host-dependent line fails the run with a line diff.
fn compare_against_baseline(path: &str, current: &str) {
    let baseline = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("ERROR: baseline {path} unreadable: {e}");
            std::process::exit(1);
        }
    };
    if baseline.lines().any(|l| l.contains("\"bless\": true")) {
        if let Err(e) = std::fs::write(path, current) {
            eprintln!("ERROR: blessing baseline {path}: {e}");
            std::process::exit(1);
        }
        println!("baseline {path} blessed from this run");
        return;
    }
    let want: Vec<&str> = baseline.lines().filter(|l| !host_dependent(l)).collect();
    let got: Vec<&str> = current.lines().filter(|l| !host_dependent(l)).collect();
    if want == got {
        println!("baseline {path}: OK ({} deterministic lines)", got.len());
        return;
    }
    eprintln!("ERROR: serving metrics drifted from baseline {path}:");
    for i in 0..want.len().max(got.len()) {
        let w = want.get(i).copied().unwrap_or("<missing>");
        let g = got.get(i).copied().unwrap_or("<missing>");
        if w != g {
            eprintln!("  - {w}");
            eprintln!("  + {g}");
        }
    }
    std::process::exit(1);
}
