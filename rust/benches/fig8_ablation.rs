//! Figure 8 ablation (7B model, 16 A100-40GB GPUs): starting from the
//! naïvely fused baseline, enable LobRA's techniques one at a time —
//!
//!   base : homogeneous replicas, fixed bucketing       (Task-Fused)
//!   +H   : heterogeneous replicas, length-based dispatch
//!   +W   : + workload-balanced dispatching
//!   +D   : + dynamic bucketing                         (full LobRA)
//!
//! Paper: reductions of 18.94% → 36.65% → 45.03% vs base.
//!
//! ```bash
//! cargo bench --bench fig8_ablation
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::coordinator::dispatcher::DispatchPolicy;
use lobra::coordinator::planner::Planner;
use lobra::experiments::{Arm, Scenario};
use lobra::util::bench::Table;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 100);
    let sc = Scenario::paper_7b_16();
    println!("== Figure 8: ablation, {} ({steps} steps/arm) ==\n", sc.label);

    // base: Task-Fused (homogeneous + fixed bucketing + balanced-within-homog)
    let base = sc.arm_report(Arm::TaskFused, steps).unwrap();
    let base_gs = base.report.gpu_seconds_per_step;

    // heterogeneous plans: the +H arm plans self-consistently for
    // length-based dispatch; the balanced arms use the LobRA plan.
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    let mut lb_opts = sc.planner_opts();
    lb_opts.inner_policy = DispatchPolicy::LengthBased;
    let plan_lb = planner.plan(&sc.tasks, lb_opts).unwrap_or_else(|| plan.clone());

    let arms: [(&str, &lobra::coordinator::planner::DeploymentPlan, DispatchPolicy, bool); 3] = [
        ("+ heterogeneous replicas (length-based)", &plan_lb, DispatchPolicy::LengthBased, false),
        ("+ workload-balanced dispatching", &plan, DispatchPolicy::Balanced, false),
        ("+ dynamic bucketing (LobRA)", &plan, DispatchPolicy::Balanced, true),
    ];

    let mut t = Table::new(&["arm", "GPU·s/step", "util", "pad", "reduction vs base"]);
    t.row(&[
        format!("naively fused [{}]", base.plan.as_ref().unwrap().notation()),
        format!("{base_gs:.2}"),
        format!("{:.1}%", base.report.utilization * 100.0),
        format!("{:.1}%", base.report.mean_padding_ratio * 100.0),
        "—".into(),
    ]);
    for (label, arm_plan, policy, dynb) in arms {
        let rep = sc.custom_report(arm_plan, policy, dynb, steps);
        t.row(&[
            label.to_string(),
            format!("{:.2}", rep.gpu_seconds_per_step),
            format!("{:.1}%", rep.utilization * 100.0),
            format!("{:.1}%", rep.mean_padding_ratio * 100.0),
            format!("-{:.2}%", (1.0 - rep.gpu_seconds_per_step / base_gs) * 100.0),
        ]);
    }
    t.print();
    println!("\nlength-based-planned: [{}]", plan_lb.notation());
    println!("balanced-planned (LobRA): [{}]", plan.notation());
}
