//! Figure 11: scalability of LobRA vs Task-Fused (70B model).
//!
//! Left: GPU seconds of 4-task joint FT over {16, 32, 64} GPUs — at 16
//! GPUs both can only deploy ⟨16,1⟩×1 and tie; with more GPUs LobRA's
//! heterogeneous plans pull ahead while Task-Fused degrades slightly from
//! sync overhead.
//!
//! Right: GPU seconds over {4, 8, 12, 16} tasks at 64 GPUs — near-linear
//! growth for both, LobRA consistently lower.
//!
//! ```bash
//! cargo bench --bench fig11_scalability
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::experiments::{Arm, Scenario};
use lobra::prelude::TaskSet;
use lobra::util::bench::Table;
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 50);
    // the streaming planner keeps 128-GPU planning survivor-bounded; opt in
    // with LOBRA_BENCH_MAX_GPUS=128 (the default stops at the paper's 64)
    let max_gpus: u32 = benv::parse_or("LOBRA_BENCH_MAX_GPUS", 64);
    // opt-in wall-clock recording (the CI scalability job sets this)
    let json_path = benv::var("LOBRA_BENCH_JSON").map(str::to_string);

    println!("== Figure 11 (left): GPU scalability, 70B, 4 tasks ({steps} steps) ==\n");
    let mut t = Table::new(&[
        "GPUs", "Task-Fused GPU·s", "LobRA GPU·s", "reduction", "fused plan", "lobra plan",
    ]);
    let mut wall_rows: Vec<String> = Vec::new();
    for gpus in [16u32, 32, 64, 128].into_iter().filter(|&g| g <= max_gpus) {
        let sc = Scenario::new(
            &format!("70B/{gpus}"),
            ModelDesc::llama2_70b(),
            ClusterSpec::a800_80g(gpus),
            TaskSet::paper_scalability_subset(),
        );
        let t_fused = Stopwatch::start();
        let fused = sc.arm_report(Arm::TaskFused, steps).unwrap();
        let fused_wall = t_fused.elapsed_secs();
        let t_lobra = Stopwatch::start();
        let lobra = sc.arm_report(Arm::Lobra, steps).unwrap();
        let lobra_wall = t_lobra.elapsed_secs();
        let fg = fused.report.gpu_seconds_per_step;
        let lg = lobra.report.gpu_seconds_per_step;
        t.row(&[
            gpus.to_string(),
            format!("{fg:.1}"),
            format!("{lg:.1}"),
            format!("-{:.1}%", (1.0 - lg / fg) * 100.0),
            fused.plan.as_ref().unwrap().notation(),
            lobra.plan.as_ref().unwrap().notation(),
        ]);
        wall_rows.push(format!(
            "    {{\"gpus\": {gpus}, \"steps\": {steps}, \
             \"task_fused_wall_seconds\": {fused_wall:.3}, \
             \"lobra_wall_seconds\": {lobra_wall:.3}, \
             \"task_fused_gpu_seconds\": {fg:.3}, \"lobra_gpu_seconds\": {lg:.3}}}"
        ));
    }
    t.print();
    if let Some(path) = &json_path {
        let json = format!(
            "{{\n  \"bench\": \"fig11_scalability\",\n  \"max_gpus\": {max_gpus},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            wall_rows.join(",\n")
        );
        match std::fs::write(path, &json) {
            Ok(()) => println!("\nwall-clocks recorded to {path}"),
            Err(e) => eprintln!("\nWARNING: could not write {path}: {e}"),
        }
    }

    println!("\n== Figure 11 (right): task scalability, 70B, 64 GPUs ({steps} steps) ==\n");
    let mut t2 = Table::new(&["tasks", "Task-Fused GPU·s", "LobRA GPU·s", "reduction"]);
    for n_tasks in [4usize, 8, 12, 16] {
        let sc = Scenario::new(
            &format!("70B/64/{n_tasks}t"),
            ModelDesc::llama2_70b(),
            ClusterSpec::a800_80g(64),
            TaskSet::paper_first_n(n_tasks),
        );
        let fused = sc.arm_report(Arm::TaskFused, steps).unwrap();
        let lobra = sc.arm_report(Arm::Lobra, steps).unwrap();
        let fg = fused.report.gpu_seconds_per_step;
        let lg = lobra.report.gpu_seconds_per_step;
        t2.row(&[
            n_tasks.to_string(),
            format!("{fg:.1}"),
            format!("{lg:.1}"),
            format!("-{:.1}%", (1.0 - lg / fg) * 100.0),
        ]);
    }
    t2.print();
}
