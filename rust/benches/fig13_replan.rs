//! Figure 13 (ours): cold vs warm replanning latency over a tenant churn
//! trace (paper §5.1: task arrival/exit forces a replan; the "< 3 minutes"
//! adjustment budget is dominated by re-running the plan search).
//!
//! The trace slides a window of concurrent tasks over the paper's dataset
//! pool, so task sets leave and return — the regime a multi-tenant
//! deployment actually sees. Every event is replanned twice:
//!
//!  * **cold** — a fresh `Planner::plan` (the pre-session behaviour);
//!  * **warm** — through one persistent `PlanningSession`, which re-scores
//!    the previous survivor set to seed the search incumbent and draws its
//!    cost table from the shared LRU.
//!
//! Warm replans are verified plan-identical (bit-identical expected step
//! time) to cold ones on every event; the wall-clock totals and speedup
//! are written to `BENCH_fig13.json` (path override: `LOBRA_BENCH_JSON`).
//!
//! After the churn trace, the bench sweeps the **anytime replan budget**:
//! one budget-sliced search (`LOBRA_BENCH_SLICE` plans per slice) over the
//! final task set records the best-so-far objective after every slice —
//! the plan-quality-vs-budget curve a serving deployment trades on — and
//! certifies the fully-pumped plan identical to a cold one. The curve is
//! written into `BENCH_fig13.json` as `budget_curve`.
//!
//! `LOBRA_BENCH_BASELINE=path` gates the run's JSON against a checked-in
//! baseline (timing and speedup lines are host-dependent and skipped; the
//! identity bits, start/hit counters, and event counts are what's locked)
//! and exits nonzero on drift. A baseline holding a `"bless": true` line
//! is overwritten with this run instead — how the first CI run locks in
//! real numbers from a toolchain-less commit.
//!
//! ```bash
//! cargo bench --bench fig13_replan
//! LOBRA_BENCH_GPUS=32 LOBRA_BENCH_EVENTS=18 cargo bench --bench fig13_replan
//! LOBRA_BENCH_SLICE=500 cargo bench --bench fig13_replan
//! LOBRA_BENCH_BASELINE=benches/baselines/BENCH_fig13.json \
//!     cargo bench --bench fig13_replan                    # drift gate
//! ```


// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, TaskSet, TaskSpec};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::session::PlanningSession;
use lobra::costmodel::CostModel;
use lobra::util::bench::{fmt_secs, gate_against_baseline, BaselineGate, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn main() {
    let gpus: u32 = benv::parse_or("LOBRA_BENCH_GPUS", 64);
    let n_events: usize = benv::parse_or("LOBRA_BENCH_EVENTS", 12);
    let json_path = benv::var("LOBRA_BENCH_JSON").unwrap_or("BENCH_fig13.json").to_string();
    let baseline_path = benv::var("LOBRA_BENCH_BASELINE");

    let cluster = ClusterSpec::a800_80g(gpus);
    let model = ModelDesc::llama2_70b();
    let cost = CostModel::calibrated(&model, &cluster);
    let planner = Planner::new(&cost, &cluster);
    let opts = PlannerOptions::default();
    let mut session = PlanningSession::new(opts.clone());

    // Sliding-window churn over 6 paper datasets: each event retires the
    // oldest task and admits the next, so 4-task sets recur with period 6
    // (recurring contexts are what the cost-table LRU and the survivor
    // memo exist for).
    let pool: Vec<TaskSpec> = TaskSet::paper_all().tasks.into_iter().take(6).collect();
    let window = 4usize;
    let mut live: Vec<TaskSpec> = pool[..window].to_vec();
    let mut next = window;

    println!(
        "== Figure 13: cold vs warm replan latency, 70B / {gpus} GPUs, {n_events} churn events ==\n"
    );
    let mut t = Table::new(&[
        "event", "tasks", "cold", "warm", "speedup", "identical", "plan",
    ]);
    let mut cold_total = 0.0f64;
    let mut warm_total = 0.0f64;
    let mut all_identical = true;

    for event in 0..n_events {
        // churn: oldest task exits, the next pool task (re-)arrives
        live.remove(0);
        live.push(pool[next % pool.len()].clone());
        next += 1;
        let tasks = TaskSet::new(live.clone());

        let t0 = Stopwatch::start();
        let cold = planner.plan(&tasks, opts.clone()).expect("cold plan");
        let cold_s = t0.elapsed_secs();

        let t1 = Stopwatch::start();
        let warm = session.plan(&planner, &tasks).expect("warm plan");
        let warm_s = t1.elapsed_secs();

        let identical = warm.groups == cold.groups
            && warm.expected_step_time.to_bits() == cold.expected_step_time.to_bits();
        all_identical &= identical;
        cold_total += cold_s;
        warm_total += warm_s;
        t.row(&[
            event.to_string(),
            tasks.len().to_string(),
            fmt_secs(cold_s),
            fmt_secs(warm_s),
            format!("{:.2}x", cold_s / warm_s.max(1e-12)),
            if identical { "yes".into() } else { "NO".into() },
            warm.notation(),
        ]);
    }
    t.print();

    let (hits, misses) = session.tables().stats();
    let speedup = cold_total / warm_total.max(1e-12);
    println!(
        "\ntotals: cold {} vs warm {} ({speedup:.2}x); session: {} warm / {} cold starts, \
         table LRU {hits} hits / {misses} misses",
        fmt_secs(cold_total),
        fmt_secs(warm_total),
        session.stats.warm_starts,
        session.stats.cold_starts,
    );
    println!(
        "plan identity warm==cold on every event: {}",
        if all_identical { "yes" } else { "NO — BUG" }
    );

    // --- anytime budget sweep: plan quality vs enumeration budget ---
    let slice_plans: usize = benv::parse_or("LOBRA_BENCH_SLICE", 2_000);
    let tasks = TaskSet::new(live.clone());
    println!(
        "\n== anytime budget sweep: best-so-far objective per {slice_plans}-plan slice =="
    );
    let mut sweep = PlanningSession::new(opts.clone());
    let mut search =
        sweep.begin_anytime(&planner, &tasks).expect("plannable final task set");
    let mut curve: Vec<(usize, f64, f64)> = Vec::new();
    let t_sweep = Stopwatch::start();
    let mut ct = Table::new(&["slice", "plans", "best step time", "wall"]);
    loop {
        let r = sweep.pump_anytime(&planner, &mut search, slice_plans);
        let best = sweep
            .anytime_best(&planner, &search)
            .expect("anytime search always holds a feasible best-so-far plan");
        let wall = t_sweep.elapsed_secs();
        curve.push((search.n_enumerated(), best.expected_step_time, wall));
        ct.row(&[
            curve.len().to_string(),
            search.n_enumerated().to_string(),
            format!("{:.4}s", best.expected_step_time),
            fmt_secs(wall),
        ]);
        if r.done || curve.len() >= 10_000 {
            break;
        }
    }
    ct.print();
    let (final_plan, _) =
        sweep.finish_anytime(&planner, search).expect("final anytime plan");
    let cold_final = planner.plan(&tasks, opts.clone()).expect("cold final plan");
    let anytime_identical = final_plan.groups == cold_final.groups
        && final_plan.expected_step_time.to_bits()
            == cold_final.expected_step_time.to_bits();
    println!(
        "anytime sweep: {} slices, final plan [{}], identical to cold: {}",
        curve.len(),
        final_plan.notation(),
        if anytime_identical { "yes" } else { "NO — BUG" }
    );

    let curve_json = curve
        .iter()
        .map(|(n, t, w)| {
            format!(
                "{{\"plans\": {n}, \"best_step_time\": {t:.6}, \"wall_seconds\": {w:.6}}}"
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    let json = format!(
        "{{\n  \"bench\": \"fig13_replan\",\n  \"gpus\": {gpus},\n  \"events\": {n_events},\n  \
         \"cold_seconds\": {cold_total:.6},\n  \"warm_seconds\": {warm_total:.6},\n  \
         \"speedup\": {speedup:.4},\n  \"plan_identical\": {all_identical},\n  \
         \"warm_starts\": {},\n  \"cold_starts\": {},\n  \"table_hits\": {hits},\n  \
         \"table_misses\": {misses},\n  \"slice_plans\": {slice_plans},\n  \
         \"anytime_identical\": {anytime_identical},\n  \"budget_curve\": [\n    \
         {curve_json}\n  ]\n}}\n",
        session.stats.warm_starts, session.stats.cold_starts,
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nwall-clocks recorded to {json_path}"),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }

    if let Some(baseline) = baseline_path {
        render_gate(baseline, &json);
    }
}

/// Host-speed-dependent lines skipped by the baseline diff: every timing
/// (`*_seconds`, including the budget-curve's per-slice walls, which share
/// their lines) and the derived speedup. What remains — identity booleans,
/// warm/cold start counts, LRU hit/miss counters, event counts — is
/// deterministic and locked.
fn host_dependent(line: &str) -> bool {
    line.contains("seconds") || line.contains("speedup")
}

/// Render the shared baseline gate's outcome; exits nonzero on drift so CI
/// fails loudly when the replan-identity metrics change.
fn render_gate(path: &str, current: &str) {
    match gate_against_baseline(path, current, &host_dependent) {
        BaselineGate::Blessed => println!("baseline {path} blessed from this run"),
        BaselineGate::Ok(n) => println!("baseline {path}: OK ({n} deterministic lines)"),
        BaselineGate::Unreadable(e) => {
            eprintln!("ERROR: baseline {path} unreadable: {e}");
            std::process::exit(1);
        }
        BaselineGate::WriteFailed(e) => {
            eprintln!("ERROR: blessing baseline {path}: {e}");
            std::process::exit(1);
        }
        BaselineGate::Drift(diff) => {
            eprintln!("ERROR: replan metrics drifted from baseline {path}:");
            for (w, g) in diff {
                eprintln!("  - {w}");
                eprintln!("  + {g}");
            }
            std::process::exit(1);
        }
    }
}
