//! Figure 10: the case for the two-stage decomposition (7B, 16×A100).
//!
//! Left: per-step cost of solving the *original* joint problem (Eq. 1 —
//! re-plan deployment + dispatch for the realized batch) vs the two-stage
//! path (dynamic bucketing + Eq. 3 dispatch on the fixed plan), compared
//! with the average training-step time. Paper: Eq. 1 is slower than a
//! step; the two-stage path is microseconds and fully overlappable.
//!
//! Right: solution quality over 100 steps — `T_decomp/T_origin` (within
//! 15% in occasional spike steps) and `T_actual/T_decomp` (cost-model
//! accuracy, within 10%).
//!
//! ```bash
//! cargo bench --bench fig10_planning
//! ```

// Benches print their paper-figure tables by design (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use lobra::coordinator::bucketing::{bucketize, BucketingOptions};
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::{Planner, PlanningStats};
use lobra::data::MultiTaskSampler;
use lobra::experiments::Scenario;
use lobra::util::bench::{fmt_secs, Table};
use lobra::util::clock::Stopwatch;
use lobra::util::env as benv;

fn main() {
    let steps: usize = benv::parse_or("LOBRA_BENCH_STEPS", 100);
    let sc = Scenario::paper_7b_16();
    let cost = sc.cost();
    let planner = Planner::new(&cost, &sc.cluster);
    let plan = planner.plan(&sc.tasks, sc.planner_opts()).unwrap();
    let dispatcher = Dispatcher::new(&cost, &plan);
    println!("== Figure 10: planning cost & quality ({} steps) ==", steps);
    println!("fixed plan: [{}]\n", plan.notation());

    let mut sampler = MultiTaskSampler::new(&sc.tasks, 11);
    let opts = BucketingOptions::default();

    let mut t_origin_solve = Vec::new();
    let mut t_twostage_solve = Vec::new();
    let mut ratios_decomp = Vec::new();
    let mut ratios_actual = Vec::new();
    let mut step_times = Vec::new();
    let mut stats = PlanningStats::default();

    for step in 0..steps {
        let batch = sampler.next_batch();
        let lengths = batch.lengths();

        // two-stage: dynamic bucketing + Eq.3 dispatch on the fixed plan
        let t0 = Stopwatch::start();
        let buckets = bucketize(&lengths, &opts);
        let dp = dispatcher.dispatch(&buckets, DispatchPolicy::Balanced).unwrap();
        t_twostage_solve.push(t0.elapsed_secs());
        let t_decomp = dp.solver_makespan.max(1e-9);
        let t_actual = dp.predicted_step_time;
        step_times.push(t_actual);

        // original problem: joint re-plan for this very batch (Eq. 1)
        let t1 = Stopwatch::start();
        stats = PlanningStats::default();
        let origin = planner.plan_for_buckets(
            &buckets,
            sc.tasks.len() as u32,
            &sc.planner_opts(),
            &mut stats,
            t1,
        );
        t_origin_solve.push(t1.elapsed_secs());
        if let Some(op) = origin {
            let t_origin = op.expected_step_time.max(1e-9);
            ratios_decomp.push(t_actual / t_origin);
            ratios_actual.push(t_actual / t_decomp);
        }
        if step < 3 {
            eprintln!("  step {step}: origin solve {} two-stage {}",
                fmt_secs(*t_origin_solve.last().unwrap()),
                fmt_secs(*t_twostage_solve.last().unwrap()));
        }
    }

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);

    println!(
        "planner search (last re-plan): {} candidate configs, {} plans enumerated,\n\
         {} after lower-bound filter, peak plan storage {} (survivor-bounded)\n",
        stats.n_candidate_configs,
        stats.n_plans_enumerated,
        stats.n_plans_after_filter,
        stats.peak_plan_storage
    );

    println!("-- left: solve time vs step time --");
    let mut t = Table::new(&["quantity", "mean", "max"]);
    t.row(&["Eq.1 re-plan / step".into(), fmt_secs(mean(&t_origin_solve)), fmt_secs(max(&t_origin_solve))]);
    t.row(&["two-stage (bucket+Eq.3)".into(), fmt_secs(mean(&t_twostage_solve)), fmt_secs(max(&t_twostage_solve))]);
    t.row(&["training step (simulated)".into(), fmt_secs(mean(&step_times)), fmt_secs(max(&step_times))]);
    t.print();
    println!(
        "\ntwo-stage overlappable: {} (solve << step)",
        mean(&t_twostage_solve) < 0.1 * mean(&step_times)
    );

    println!("\n-- right: solution quality over {} steps --", ratios_decomp.len());
    let mut q = Table::new(&["ratio", "mean", "max"]);
    q.row(&[
        "T_twostage / T_origin".into(),
        format!("{:.3}", mean(&ratios_decomp)),
        format!("{:.3}", max(&ratios_decomp)),
    ]);
    q.row(&[
        "T_actual / T_decomp-estimate".into(),
        format!("{:.3}", mean(&ratios_actual)),
        format!("{:.3}", max(&ratios_actual)),
    ]);
    q.print();
    println!(
        "\npaper expectation: T_twostage/T_origin ≈ 1 (spikes < 1.15); estimate accurate within ~10%."
    );
    println!(
        "note: the paper's Eq.1 (SCIP MINLP) is slower than a training step; our specialized\n\
         solver re-plans in ms at 16 GPUs (it grows to minutes at 128-256 GPUs, Table 5).\n\
         Per-step re-planning is still useless in practice: a plan change costs a ~2-3 min\n\
         checkpoint/restart redeployment (§5.1), which the two-stage decomposition avoids."
    );
}
