//! Staged-runtime certificates: the pp/tp-realized pipeline must be a
//! *refactoring* of the unstaged native engine, not a different model.
//!
//! - pp=1 × tp=1 staged execution is bit-identical to
//!   `NativeModel::train_step` (the identity certificate);
//! - any pp partitioning (tp=1) is bit-identical too — stage boundaries
//!   reorder execution across microbatches, never within-math;
//! - results are invariant across worker thread counts (the 1F1B channel
//!   schedule is fixed by (pp, M), not by timing);
//! - a short training trajectory converges to the same losses for every
//!   stage count;
//! - the executor built on the native backend emits *measured* calibration
//!   observations for tp>1 and pp>1 configurations.

use std::sync::Arc;

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::bucketing::{bucketize, BucketingOptions};
use lobra::coordinator::dispatcher::DispatchPolicy;
use lobra::coordinator::planner::DeploymentPlan;
use lobra::costmodel::CostModel;
use lobra::data::{MultiTaskSampler, SyntheticCorpus};
use lobra::exec::{ExecutionPlan, PjrtExecutor, ReplicaExecutor, StepExecution};
use lobra::prelude::TaskSet;
use lobra::runtime::{NativeModel, NativeSpec, ParamVector, StageMb, StagedEngine};
use lobra::util::par::with_max_threads;
use lobra::util::Rng;

/// Micro model + params with a *non-zero* adapter (fresh LoRA A-matrices
/// init to zero, which would leave the adapter path untested).
fn micro_world(seed: u64) -> (Arc<NativeModel>, Arc<ParamVector>, ParamVector) {
    let model = NativeModel::new(NativeSpec::micro()).unwrap();
    let (base, mut lora) = model.init_params(seed);
    let mut rng = Rng::new(seed ^ 0x10_5a);
    for v in lora.data.iter_mut() {
        *v = 0.02 * rng.normal() as f32;
    }
    (Arc::new(model), Arc::new(base), lora)
}

/// A deterministic mixed-task microbatch set covering both micro shapes.
fn microbatches(model: &NativeModel, seed: u64, reps: usize) -> Vec<StageMb> {
    let spec = model.spec();
    let mut corpus = SyntheticCorpus::new(spec.vocab as u32, spec.n_tasks, seed);
    let mut mbs = Vec::new();
    for _ in 0..reps {
        for &(b, s) in &model.shapes() {
            let mut tokens = Vec::with_capacity((b * s) as usize);
            let mut seg_ids = Vec::with_capacity(b as usize);
            for row in 0..b as usize {
                let task = row * spec.n_tasks / b as usize;
                tokens.extend(corpus.sequence_exact(task, s as usize, s as usize));
                seg_ids.push(task as i32);
            }
            mbs.push(StageMb { shape: (b, s), tokens, seg_ids });
        }
    }
    mbs
}

fn assert_outputs_bit_identical(
    a: &[(lobra::runtime::StepOutput, lobra::runtime::MbTiming)],
    b: &[(lobra::runtime::StepOutput, lobra::runtime::MbTiming)],
    tag: &str,
) {
    assert_eq!(a.len(), b.len(), "{tag}: run lengths differ");
    for (i, ((oa, _), (ob, _))) in a.iter().zip(b).enumerate() {
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "{tag}: mb {i} loss");
        assert_eq!(oa.tokens.to_bits(), ob.tokens.to_bits(), "{tag}: mb {i} tokens");
        assert_eq!(oa.grad.len(), ob.grad.len());
        for (j, (ga, gb)) in oa.grad.iter().zip(&ob.grad).enumerate() {
            assert_eq!(ga.to_bits(), gb.to_bits(), "{tag}: mb {i} grad[{j}]");
        }
        for (ta, tb) in oa.task_loss.iter().zip(&ob.task_loss) {
            assert_eq!(ta.to_bits(), tb.to_bits(), "{tag}: mb {i} task_loss");
        }
    }
}

#[test]
fn pp1_tp1_staged_is_bit_identical_to_unstaged() {
    let (model, base, lora) = micro_world(11);
    let mbs = microbatches(&model, 3, 2);
    let staged = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 1, 1).unwrap();
    let outs = staged.run(&lora, &mbs).unwrap();
    assert_eq!(outs.len(), mbs.len());
    for (mb, (out, timing)) in mbs.iter().zip(&outs) {
        let want = model
            .train_step(&base, &lora, mb.shape, &mb.tokens, &mb.seg_ids)
            .unwrap();
        assert_eq!(out.loss.to_bits(), want.loss.to_bits());
        assert_eq!(out.tokens.to_bits(), want.tokens.to_bits());
        for (g, w) in out.grad.iter().zip(&want.grad) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        for (g, w) in out.task_loss.iter().zip(&want.task_loss) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        for (g, w) in out.task_tokens.iter().zip(&want.task_tokens) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // tp=1 performs no tensor-parallel combine
        assert_eq!(timing.comm.to_bits(), 0.0f64.to_bits());
        assert!(timing.seconds >= 0.0 && timing.bubble >= 0.0);
    }
}

#[test]
fn stage_count_never_changes_the_math() {
    // pipelining reorders *which microbatch* a stage works on, never the
    // within-microbatch arithmetic: every pp partitioning of the 4-layer
    // stack must produce bit-identical outputs (tp=1)
    let (model, base, lora) = micro_world(23);
    let mbs = microbatches(&model, 5, 3);
    let reference = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 1, 1)
        .unwrap()
        .run(&lora, &mbs)
        .unwrap();
    for pp in [2usize, 3, 4] {
        let outs = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 1, pp)
            .unwrap()
            .run(&lora, &mbs)
            .unwrap();
        assert_outputs_bit_identical(&reference, &outs, &format!("pp={pp}"));
    }
}

#[test]
fn tp_sharding_stays_within_float_noise() {
    // column-parallel projections are bit-identical under tp; row-parallel
    // ones tree-reduce partials in a fixed shape, so tp>1 may differ from
    // tp=1 only by reassociation noise — and is itself deterministic
    let (model, base, lora) = micro_world(31);
    let mbs = microbatches(&model, 7, 2);
    let t1 = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 1, 1)
        .unwrap()
        .run(&lora, &mbs)
        .unwrap();
    for tp in [2usize, 3] {
        let tn = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), tp, 1)
            .unwrap()
            .run(&lora, &mbs)
            .unwrap();
        for (i, ((oa, _), (ob, _))) in t1.iter().zip(&tn).enumerate() {
            let rel = (oa.loss - ob.loss).abs() / oa.loss.abs().max(1e-12);
            assert!(rel < 1e-5, "tp={tp} mb {i}: loss {} vs {}", oa.loss, ob.loss);
        }
        // same tp, fresh engine: deterministic to the bit
        let again = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), tp, 1)
            .unwrap()
            .run(&lora, &mbs)
            .unwrap();
        assert_outputs_bit_identical(&tn, &again, &format!("tp={tp} rerun"));
    }
}

#[test]
fn pipeline_results_are_thread_count_invariant() {
    // the 1F1B schedule is fixed by (pp, M); worker-pool width may only
    // move wall-clock, never values
    let (model, base, lora) = micro_world(41);
    let mbs = microbatches(&model, 13, 3);
    let staged = StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 2, 2).unwrap();
    let narrow = with_max_threads(1, || staged.run(&lora, &mbs).unwrap());
    let wide = with_max_threads(8, || staged.run(&lora, &mbs).unwrap());
    assert_outputs_bit_identical(&narrow, &wide, "threads 1 vs 8");
}

#[test]
fn training_trajectory_is_stage_count_invariant() {
    // converged-loss certificate: a short SGD trajectory over the same
    // microbatch stream lands on bit-identical losses for every pp
    let (model, base, lora0) = micro_world(53);
    let mbs = microbatches(&model, 17, 2);
    let lr = 0.05f32;
    let mut trajectories: Vec<Vec<u32>> = Vec::new();
    for pp in [1usize, 2, 4] {
        let staged =
            StagedEngine::new(Arc::clone(&model), Arc::clone(&base), 1, pp).unwrap();
        let mut lora = lora0.clone();
        let mut losses = Vec::new();
        for _ in 0..4 {
            let outs = staged.run(&lora, &mbs).unwrap();
            let mut grad = vec![0.0f64; lora.len()];
            let mut loss_sum = 0.0f64;
            let mut tokens = 0.0f64;
            for (out, _) in &outs {
                let w = out.tokens as f64;
                loss_sum += out.loss as f64 * w;
                tokens += w;
                for (g, gi) in grad.iter_mut().zip(&out.grad) {
                    *g += *gi as f64 * w;
                }
            }
            losses.push((loss_sum / tokens) as f32);
            for (p, g) in lora.data.iter_mut().zip(&grad) {
                *p -= lr * (*g / tokens) as f32;
            }
        }
        // the trajectory actually trains (descends) ...
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "pp={pp}: no descent: {losses:?}"
        );
        trajectories.push(losses.iter().map(|l| l.to_bits()).collect());
    }
    // ... and is the same trajectory for every stage count
    assert_eq!(trajectories[0], trajectories[1], "pp=1 vs pp=2");
    assert_eq!(trajectories[0], trajectories[2], "pp=1 vs pp=4");
}

/// One executor step of the native backend under a homogeneous deployment
/// of `cfg`.
fn native_executor_step(cfg: ParallelConfig) -> StepExecution {
    let model = NativeModel::new(NativeSpec::micro()).unwrap();
    let spec_tasks = model.spec().n_tasks;
    let (base, _) = model.init_params(5);
    let cluster = ClusterSpec::local_cpu(8);
    let cost = CostModel::calibrated(&ModelDesc::tiny(), &cluster);
    let corpus = SyntheticCorpus::new(model.spec().vocab as u32, spec_tasks, 9);
    let mut exec = PjrtExecutor::with_native(
        model,
        base,
        CostModel::calibrated(&ModelDesc::tiny(), &cluster),
        corpus,
    )
    .unwrap();
    assert_eq!(exec.platform(), "native");
    assert!(exec.engine().is_none());
    let tasks = TaskSet::paper_first_n(spec_tasks);
    let plan = DeploymentPlan::homogeneous(cfg, 2, spec_tasks as u32);
    let mut sampler = MultiTaskSampler::new(&tasks, 7);
    let batch = sampler.next_batch();
    let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
    let ep = ExecutionPlan::build(&cost, &plan, None, batch, buckets, DispatchPolicy::Balanced)
        .expect("micro deployment cannot serve the batch");
    exec.execute_step(&ep).unwrap()
}

#[test]
fn native_backend_emits_measured_multi_gpu_observations() {
    // the acceptance bar: at least one tp>1 and one pp>1 config must
    // produce real measured observations through the executor
    for cfg in [
        ParallelConfig::new(2, 1),
        ParallelConfig::new(1, 2),
        ParallelConfig::new(2, 2),
    ] {
        let out = native_executor_step(cfg);
        let train = out.train.expect("native backend must train");
        assert!(train.microbatches > 0);
        assert!(train.tokens > 0.0);
        assert!((train.loss_sum / train.tokens).is_finite());
        assert!(!out.observations.is_empty(), "{cfg}: no observations");
        for (c, o) in &out.observations {
            assert_eq!(*c, cfg);
            assert!(o.seconds > 0.0, "{cfg}: non-positive measured time");
            assert!(o.comm >= 0.0 && o.bubble >= 0.0);
            assert!(
                o.seconds >= o.comm + o.bubble - 1e-12,
                "{cfg}: overheads exceed the measured time"
            );
            if cfg.pp == 1 {
                assert_eq!(o.bubble.to_bits(), 0.0f64.to_bits(), "{cfg}: pp=1 bubble");
            }
        }
    }
}

#[test]
fn executor_step_is_thread_count_invariant() {
    // whole-step certificate over the staged backend: worker-pool width
    // must never move the training outputs (microbatch interleaving and
    // the gradient tree-reduction are fixed by the plan, not by timing)
    let run = |threads: usize| {
        with_max_threads(threads, || native_executor_step(ParallelConfig::new(2, 2)))
    };
    let a = run(1);
    let b = run(8);
    let (ta, tb) = (a.train.unwrap(), b.train.unwrap());
    assert_eq!(ta.microbatches, tb.microbatches);
    assert_eq!(ta.loss_sum.to_bits(), tb.loss_sum.to_bits());
    assert_eq!(ta.tokens.to_bits(), tb.tokens.to_bits());
    for (x, y) in ta.grad.iter().zip(&tb.grad) {
        assert_eq!(x.to_bits(), y.to_bits(), "gradient moved with thread count");
    }
    for (x, y) in ta.task_loss.iter().zip(&tb.task_loss) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
