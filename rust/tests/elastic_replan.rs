//! Elastic-fleet recovery-identity certificates (ISSUE 9).
//!
//! Cluster churn events (`Preempt`, `NodeJoin`) shrink and restore planner
//! capacity through `ShardManager::apply_capacity`. The contract under
//! test: after a `Preempt` forces a shrink onto the surviving GPUs and a
//! `NodeJoin` restores *identical* capacity, the next adopted plan is
//! **bit-identical** to the plan of a run that never lost the capacity —
//! same replica groups, same `expected_step_time` bits — across shard
//! counts {1, 4} and two worker-thread counts. Degradation must also be
//! *accounted*: the interrupted step's GPU-seconds charged, and exactly
//! one recovery episode with a positive time-to-recover.
//!
//! Thread counts are swept with `util::par::with_max_threads` (scoped,
//! thread-local) rather than env mutation — rule R3 snapshots the env
//! once per process.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig, TaskSpec};
use lobra::coordinator::planner::PlannerOptions;
use lobra::coordinator::runtime::{
    BudgetMeter, ServeOptions, ServeReport, ServeRuntime, TraceEvent,
};
use lobra::coordinator::tasks::Event;
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::util::par::with_max_threads;

const GPUS: u32 = 32;

fn world() -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(GPUS);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

fn opts(shards: usize) -> ServeOptions {
    let mut planner = PlannerOptions::default();
    planner.calibration_multiple = 20;
    planner.eval_batches = 1;
    planner.max_evaluated = 100;
    let mut o = ServeOptions::default();
    o.replan_budget = None; // unlimited: every adoption is a completed search
    o.meter = BudgetMeter::SimPerPlan(1e-3);
    o.slice_plans = 4096;
    o.certify_identity = shards <= 1; // the runtime's own cold-identity gate
    o.tail_steps = 3;
    o.planner = planner;
    o.shards = shards;
    o
}

/// Four tenants with distinct length profiles (so 4-shard runs spread
/// them), all arrived well before the capacity churn starts.
fn tenant_events() -> Vec<TraceEvent> {
    let specs: [(&str, u32, f64, f64, u32, u32); 4] = [
        ("qa", 64, 210.0, 6.0, 16, 2048),
        ("chat", 32, 420.0, 4.0, 16, 4096),
        ("code", 24, 700.0, 6.5, 16, 8192),
        ("sum", 16, 3600.0, 4.3, 16, 16384),
    ];
    specs
        .iter()
        .enumerate()
        .map(|(i, &(name, batch, mean, skew, min, max))| TraceEvent {
            at: i as f64 * 300.0,
            event: Event::Arrive(TaskSpec::new(
                name,
                batch,
                LengthDistribution::fit(mean, skew, min, max),
            )),
        })
        .collect()
}

/// The elastic suffix: half of server 0's GPUs are reclaimed mid-training,
/// then the server rejoins — restoring exactly the starting capacity.
fn elastic_events() -> Vec<TraceEvent> {
    vec![
        TraceEvent { at: 1800.0, event: Event::Preempt { gpu_range: (0, 4) } },
        TraceEvent { at: 3600.0, event: Event::NodeJoin { server: 0 } },
    ]
}

/// Final-state snapshot: plan (groups + step-time bits) and the per-shard
/// GPU-budget clamps.
type Snap = (Vec<(ParallelConfig, u32)>, u64);

struct Run {
    report: ServeReport,
    plan: Option<Snap>,
    budgets: Vec<Option<u32>>,
}

fn run_with(trace: &[TraceEvent], o: ServeOptions, scope_threads: usize) -> Run {
    with_max_threads(scope_threads, || {
        let (cost, cluster) = world();
        let mut rt = ServeRuntime::new(&cost, &cluster, o);
        let report = rt.run_trace(trace);
        let plan = rt
            .manager()
            .plan()
            .map(|p| (p.groups.clone(), p.expected_step_time.to_bits()));
        let budgets =
            (0..rt.manager().n_shards()).map(|s| rt.manager().gpu_budget(s)).collect();
        Run { report, plan, budgets }
    })
}

fn run(trace: &[TraceEvent], shards: usize, threads: usize) -> Run {
    run_with(trace, opts(shards), threads)
}

#[test]
fn preempt_then_join_recovers_the_never_shrunk_plan() {
    let cold_trace = tenant_events();
    let mut elastic_trace = tenant_events();
    elastic_trace.extend(elastic_events());
    for shards in [1usize, 4] {
        for threads in [1usize, 2] {
            let tag = format!("shards={shards} threads={threads}");
            let cold = run(&cold_trace, shards, threads);
            let elastic = run(&elastic_trace, shards, threads);
            // the churn was delivered and accounted
            assert_eq!(elastic.report.preempt_events, 1, "{tag}");
            assert_eq!(elastic.report.join_events, 1, "{tag}");
            assert!(
                elastic.report.gpu_seconds_lost_preempt > 0.0,
                "{tag}: the interrupted step's work was not charged"
            );
            // exactly one degraded episode, closed with a positive TTR
            assert_eq!(
                elastic.report.recoveries.len(),
                1,
                "{tag}: {:?}",
                elastic.report.recoveries
            );
            assert!(elastic.report.recoveries[0] > 0.0, "{tag}");
            // the shrink and the restore each opened replan work on top of
            // the tenant churn both runs share (single-shard: the budget
            // clamp is global, so both windows are guaranteed; sharded,
            // the reslice may leave an individual shard's slice intact)
            let extra = if shards <= 1 { 2 } else { 0 };
            assert!(
                elastic.report.replan_windows >= cold.report.replan_windows + extra,
                "{tag}: elastic {} vs cold {}",
                elastic.report.replan_windows,
                cold.report.replan_windows
            );
            // every tenant admitted and progressing in both runs
            for (which, r) in [("cold", &cold.report), ("elastic", &elastic.report)] {
                assert_eq!(r.tenants.len(), 4, "{tag} {which}");
                for t in &r.tenants {
                    assert!(
                        t.admitted_at.is_some(),
                        "{tag} {which}: {} never admitted",
                        t.name
                    );
                    assert!(t.steps_trained > 0, "{tag} {which}: {} stalled", t.name);
                }
            }
            // the recovery-identity certificate: the adopted plan after the
            // restore is bit-identical to the never-shrunk run's
            assert!(elastic.plan.is_some(), "{tag}: deployment drained");
            assert_eq!(elastic.plan, cold.plan, "{tag}: recovered plan != cold plan");
            // and the capacity clamps round-tripped exactly
            assert_eq!(
                elastic.budgets, cold.budgets,
                "{tag}: budgets did not recover"
            );
            if shards <= 1 {
                assert_eq!(elastic.budgets, vec![None], "{tag}: clamp left armed");
                // the runtime's built-in certificate re-verified the
                // full-capacity adoptions (cold deploy + post-restore)
                // against a cold `Planner::plan`
                assert!(elastic.report.identity_checks > 0, "{tag}");
                assert_eq!(
                    elastic.report.identity_failures, 0,
                    "{tag}: {:#?}",
                    elastic.report
                );
            }
        }
    }
}

#[test]
fn degraded_capacity_actually_clamps_the_planner() {
    // stop right after the preempt settles: the deployed plan must fit the
    // surviving GPUs and the clamp must still be armed
    let mut trace = tenant_events();
    trace.push(TraceEvent { at: 1800.0, event: Event::Preempt { gpu_range: (0, 4) } });
    let r = run(&trace, 1, 1);
    assert_eq!(r.report.preempt_events, 1);
    assert_eq!(r.budgets, vec![Some(GPUS - 4)], "clamp not applied");
    let (groups, _) = r.plan.expect("deployment survived the shrink");
    let used: u32 = groups.iter().map(|&(c, k)| c.n() * k).sum();
    assert!(used <= GPUS - 4, "plan oversubscribes the survivors: {used} GPUs");
    assert!(r.report.recoveries.is_empty(), "no recovery without a join");
    // deterministic sim meter: the same elastic trace reproduces bit-for-bit
    let again = run(&trace, 1, 1);
    assert_eq!(r.plan, again.plan);
    assert_eq!(
        r.report.gpu_seconds_lost_preempt.to_bits(),
        again.report.gpu_seconds_lost_preempt.to_bits()
    );
    assert_eq!(r.report.steps_total, again.report.steps_total);
}

#[test]
fn async_service_adopts_recovery_identical_plans() {
    // the async planner-service path honors the same contract; its final
    // plan is compared against its own never-shrunk async run
    let cold_trace = tenant_events();
    let mut elastic_trace = tenant_events();
    elastic_trace.extend(elastic_events());
    let mut o = opts(1);
    o.planner_threads = 2;
    let cold = run_with(&cold_trace, o.clone(), 1);
    let elastic = run_with(&elastic_trace, o, 1);
    assert_eq!(elastic.report.preempt_events, 1);
    assert_eq!(elastic.report.join_events, 1);
    assert_eq!(
        elastic.report.recoveries.len(),
        1,
        "{:?}",
        elastic.report.recoveries
    );
    assert!(elastic.plan.is_some(), "deployment drained");
    assert_eq!(elastic.plan, cold.plan, "async recovered plan != async cold plan");
    assert_eq!(elastic.budgets, vec![None]);
    assert_eq!(elastic.report.identity_failures, 0, "{:#?}", elastic.report);
}
