//! Property tests for the fused streaming plan search and the memoized
//! cost table:
//!
//!  * on small clusters (N ≤ 16) the streaming enumerate+filter visits
//!    exactly the surviving plan set (and order) of the two-phase
//!    enumerate-then-filter reference path, with bit-identical bounds;
//!  * `CostTable` answers bit-identical values to the uncached `CostModel`
//!    calls across configs, boundaries, and replica loads.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::bucketing::Buckets;
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::{LowerBoundScratch, Planner, PlannerOptions};
use lobra::costmodel::{BucketLoad, CostModel, CostTable};
use lobra::solver::partition::{enumerate_plans, Plan};

fn world(n_gpus: u32) -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(n_gpus);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

fn paper_buckets() -> Buckets {
    Buckets {
        boundaries: vec![512, 2048, 8192],
        counts: vec![200, 40, 4],
        padding_tokens: 0,
    }
}

/// The seed's two-phase reference path: enumerate everything into a Vec,
/// drop plans unable to run the longest bucket, bound each survivor, then
/// filter against the best bound.
fn two_phase_survivors(
    planner: &Planner,
    cost: &CostModel,
    configs: &[ParallelConfig],
    n_gpus: u32,
    buckets: &Buckets,
    opts: &PlannerOptions,
) -> Vec<(Plan, f64)> {
    let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
    let min_gpus = n_gpus.saturating_sub(min_n - 1);
    let plans = enumerate_plans(configs, n_gpus, min_gpus, None, opts.max_plans);
    let longest = *buckets.boundaries.last().unwrap() as u64;
    let plans: Vec<Plan> = plans
        .into_iter()
        .filter(|p| {
            configs
                .iter()
                .enumerate()
                .any(|(i, c)| p.counts[i] > 0 && cost.max_seq_len(*c) >= longest)
        })
        .collect();
    if !opts.lower_bound_filter {
        return plans.into_iter().map(|p| (p, 0.0)).collect();
    }
    let bounds: Vec<(Plan, f64)> = plans
        .into_iter()
        .filter_map(|p| planner.lower_bound(configs, &p, buckets).map(|lb| (p, lb)))
        .collect();
    let best = bounds.iter().map(|&(_, lb)| lb).fold(f64::INFINITY, f64::min);
    bounds
        .into_iter()
        .filter(|&(_, lb)| lb <= best * (1.0 + opts.lower_bound_threshold))
        .collect()
}

#[test]
fn streaming_matches_two_phase_on_small_clusters() {
    for n in [4u32, 8, 12, 16] {
        let (cost, cluster) = world(n);
        let planner = Planner::new(&cost, &cluster);
        let buckets = paper_buckets();
        let opts = PlannerOptions::default();
        let configs = planner.propose_configs(&buckets.boundaries, true);
        if configs.is_empty() {
            continue;
        }
        let table = CostTable::build(&cost, &configs, &buckets.boundaries);
        let streaming = planner.filtered_plans(&configs, &table, &buckets, &opts);
        let reference =
            two_phase_survivors(&planner, &cost, &configs, n, &buckets, &opts);
        assert_eq!(
            streaming.survivors.len(),
            reference.len(),
            "N={n}: survivor count"
        );
        for (k, ((sp, slb), (rp, rlb))) in
            streaming.survivors.iter().zip(&reference).enumerate()
        {
            assert_eq!(sp, rp, "N={n} survivor {k}: plan mismatch");
            assert_eq!(
                slb.to_bits(),
                rlb.to_bits(),
                "N={n} survivor {k}: bound mismatch"
            );
        }
        assert!(!streaming.hit_cap, "N={n}: unexpected plan cap");
    }
}

#[test]
fn streaming_matches_two_phase_without_filter() {
    let n = 12u32;
    let (cost, cluster) = world(n);
    let planner = Planner::new(&cost, &cluster);
    let buckets = paper_buckets();
    let mut opts = PlannerOptions::default();
    opts.lower_bound_filter = false;
    let configs = planner.propose_configs(&buckets.boundaries, true);
    let table = CostTable::build(&cost, &configs, &buckets.boundaries);
    let streaming = planner.filtered_plans(&configs, &table, &buckets, &opts);
    let reference = two_phase_survivors(&planner, &cost, &configs, n, &buckets, &opts);
    let got: Vec<&Plan> = streaming.survivors.iter().map(|(p, _)| p).collect();
    let want: Vec<&Plan> = reference.iter().map(|(p, _)| p).collect();
    assert_eq!(got, want);
    assert!(streaming.n_enumerated > 0);
}

#[test]
fn streaming_respects_plan_cap() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let buckets = paper_buckets();
    let mut opts = PlannerOptions::default();
    opts.max_plans = 10;
    let configs = planner.propose_configs(&buckets.boundaries, true);
    let table = CostTable::build(&cost, &configs, &buckets.boundaries);
    let search = planner.filtered_plans(&configs, &table, &buckets, &opts);
    assert!(search.hit_cap);
    assert_eq!(search.n_enumerated, 10);
    assert!(search.survivors.len() <= 10);
}

/// Reference rank-truncation: the seed's collect-all survivors, stable
/// sorted by bound (when above K) and truncated — what the online top-K
/// search must reproduce exactly.
fn truncated_reference(
    mut survivors: Vec<(Plan, f64)>,
    k: usize,
) -> Vec<(Plan, f64)> {
    if survivors.len() > k {
        survivors.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        survivors.truncate(k);
    }
    survivors
}

#[test]
fn top_k_matches_truncated_survivors() {
    for (n, k) in [(8u32, 3usize), (12, 5), (16, 4), (16, 10_000)] {
        let (cost, cluster) = world(n);
        let planner = Planner::new(&cost, &cluster);
        let buckets = paper_buckets();
        let mut opts = PlannerOptions::default();
        opts.max_evaluated = k;
        let configs = planner.propose_configs(&buckets.boundaries, true);
        if configs.is_empty() {
            continue;
        }
        let table = CostTable::build(&cost, &configs, &buckets.boundaries);
        let full = planner.filtered_plans(&configs, &table, &buckets, &opts);
        let reference = truncated_reference(full.survivors.clone(), k);
        let topk = planner.search_top_k(&configs, &table, &buckets, &opts, None);
        assert_eq!(topk.n_survivors, full.survivors.len(), "N={n} K={k}");
        assert_eq!(topk.candidates.len(), reference.len(), "N={n} K={k}");
        for (i, ((tp, tlb), (rp, rlb))) in
            topk.candidates.iter().zip(&reference).enumerate()
        {
            assert_eq!(tp, rp, "N={n} K={k} candidate {i}");
            assert_eq!(tlb.to_bits(), rlb.to_bits(), "N={n} K={k} bound {i}");
        }
        // the top-K search never buffers more plans than it enumerated
        assert!(topk.peak_storage <= topk.n_enumerated.max(1), "N={n} K={k}");
    }
}

#[test]
fn seeded_search_is_bit_identical_to_cold() {
    // seeding the incumbent with any valid plan's bound must not change
    // the candidate set, order, bounds, or survivor count
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let buckets = paper_buckets();
    let mut opts = PlannerOptions::default();
    opts.max_evaluated = 6;
    let configs = planner.propose_configs(&buckets.boundaries, true);
    let table = CostTable::build(&cost, &configs, &buckets.boundaries);
    let cold = planner.search_top_k(&configs, &table, &buckets, &opts, None);
    assert!(!cold.candidates.is_empty());
    // seed with the true best bound (tightest valid seed) and a loose one
    for seed in [cold.best_bound, cold.best_bound * 1.1] {
        let warm = planner.search_top_k(&configs, &table, &buckets, &opts, Some(seed));
        assert_eq!(warm.n_survivors, cold.n_survivors, "seed {seed}");
        assert_eq!(warm.candidates.len(), cold.candidates.len(), "seed {seed}");
        assert_eq!(warm.best_bound.to_bits(), cold.best_bound.to_bits());
        for (i, ((wp, wlb), (cp, clb))) in
            warm.candidates.iter().zip(&cold.candidates).enumerate()
        {
            assert_eq!(wp, cp, "seed {seed} candidate {i}");
            assert_eq!(wlb.to_bits(), clb.to_bits(), "seed {seed} bound {i}");
        }
    }
}

#[test]
fn costtable_bit_identical_to_costmodel() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let boundaries = [256u32, 512, 1024, 2048, 4096, 8192, 16384];
    let configs = planner.feasible_configs(true);
    assert!(!configs.is_empty());
    let table = CostTable::build(&cost, &configs, &boundaries);
    for &cfg in &configs {
        assert_eq!(table.max_seq_len(cfg), cost.max_seq_len(cfg), "{cfg}");
        assert_eq!(table.max_chunk_tokens(cfg), cost.max_chunk_tokens(cfg), "{cfg}");
        for &s in &boundaries {
            assert_eq!(
                table.per_seq_cost(cfg, s as u64).to_bits(),
                cost.per_seq_cost(cfg, s as u64).to_bits(),
                "{cfg} s={s}"
            );
        }
        let loads = [
            vec![BucketLoad { count: 13, padded_len: 512 }],
            vec![
                BucketLoad { count: 200, padded_len: 256 },
                BucketLoad { count: 7, padded_len: 2048 },
            ],
            vec![
                BucketLoad { count: 1, padded_len: 16384 },
                BucketLoad { count: 0, padded_len: 512 },
            ],
        ];
        for l in &loads {
            assert_eq!(
                table.replica_time(cfg, l).to_bits(),
                cost.replica_time(cfg, l).to_bits(),
                "{cfg} {l:?}"
            );
        }
        // untabulated inputs fall back to the exact model
        assert_eq!(
            table.per_seq_cost(cfg, 300).to_bits(),
            cost.per_seq_cost(cfg, 300).to_bits()
        );
    }
}

#[test]
fn scratch_reuse_does_not_corrupt_bounds() {
    // the hot path reuses one LowerBoundScratch across millions of plans;
    // a fresh scratch per plan must give bit-identical bounds
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let buckets = paper_buckets();
    let configs = planner.propose_configs(&buckets.boundaries, true);
    let table = CostTable::build(&cost, &configs, &buckets.boundaries);
    let plans = enumerate_plans(&configs, 16, 14, None, 100_000);
    assert!(!plans.is_empty());
    let mut shared = LowerBoundScratch::new();
    for p in plans.iter().take(500) {
        let mut fresh = LowerBoundScratch::new();
        let a = planner.lower_bound_cached(&table, &p.counts, &buckets, &mut shared);
        let b = planner.lower_bound_cached(&table, &p.counts, &buckets, &mut fresh);
        assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits), "{p:?}");
    }
}

#[test]
fn full_planner_is_deterministic_with_memoization() {
    // end-to-end: the streaming + memoized planner returns the same plan
    // (groups and predicted time) across repeated runs and thread timings
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let tasks = lobra::prelude::TaskSet::paper_7b_subset();
    let a = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let b = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    assert_eq!(a.groups, b.groups);
    assert_eq!(
        a.expected_step_time.to_bits(),
        b.expected_step_time.to_bits()
    );
}

#[test]
fn memoized_dispatch_equals_uncached_on_planned_deployment() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let tasks = lobra::prelude::TaskSet::paper_7b_subset();
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let buckets = paper_buckets();
    let cfgs: Vec<ParallelConfig> = plan.groups.iter().map(|&(c, _)| c).collect();
    let table = CostTable::build(&cost, &cfgs, &buckets.boundaries);
    let plain = Dispatcher::new(&cost, &plan)
        .dispatch(&buckets, DispatchPolicy::Balanced)
        .unwrap();
    let memo = Dispatcher::with_table(&cost, &plan, &table)
        .dispatch(&buckets, DispatchPolicy::Balanced)
        .unwrap();
    assert_eq!(plain.d, memo.d);
    assert_eq!(
        plain.predicted_step_time.to_bits(),
        memo.predicted_step_time.to_bits()
    );
}
