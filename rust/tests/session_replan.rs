//! Warm-start correctness of persistent planning sessions:
//!
//!  * after ANY `Arrive`/`Exit` churn sequence, a warm-started session
//!    replan is plan-identical — same `groups`, bit-identical
//!    `expected_step_time` — to a cold `Planner::plan` on the same task
//!    set (seeding the search incumbent only accelerates pruning, it never
//!    changes the survivor set the evaluation sees);
//!  * `TaskManager` accounting (`replans`/`redeploys`) stays exact over a
//!    long churn trace with duplicate arrivals and unknown exits mixed in;
//!  * a search that tripped the `max_plans` cap can be *extended* from its
//!    resume checkpoint until the enumeration completes, recovering the
//!    exact plan of an uncapped cold search;
//!  * the budget-sliced **anytime** search (begin/pump/finish) given an
//!    unlimited budget is plan-identical to a cold `Planner::plan` for any
//!    slice schedule, and an exhausted budget still yields a valid
//!    feasible plan — never `None` while tasks exist.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, TaskSet, TaskSpec};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::session::PlanningSession;
use lobra::coordinator::tasks::{Event, Outcome, TaskManager};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::util::Rng;

fn world(n_gpus: u32) -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(n_gpus);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

/// A varied pool of tenants: short instruction tasks through a 16K
/// summarization tail, so churn moves the bucket boundaries and the
/// candidate-config set around.
fn spec_pool() -> Vec<TaskSpec> {
    vec![
        TaskSpec::new("qa-short", 128, LengthDistribution::fit(210.0, 6.0, 16, 2048)),
        TaskSpec::new("code-instr", 96, LengthDistribution::fit(280.0, 8.0, 16, 2048)),
        TaskSpec::new("evol-like", 64, LengthDistribution::fit(700.0, 6.5, 16, 8192)),
        TaskSpec::new("commits", 64, LengthDistribution::fit(660.0, 0.8, 16, 4096)),
        TaskSpec::new("xsum-like", 64, LengthDistribution::fit(520.0, 7.5, 16, 8192)),
        TaskSpec::new("meetings", 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384)),
    ]
}

/// Faster planner options for churn tests (identical for warm and cold
/// paths, so the identity property is unaffected).
fn churn_opts() -> PlannerOptions {
    let mut opts = PlannerOptions::default();
    opts.calibration_multiple = 25;
    opts.eval_batches = 2;
    opts.max_evaluated = 300;
    opts
}

#[test]
fn warm_replan_matches_cold_after_any_churn() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = churn_opts();
    let mut session = PlanningSession::new(opts.clone());
    let pool = spec_pool();
    let mut live: Vec<TaskSpec> = vec![pool[0].clone(), pool[2].clone()];
    let mut rng = Rng::new(0xC0FFEE);

    let mut checked = 0;
    for event in 0..10 {
        // mutate the live set: arrive an absent pool task or exit a live
        // one (keeping at least one task live, so every event replans)
        let arriving = live.len() <= 1 || (live.len() < pool.len() && rng.f64() < 0.5);
        if arriving {
            let absent: Vec<&TaskSpec> = pool
                .iter()
                .filter(|s| !live.iter().any(|l| l.name == s.name))
                .collect();
            let pick = absent[rng.below(absent.len() as u64) as usize];
            live.push(pick.clone());
        } else {
            let victim = rng.below(live.len() as u64) as usize;
            live.remove(victim);
        }
        let tasks = TaskSet::new(live.clone());
        let warm = session.plan(&planner, &tasks).unwrap();
        let cold = planner.plan(&tasks, opts.clone()).unwrap();
        assert_eq!(
            warm.groups, cold.groups,
            "event {event}: warm plan diverged from cold ({} tasks)",
            tasks.len()
        );
        assert_eq!(
            warm.expected_step_time.to_bits(),
            cold.expected_step_time.to_bits(),
            "event {event}: warm step-time not bit-identical to cold"
        );
        checked += 1;
    }
    assert_eq!(checked, 10, "every churn event must have replanned");

    // replanning an unchanged task set is guaranteed to warm-start (the
    // candidate-config set cannot have moved) and stay identical
    let tasks = TaskSet::new(live.clone());
    let warm_before = session.stats.warm_starts;
    let warm = session.plan(&planner, &tasks).unwrap();
    let cold = planner.plan(&tasks, opts.clone()).unwrap();
    assert_eq!(session.stats.warm_starts, warm_before + 1);
    assert_eq!(warm.groups, cold.groups);
    assert_eq!(warm.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
}

#[test]
fn churn_accounting_over_twenty_events() {
    let (cost, cluster) = world(8);
    let mut opts = churn_opts();
    opts.eval_batches = 1;
    opts.calibration_multiple = 10;
    let pool = spec_pool();
    let initial = TaskSet::new(vec![pool[0].clone()]);
    let mut mgr = TaskManager::new(&cost, &cluster, initial, opts);
    let mut expected_replans = mgr.replans; // the initial plan
    assert_eq!(expected_replans, 1);

    let mut rng = Rng::new(0x5EED);
    let mut live: Vec<String> = vec![pool[0].name.clone()];
    for event in 0..24 {
        let roll = rng.f64();
        let outcome = if roll < 0.35 && live.len() < pool.len() {
            // fresh arrival: replan expected
            let absent: Vec<&TaskSpec> = pool
                .iter()
                .filter(|s| !live.contains(&s.name))
                .collect();
            let pick = absent[rng.below(absent.len() as u64) as usize].clone();
            live.push(pick.name.clone());
            expected_replans += 1;
            let out = mgr.handle(Event::Arrive(pick));
            assert_ne!(out, Outcome::Rejected, "event {event}");
            out
        } else if roll < 0.5 && !live.is_empty() {
            // duplicate arrival: rejected, no replan
            let name = &live[rng.below(live.len() as u64) as usize];
            let dup = pool.iter().find(|s| &s.name == name).unwrap().clone();
            let out = mgr.handle(Event::Arrive(dup));
            assert_eq!(out, Outcome::Rejected, "event {event}");
            out
        } else if roll < 0.65 {
            // unknown exit: unchanged, no replan
            let out = mgr.handle(Event::Exit { name: "never-arrived".into() });
            assert_eq!(out, Outcome::Unchanged, "event {event}");
            out
        } else if live.len() > 1 {
            // real exit leaving a non-empty set: replan expected
            let victim = live.remove(rng.below(live.len() as u64) as usize);
            expected_replans += 1;
            mgr.handle(Event::Exit { name: victim })
        } else {
            // keep at least one live task so the manager never drains
            let absent: Vec<&TaskSpec> = pool
                .iter()
                .filter(|s| !live.contains(&s.name))
                .collect();
            let pick = absent[rng.below(absent.len() as u64) as usize].clone();
            live.push(pick.name.clone());
            expected_replans += 1;
            mgr.handle(Event::Arrive(pick))
        };
        assert_eq!(
            mgr.replans, expected_replans,
            "event {event} ({outcome:?}): replan accounting drifted"
        );
        assert!(mgr.redeploys <= mgr.replans, "event {event}");
        assert_eq!(mgr.tasks().len(), live.len(), "event {event}");
        assert!(mgr.plan().is_some(), "event {event}: live tasks but no plan");
    }
    // every replan was served by the persistent session
    assert_eq!(mgr.session().stats.plans, mgr.replans as u64);
    assert_eq!(
        mgr.session().stats.warm_starts + mgr.session().stats.cold_starts,
        mgr.replans as u64
    );
    let (hits, misses) = mgr.tables().stats();
    assert_eq!(hits + misses, mgr.replans as u64, "one table fetch per replan");
}

#[test]
fn anytime_with_unlimited_budget_is_plan_identical_to_cold() {
    // Property: for varied task subsets and slice schedules, pumping the
    // anytime search to enumeration completion and finishing yields the
    // exact cold plan (same groups, bit-identical expected_step_time).
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = churn_opts();
    let pool = spec_pool();
    let cases = [
        (vec![0usize, 2], 7usize),
        (vec![0, 2, 5], 11),
        (vec![1, 3, 4, 5], 16),
    ];
    for (case, (picks, slice_plans)) in cases.iter().enumerate() {
        let tasks =
            TaskSet::new(picks.iter().map(|&k| pool[k].clone()).collect());
        let mut session = PlanningSession::new(opts.clone());
        let mut search = session
            .begin_anytime(&planner, &tasks)
            .expect("plannable world");
        let mut slices = 0u32;
        loop {
            let r = session.pump_anytime(&planner, &mut search, *slice_plans);
            slices += 1;
            assert!(slices < 100_000, "case {case}: anytime failed to converge");
            if r.done {
                break;
            }
        }
        assert!(
            slices > 1,
            "case {case}: slice budget too generous to exercise resumption"
        );
        assert!(search.enumeration_done());
        let (anytime, stats) = session.finish_anytime(&planner, search).unwrap();
        assert!(!stats.hit_plan_cap, "case {case}");
        let cold = planner.plan(&tasks, opts.clone()).unwrap();
        assert_eq!(anytime.groups, cold.groups, "case {case}");
        assert_eq!(
            anytime.expected_step_time.to_bits(),
            cold.expected_step_time.to_bits(),
            "case {case}: anytime not bit-identical to cold"
        );
    }
}

#[test]
fn exhausted_budget_still_yields_feasible_plan() {
    // An anytime replan whose budget expires mid-search must deploy a
    // valid feasible best-so-far plan — never None while tasks exist.
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = churn_opts();
    let pool = spec_pool();
    let tasks =
        TaskSet::new(vec![pool[0].clone(), pool[2].clone(), pool[5].clone()]);
    let longest = tasks.tasks.iter().map(|t| t.lengths.max_len).max().unwrap();

    let mut session = PlanningSession::new(opts.clone());
    let mut search = session.begin_anytime(&planner, &tasks).unwrap();
    // burn one tiny slice, then force-adopt mid-search
    let r = session.pump_anytime(&planner, &mut search, 3);
    assert!(!r.done, "3-plan slice cannot finish a 16-GPU enumeration");
    let (plan, stats) =
        session.finish_anytime(&planner, search).expect("best-so-far plan");
    assert!(stats.hit_plan_cap, "an interrupted search memoizes as capped");
    assert!(plan.gpus_used() >= 1 && plan.gpus_used() <= 16);
    let cap = plan.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
    assert!(
        cap >= longest as u64,
        "best-so-far plan cannot serve the longest tenant: {cap} < {longest}"
    );

    // extreme case: a budget so tight not even one slice ran — the
    // homogeneous fallbacks still produce a feasible deployment
    let search = session.begin_anytime(&planner, &tasks).unwrap();
    let (plan, _) = session
        .finish_anytime(&planner, search)
        .expect("zero-slice finish must still deploy");
    assert!(plan.gpus_used() >= 1 && plan.gpus_used() <= 16);
    let cap = plan.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
    assert!(cap >= longest as u64);
}

#[test]
fn extend_capped_search_recovers_uncapped_plan() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let tasks = TaskSet::new(vec![
        spec_pool()[0].clone(),
        spec_pool()[2].clone(),
        spec_pool()[5].clone(),
    ]);

    let mut capped_opts = churn_opts();
    // Force the cap: ≥5 distinct replica sizes {1,2,4,8,16} admit ≥36
    // maximal packings of 16 GPUs, so a 20-plan budget always trips.
    capped_opts.max_plans = 20;
    let mut session = PlanningSession::new(capped_opts.clone());
    let (first, stats) = session.plan_with_stats(&planner, &tasks).unwrap();
    assert!(stats.hit_plan_cap, "20-plan budget must cap at 16 GPUs");
    assert!(first.gpus_used() <= 16);

    // extend in slices until the enumeration completes
    let mut final_plan = first;
    let mut rounds = 0;
    loop {
        let Some((plan, stats)) = session.extend_capped_search(&planner, &tasks, 100_000)
        else {
            break;
        };
        final_plan = plan;
        rounds += 1;
        if !stats.hit_plan_cap {
            break;
        }
        assert!(rounds < 50, "extension failed to converge");
    }
    assert!(rounds >= 1, "capped memo must be extendable");
    // once complete, further extension has nothing to do
    assert!(session.extend_capped_search(&planner, &tasks, 100_000).is_none());

    // the incrementally-extended search equals one uncapped cold search
    let mut full_opts = capped_opts;
    full_opts.max_plans = usize::MAX / 2;
    let cold = planner.plan(&tasks, full_opts).unwrap();
    assert_eq!(final_plan.groups, cold.groups);
    assert_eq!(
        final_plan.expected_step_time.to_bits(),
        cold.expected_step_time.to_bits(),
        "extended {} vs cold {}",
        final_plan.expected_step_time,
        cold.expected_step_time
    );
}
