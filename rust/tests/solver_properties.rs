//! Randomized property tests for the min–max dispatch solvers (the role
//! proptest would play; generation is driven by the in-tree deterministic
//! RNG so failures are reproducible by seed).
//!
//! Invariants certified over hundreds of random instances:
//!  * feasibility: demand conservation + support constraints
//!  * `solve_balanced` never loses to `solve_length_based`
//!  * the fractional optimum lower-bounds every integer solution
//!  * B&B (exact) never loses to the heuristic, and the heuristic is
//!    within a small factor of exact on small instances

use lobra::solver::{
    bnb, makespan, solve_balanced, solve_fractional, solve_length_based,
    DispatchProblem, GroupSpec,
};
use lobra::util::Rng;

/// Random instance with nested support structure (as in LobRA: group i
/// supports buckets `0..=r_i`).
fn random_problem(rng: &mut Rng, max_groups: usize, max_buckets: usize, max_demand: u64) -> DispatchProblem {
    let n_groups = 1 + rng.below(max_groups as u64) as usize;
    let n_buckets = 1 + rng.below(max_buckets as u64) as usize;
    // per-bucket base cost grows with bucket index (longer sequences)
    let base: Vec<f64> = (0..n_buckets)
        .map(|j| (j + 1) as f64 * (0.5 + rng.f64()))
        .collect();
    let mut groups = Vec::new();
    for gi in 0..n_groups {
        // group efficiency factor; later groups support more buckets
        let eff = 0.5 + rng.f64() * 2.0;
        let r = if gi == n_groups - 1 {
            n_buckets // someone must support everything
        } else {
            1 + rng.below(n_buckets as u64) as usize
        };
        let costs: Vec<f64> = (0..n_buckets)
            .map(|j| if j < r { base[j] * eff } else { f64::INFINITY })
            .collect();
        groups.push(GroupSpec {
            costs,
            replicas: 1 + rng.below(4) as u32,
            fixed: rng.f64() * 0.5,
        });
    }
    let demand: Vec<u64> = (0..n_buckets).map(|_| rng.below(max_demand + 1)).collect();
    DispatchProblem { groups, demand }
}

#[test]
fn balanced_feasible_and_no_worse_than_length_based() {
    let mut rng = Rng::new(0xF00D);
    for trial in 0..300 {
        let p = random_problem(&mut rng, 5, 8, 40);
        let lb = solve_length_based(&p).expect("satisfiable by construction");
        let bal = solve_balanced(&p).expect("satisfiable by construction");
        assert!(bal.is_feasible(&p), "trial {trial}: balanced infeasible");
        assert!(lb.is_feasible(&p), "trial {trial}: length-based infeasible");
        assert!(
            bal.makespan <= lb.makespan + 1e-6,
            "trial {trial}: balanced {} > length-based {}",
            bal.makespan,
            lb.makespan
        );
        // reported makespan must match recomputation
        assert!((makespan(&p, &bal.d) - bal.makespan).abs() < 1e-9);
    }
}

#[test]
fn fractional_lower_bounds_integer() {
    let mut rng = Rng::new(0xBEEF);
    for trial in 0..300 {
        let p = random_problem(&mut rng, 4, 6, 30);
        let (t_frac, d_frac) = solve_fractional(&p).unwrap();
        let bal = solve_balanced(&p).unwrap();
        assert!(
            t_frac <= bal.makespan + 1e-6,
            "trial {trial}: fractional {} > integer {}",
            t_frac,
            bal.makespan
        );
        // fractional assignment conserves demand
        for (j, &bj) in p.demand.iter().enumerate() {
            let total: f64 = d_frac.iter().map(|row| row[j]).sum();
            assert!(
                (total - bj as f64).abs() < 1e-6,
                "trial {trial}: bucket {j} fractional {total} != {bj}"
            );
        }
    }
}

#[test]
fn exact_bnb_certifies_heuristic_on_small_instances() {
    let mut rng = Rng::new(0xCAFE);
    let mut worst_gap: f64 = 0.0;
    for trial in 0..60 {
        let p = random_problem(&mut rng, 3, 3, 6);
        let bal = solve_balanced(&p).unwrap();
        let exact = bnb::solve_exact(&p, 3_000_000).unwrap();
        assert!(exact.is_feasible(&p));
        assert!(
            exact.makespan <= bal.makespan + 1e-9,
            "trial {trial}: exact {} > heuristic {}",
            exact.makespan,
            bal.makespan
        );
        if exact.makespan > 0.0 {
            worst_gap = worst_gap.max(bal.makespan / exact.makespan - 1.0);
        }
    }
    // the heuristic should be near-optimal on these instances
    assert!(worst_gap < 0.25, "heuristic gap {worst_gap:.3} too large");
}

#[test]
fn zero_demand_buckets_never_assigned() {
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..100 {
        let mut p = random_problem(&mut rng, 4, 5, 20);
        let kill = rng.below(p.demand.len() as u64) as usize;
        p.demand[kill] = 0;
        let bal = solve_balanced(&p).unwrap();
        let total: u64 = bal.d.iter().map(|row| row[kill]).sum();
        assert_eq!(total, 0);
    }
}

#[test]
fn single_group_gets_everything() {
    let mut rng = Rng::new(0xAB);
    for _ in 0..50 {
        let p = random_problem(&mut rng, 1, 6, 25);
        let bal = solve_balanced(&p).unwrap();
        for (j, &bj) in p.demand.iter().enumerate() {
            assert_eq!(bal.d[0][j], bj);
        }
    }
}

#[test]
fn makespan_scale_invariance() {
    // scaling all costs by k scales the optimum by ~k (fixed costs too)
    let mut rng = Rng::new(0x5CA1E);
    for _ in 0..50 {
        let p = random_problem(&mut rng, 4, 5, 20);
        let mut p2 = p.clone();
        for g in &mut p2.groups {
            for c in &mut g.costs {
                *c *= 3.0;
            }
            g.fixed *= 3.0;
        }
        let a = solve_balanced(&p).unwrap();
        let b = solve_balanced(&p2).unwrap();
        if a.makespan > 0.0 {
            let ratio = b.makespan / a.makespan;
            assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
        }
    }
}
