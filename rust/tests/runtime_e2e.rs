//! End-to-end runtime tests over the real PJRT engine + AOT artifacts.
//!
//! These require `make artifacts` (they skip politely when artifacts are
//! absent). Engine compilation dominates test time, so the checks are
//! grouped into two test functions sharing one engine each.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::planner::DeploymentPlan;
use lobra::costmodel::CostModel;
use lobra::data::SyntheticCorpus;
use lobra::runtime::Engine;
use lobra::train::{Trainer, TrainerConfig};
use std::path::PathBuf;

fn artifacts_dir() -> Option<String> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json")
        .exists()
        .then(|| p.to_string_lossy().to_string())
}

#[test]
fn engine_contract() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut engine = Engine::load(&dir).unwrap();
    let (base, lora) = engine.init_params(7);
    engine.set_base(&base).unwrap();
    let m = engine.manifest().clone();
    let n_tasks = m.model.n_tasks as usize;
    let mut corpus = SyntheticCorpus::new(m.model.vocab as u32, n_tasks, 1);

    // --- executes all shapes with finite loss + nonzero grads ------------
    for (b, s) in engine.shapes() {
        let tasks: Vec<usize> = (0..b as usize).map(|i| i % n_tasks).collect();
        let (toks, segs) = corpus.fused_microbatch(&tasks, s as usize);
        let out = engine.train_step((b, s), &lora, &toks, &segs).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0, "shape {b}x{s}");
        assert_eq!(out.grad.len(), lora.len());
        assert!(out.tokens > 0.0);
        let gnorm: f64 = out.grad.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
        assert!(gnorm > 0.0, "zero gradient at {b}x{s}");
        let tl: f32 = out.task_tokens.iter().sum();
        assert!((tl - out.tokens).abs() < 1.0, "task tokens {tl} vs {}", out.tokens);
    }

    // --- gradient locality: only task 0 present => others get zero -------
    let (b, s) = engine.shapes()[0];
    let tasks0 = vec![0usize; b as usize];
    let (toks, segs) = corpus.fused_microbatch(&tasks0, s as usize);
    let out = engine.train_step((b, s), &lora, &toks, &segs).unwrap();
    for e in &m.lora_params {
        let per_task = (e.size / n_tasks as u64) as usize;
        let lo = e.offset as usize;
        for t in 1..n_tasks {
            let sl = &out.grad[lo + t * per_task..lo + (t + 1) * per_task];
            let max = sl.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert_eq!(max, 0.0, "{}: task {t} got gradient", e.name);
        }
    }

    // --- determinism -------------------------------------------------------
    let o1 = engine.train_step((b, s), &lora, &toks, &segs).unwrap();
    let o2 = engine.train_step((b, s), &lora, &toks, &segs).unwrap();
    assert_eq!(o1.loss, o2.loss);
    assert_eq!(o1.grad, o2.grad);

    // --- eval path ---------------------------------------------------------
    if let Some((eb, es)) = engine.eval_shape() {
        let etasks: Vec<usize> = (0..eb as usize).map(|i| i % n_tasks).collect();
        let (etoks, esegs) = corpus.fused_microbatch(&etasks, es as usize);
        let (loss, toks, _, tt) = engine.eval_loss(&lora, &etoks, &esegs).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((tt.iter().sum::<f32>() - toks).abs() < 1.0);
    }

    // --- malformed inputs rejected ------------------------------------------
    let toks_ok = vec![1i32; (b * s) as usize];
    let mut bad_segs = vec![0i32; b as usize];
    if b >= 2 {
        bad_segs[0] = 1; // unsorted
        assert!(engine.train_step((b, s), &lora, &toks_ok, &bad_segs).is_err());
    }
    assert!(engine
        .train_step((b, s), &lora, &toks_ok[..toks_ok.len() - 1], &vec![0; b as usize])
        .is_err());
    assert!(engine
        .train_step((b + 1, s), &lora, &toks_ok, &vec![0; b as usize + 1])
        .is_err());
}

#[test]
fn trainer_learns_and_checkpoints() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut cfg = TrainerConfig::default();
    cfg.adam.lr = 1e-2;
    cfg.per_task_batch = 2;
    cfg.seed = 5;
    let mut trainer = Trainer::new(&dir, cfg).unwrap();

    let mut first = None;
    trainer
        .run(8, |log| {
            if first.is_none() {
                first = Some(log.loss);
            }
            assert!(log.loss.is_finite());
            assert!(log.microbatches > 0);
        })
        .unwrap();
    let last = trainer.logs().last().unwrap().loss;
    assert!(last < first.unwrap(), "no improvement: {:?} -> {last}", first);

    // checkpoint roundtrip (adapters + optimizer moments + step count)
    let path = std::env::temp_dir().join("lobra_test_trainer.ckpt");
    let path = path.to_string_lossy().to_string();
    trainer.save_checkpoint(&path).unwrap();
    let norm_before = trainer.lora().norm();
    let step_before = trainer.logs().last().unwrap().step;
    trainer.step().unwrap();
    assert_ne!(trainer.lora().norm(), norm_before);
    trainer.load_checkpoint(&path).unwrap();
    assert_eq!(trainer.lora().norm(), norm_before);
    // the optimizer resumed too (step count was persisted, not reset):
    // the next step continues the pre-save sequence exactly
    let log = trainer.step().unwrap();
    assert_eq!(log.step, step_before + 1, "optimizer step count not restored");

    // --- virtual-cluster redeploy (serving-runtime swap path) ------------
    // the engine world the trainer's default deployment lives on
    let preset = trainer.engine().unwrap().manifest().preset.clone();
    let model = ModelDesc::by_name(&preset).unwrap_or_else(ModelDesc::tiny);
    let cluster = ClusterSpec::local_cpu(4);
    // plan-identical redeploy: zero changed replicas, zero charge
    let same = trainer.virtual_plan().clone();
    let adj = trainer.redeploy(CostModel::calibrated(&model, &cluster), same);
    assert!(adj.is_zero(), "identical plan must charge nothing: {adj:?}");
    assert_eq!(trainer.redeploys(), 1);
    // shrink <1,1>x4 → <1,1>x2: exactly the removed replicas pay, and the
    // optimizer trajectory (adapters, moments, step count) survives
    let step_pre = trainer.logs().last().unwrap().step;
    let norm_pre = trainer.lora().norm();
    let two = DeploymentPlan::homogeneous(
        ParallelConfig::new(1, 1),
        2,
        trainer.n_tasks() as u32,
    );
    let adj = trainer.redeploy(CostModel::calibrated(&model, &cluster), two);
    assert_eq!(adj.changed_replicas, 2, "{adj:?}");
    assert_eq!(adj.changed_gpus, 2);
    assert_eq!(trainer.redeploys(), 2);
    assert_eq!(trainer.lora().norm(), norm_pre, "redeploy touched the adapters");
    let log = trainer.step().unwrap();
    assert_eq!(log.step, step_pre + 1, "optimizer step count lost in redeploy");
    assert!(log.loss.is_finite());
    assert_eq!(trainer.virtual_plan().n_replicas(), 2);
}
