//! Property tests for the lock-free plan publication primitives behind
//! the async planner service:
//!
//!  * [`EpochCell`] readers never observe a *torn* value — every snapshot
//!    is an internally-consistent `Arc` whose payload matches its epoch —
//!    and the epochs a reader observes never regress, even under
//!    concurrent writers racing interleaved epochs;
//!  * a publish at a stale (or equal) epoch is rejected and invisible:
//!    the newest epoch stays current no matter how the races interleave;
//!  * a snapshot taken before a supersession stays valid (the `Arc` keeps
//!    the retired payload alive) while later reads see the newer epoch;
//!  * a cancellation that lands mid-slice discards the slice wholesale:
//!    the resumable search state (candidates, counters, checkpoint) is
//!    bit-untouched, so pumping on to completion still lands on the exact
//!    cold plan — cancellation can change *when* a plan appears, never
//!    *which* plan.

use std::sync::Arc;
use std::time::Duration;

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, TaskSet, TaskSpec};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::session::PlanningSession;
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::util::par::{CancelToken, EpochCell};

#[test]
fn readers_never_observe_torn_values_or_regressing_epochs() {
    const EPOCHS: u64 = 400;
    const WIDTH: usize = 64;
    let cell = Arc::new(EpochCell::<Vec<u64>>::new());
    // lint:allow(R6): hammer test needs raw reader/writer threads to race the cell.
    std::thread::scope(|s| {
        let writer_cell = Arc::clone(&cell);
        s.spawn(move || {
            for e in 1..=EPOCHS {
                // payload encodes its own epoch WIDTH times: any torn or
                // stale-mixed read shows up as a non-uniform vector
                assert!(writer_cell.publish(e, Arc::new(vec![e; WIDTH])));
            }
        });
        for _ in 0..4 {
            let reader_cell = Arc::clone(&cell);
            s.spawn(move || {
                let mut last = 0u64;
                loop {
                    if let Some((epoch, v)) = reader_cell.read() {
                        assert!(epoch >= last, "epoch regressed: {epoch} < {last}");
                        last = epoch;
                        assert_eq!(v.len(), WIDTH);
                        assert!(
                            v.iter().all(|&x| x == epoch),
                            "torn read at epoch {epoch}: {v:?}"
                        );
                        if epoch == EPOCHS {
                            return;
                        }
                    }
                    std::hint::spin_loop();
                }
            });
        }
    });
    let (epoch, v) = cell.read().expect("published");
    assert_eq!(epoch, EPOCHS);
    assert!(v.iter().all(|&x| x == EPOCHS));
}

#[test]
fn stale_publishes_lose_every_race() {
    let cell = Arc::new(EpochCell::<u64>::new());
    // two writers race disjoint interleaved epoch sequences; whatever the
    // interleaving, only strictly-newer publishes may land
    // lint:allow(R6): the race under test needs two real writer threads.
    std::thread::scope(|s| {
        for parity in 0..2u64 {
            let c = Arc::clone(&cell);
            s.spawn(move || {
                for e in (1 + parity..=300).step_by(2) {
                    let accepted = c.publish(e, Arc::new(e));
                    if accepted {
                        let (now, _) = c.read().expect("just published");
                        assert!(now >= e, "accepted epoch {e} then read older {now}");
                    }
                }
            });
        }
    });
    let (epoch, v) = cell.read().expect("published");
    assert_eq!(epoch, 300);
    assert_eq!(*v, 300);
    // explicit stale and same-epoch publishes are rejected and invisible
    assert!(!cell.publish(12, Arc::new(12)));
    assert!(!cell.publish(300, Arc::new(0)));
    let (epoch, v) = cell.read().expect("published");
    assert_eq!((epoch, *v), (300, 300));
}

#[test]
fn old_snapshot_survives_supersession() {
    let cell = EpochCell::<Vec<u64>>::new();
    assert!(cell.publish(1, Arc::new(vec![1; 8])));
    let (e1, old) = cell.read().expect("published");
    assert_eq!(e1, 1);
    assert!(cell.publish(2, Arc::new(vec![2; 8])));
    // the pre-supersession snapshot is still intact (Arc keeps the retired
    // slot's payload alive) while fresh reads see the newer epoch
    assert!(old.iter().all(|&x| x == 1));
    let (e2, new) = cell.read().expect("published");
    assert_eq!(e2, 2);
    assert!(new.iter().all(|&x| x == 2));
}

fn world(n_gpus: u32) -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(n_gpus);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

fn fast_opts() -> PlannerOptions {
    let mut opts = PlannerOptions::default();
    opts.calibration_multiple = 25;
    opts.eval_batches = 2;
    opts.max_evaluated = 300;
    opts
}

#[test]
fn cancellation_mid_slice_never_perturbs_the_resumable_search() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = fast_opts();
    let tasks = TaskSet::new(vec![
        TaskSpec::new("qa-short", 128, LengthDistribution::fit(210.0, 6.0, 16, 2048)),
        TaskSpec::new("evol-like", 64, LengthDistribution::fit(700.0, 6.5, 16, 8192)),
        TaskSpec::new("meetings", 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384)),
    ]);
    let cold = planner.plan(&tasks, opts.clone()).expect("plannable world");

    let mut session = PlanningSession::new(opts);
    let mut search = session.begin_anytime(&planner, &tasks).expect("admitted");
    let mut cancelled_slices = 0u32;
    loop {
        // snapshot the resumable state, then attack the slice with a token
        // armed from another thread at an arbitrary point mid-enumeration
        let before = (search.n_enumerated(), search.slices(), search.spent_seconds());
        let token = CancelToken::new();
        let report = {
            let t = token.clone();
            // lint:allow(R6): the property needs a cancel racing a live slice.
            std::thread::scope(|s| {
                s.spawn(move || {
                    std::thread::sleep(Duration::from_micros(200));
                    t.cancel();
                });
                session.pump_anytime_cancellable(&planner, &mut search, 400, Some(&token))
            })
        };
        if report.cancelled {
            cancelled_slices += 1;
            assert!(!report.done, "a cancelled slice can never complete the search");
            assert_eq!(
                (search.n_enumerated(), search.slices(), search.spent_seconds().to_bits()),
                (before.0, before.1, before.2.to_bits()),
                "cancelled slice leaked state into the resumable search"
            );
            // deterministic re-check: an already-armed token short-circuits
            // before any work and is equally side-effect free
            let again = session.pump_anytime_cancellable(&planner, &mut search, 400, Some(&token));
            assert!(again.cancelled && again.n_enumerated == 0);
            assert_eq!(search.slices(), before.1);
            // make guaranteed progress so the test terminates even if every
            // raced slice gets cancelled
            let clean = session.pump_anytime(&planner, &mut search, 400);
            if clean.done {
                break;
            }
        } else if report.done {
            break;
        }
    }
    // best-effort signal (timing-dependent, so not asserted): at least
    // seeing the loop finish proves cancelled slices were resumable
    let _ = cancelled_slices;
    let (plan, stats) = session.finish_anytime(&planner, search).expect("feasible");
    assert!(!stats.hit_plan_cap);
    assert_eq!(plan.groups, cold.groups, "cancellation changed the final plan");
    assert_eq!(plan.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
}
