//! Integration tests of the deployment planner across models & clusters.

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::dispatcher::DispatchPolicy;
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::prelude::{TaskSet, TaskSpec};

fn plan_world(
    model: ModelDesc,
    cluster: ClusterSpec,
    tasks: &TaskSet,
    opts: PlannerOptions,
) -> Option<(lobra::coordinator::planner::DeploymentPlan, CostModel)> {
    let cost = CostModel::calibrated(&model, &cluster);
    let planner = Planner::new(&cost, &cluster);
    planner.plan(tasks, opts).map(|p| (p, cost))
}

#[test]
fn plans_respect_gpu_budget_across_worlds() {
    let worlds = [
        (ModelDesc::llama2_7b(), ClusterSpec::a100_40g(16)),
        (ModelDesc::llama2_7b(), ClusterSpec::a100_40g(32)),
        (ModelDesc::qwen25_32b(), ClusterSpec::a800_80g(32)),
        (ModelDesc::llama2_70b(), ClusterSpec::a800_80g(64)),
    ];
    let tasks = TaskSet::paper_scalability_subset();
    for (model, cluster) in worlds {
        let n = cluster.n_gpus;
        let name = model.name.clone();
        let (plan, cost) = plan_world(model, cluster, &tasks, PlannerOptions::default())
            .unwrap_or_else(|| panic!("no plan for {name}/{n}"));
        assert!(plan.gpus_used() <= n, "{name}: {} > {n}", plan.gpus_used());
        assert!(plan.n_replicas() >= 1);
        // some deployed config must support the longest sampled bucket
        let cap = plan.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
        assert!(cap >= 8192, "{name}: longest-capable cap {cap}");
        // expected step time is positive and finite
        assert!(plan.expected_step_time.is_finite() && plan.expected_step_time > 0.0);
    }
}

#[test]
fn model_too_big_for_cluster_yields_none() {
    // 70B on 8x A100-40G: even ⟨8,1⟩ cannot hold the weights + activations.
    let cluster = ClusterSpec::a100_40g(8);
    let tasks = TaskSet::paper_scalability_subset();
    let got = plan_world(ModelDesc::llama2_70b(), cluster, &tasks, PlannerOptions::default());
    assert!(got.is_none(), "expected infeasible world");
}

#[test]
fn empty_task_set_yields_none() {
    let got = plan_world(
        ModelDesc::llama2_7b(),
        ClusterSpec::a100_40g(16),
        &TaskSet::default(),
        PlannerOptions::default(),
    );
    assert!(got.is_none());
}

#[test]
fn single_gpu_cluster_single_replica() {
    let tasks = TaskSet::new(vec![TaskSpec::new(
        "short",
        32,
        LengthDistribution::fit(150.0, 2.0, 16, 1024),
    )]);
    let (plan, _) = plan_world(
        ModelDesc::llama2_7b(),
        ClusterSpec::a100_40g(1),
        &tasks,
        PlannerOptions::default(),
    )
    .unwrap();
    assert_eq!(plan.gpus_used(), 1);
    assert_eq!(plan.n_replicas(), 1);
}

#[test]
fn short_only_tasks_avoid_big_replicas() {
    // with only short sequences, no GPU-hungry config should be deployed
    let tasks = TaskSet::new(vec![TaskSpec::new(
        "qa",
        256,
        LengthDistribution::fit(180.0, 2.0, 16, 900),
    )]);
    let (plan, cost) = plan_world(
        ModelDesc::llama2_7b(),
        ClusterSpec::a100_40g(16),
        &tasks,
        PlannerOptions::default(),
    )
    .unwrap();
    // every sequence fits the 1-GPU config; there is no reason to deploy
    // anything with more than 2 GPUs per replica
    let max_n = plan.groups.iter().map(|&(c, _)| c.n()).max().unwrap();
    assert!(max_n <= 2, "plan over-provisioned: {} (cap1={})", plan.notation(), cost.max_seq_len(lobra::config::ParallelConfig::new(1,1)));
}

#[test]
fn inner_policy_changes_plan_shape() {
    let tasks = TaskSet::paper_7b_subset();
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    let planner = Planner::new(&cost, &cluster);
    let balanced = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let mut lb_opts = PlannerOptions::default();
    lb_opts.inner_policy = DispatchPolicy::LengthBased;
    let length_planned = planner.plan(&tasks, lb_opts).unwrap();
    // both valid; the length-based plan should not be *better* under its
    // own policy than the balanced plan under balanced dispatch
    assert!(balanced.expected_step_time <= length_planned.expected_step_time + 1e-9);
}

#[test]
fn deterministic_given_seed() {
    let tasks = TaskSet::paper_7b_subset();
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    let planner = Planner::new(&cost, &cluster);
    let a = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let b = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    assert_eq!(a.groups, b.groups);
}

#[test]
fn more_gpus_never_slower() {
    let tasks = TaskSet::paper_scalability_subset();
    let mut prev = f64::INFINITY;
    for gpus in [16u32, 32, 64] {
        let (plan, _) = plan_world(
            ModelDesc::llama2_70b(),
            ClusterSpec::a800_80g(gpus),
            &tasks,
            PlannerOptions::default(),
        )
        .unwrap();
        assert!(
            plan.expected_step_time <= prev * 1.05,
            "{gpus} GPUs slower: {} > {prev}",
            plan.expected_step_time
        );
        prev = plan.expected_step_time;
    }
}
