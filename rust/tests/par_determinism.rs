//! The parallel execution path is deterministic across worker counts:
//! `par_map` (input-order results) + fixed-order token-weighted
//! `tree_reduce` yield bit-identical gradients for any worker count — the
//! property `exec::PjrtExecutor` relies on for seed-reproducible training.
//!
//! The sweep drives `util::par::set_max_threads_override` rather than
//! mutating `LOBRA_NUM_THREADS`: the env snapshot (`util::env`) is taken
//! once per process, so a mid-run `set_var` is invisible by design (rule
//! R3) — `env_mutation_after_snapshot_is_invisible` below pins that down.
//! This binary still hosts the one `set_var` call in the test suite, so
//! the historical isolation rule (concurrent `set_var`/`getenv` is UB on
//! glibc) stays satisfied as belt-and-suspenders.

use lobra::exec::tree_reduce;
use lobra::util::par::{par_map, set_max_threads_override};
use lobra::util::Rng;

/// Synthetic per-replica gradient partial: (weighted grad sum, tokens).
fn fake_partial(replica: usize, n_params: usize) -> (Vec<f32>, f64) {
    let mut rng = Rng::new(0xFEED ^ replica as u64);
    let tokens = 10.0 + rng.f64() * 100.0;
    let grad: Vec<f32> = (0..n_params)
        .map(|_| (rng.f64() as f32 - 0.5) * tokens as f32)
        .collect();
    (grad, tokens)
}

fn reduced_gradient_with_threads(threads: usize, n_replicas: usize) -> Vec<u32> {
    set_max_threads_override(Some(threads));
    // mimic the executor: replicas produce partials under par_map (order
    // preserved), then a fixed-order token-weighted tree reduction
    let ids: Vec<usize> = (0..n_replicas).collect();
    let partials = par_map(ids, |&r| fake_partial(r, 257));
    let (grad, tokens) = tree_reduce(partials, |(mut ga, ta), (gb, tb)| {
        for (a, b) in ga.iter_mut().zip(&gb) {
            *a += b;
        }
        (ga, ta + tb)
    })
    .unwrap();
    let inv = 1.0 / tokens as f32;
    grad.iter().map(|g| (g * inv).to_bits()).collect()
}

#[test]
fn gradient_reduction_deterministic_across_thread_counts() {
    let baseline = reduced_gradient_with_threads(1, 11);
    for threads in [2, 3, 8, 16] {
        let got = reduced_gradient_with_threads(threads, 11);
        assert_eq!(
            got, baseline,
            "{threads} worker threads changed the reduced gradient"
        );
    }
    set_max_threads_override(None);
}

#[test]
fn env_mutation_after_snapshot_is_invisible() {
    // Force the process-wide env snapshot, then mutate the environment:
    // the snapshot must not pick it up. This is what makes the cached
    // `max_threads()` immune to mid-run `set_var` — worker counts are
    // fixed for the life of the process unless the override above is used.
    let before = lobra::util::env::var("LOBRA_PAR_DET_PROBE");
    assert_eq!(before, None, "probe var unexpectedly set in test env");
    // lint:allow(R3): this test proves set_var is a no-op post-snapshot;
    // it is the only env mutation in the suite and this binary is isolated.
    std::env::set_var("LOBRA_PAR_DET_PROBE", "42");
    assert_eq!(
        lobra::util::env::var("LOBRA_PAR_DET_PROBE"),
        None,
        "env snapshot must be immutable after first read"
    );
}
