//! The parallel execution path is deterministic across `LOBRA_NUM_THREADS`
//! settings: `par_map` (input-order results) + fixed-order token-weighted
//! `tree_reduce` yield bit-identical gradients for any worker count — the
//! property `exec::PjrtExecutor` relies on for seed-reproducible training.
//!
//! This test mutates the process environment, so it lives alone in its own
//! test binary: concurrent `set_var`/`getenv` across threads is undefined
//! behavior on glibc, and every other test binary has concurrent env
//! readers (`util::par::max_threads`). Keep env-touching tests here only.

use lobra::exec::tree_reduce;
use lobra::util::par::par_map;
use lobra::util::Rng;

/// Synthetic per-replica gradient partial: (weighted grad sum, tokens).
fn fake_partial(replica: usize, n_params: usize) -> (Vec<f32>, f64) {
    let mut rng = Rng::new(0xFEED ^ replica as u64);
    let tokens = 10.0 + rng.f64() * 100.0;
    let grad: Vec<f32> = (0..n_params)
        .map(|_| (rng.f64() as f32 - 0.5) * tokens as f32)
        .collect();
    (grad, tokens)
}

fn reduced_gradient_with_threads(threads: &str, n_replicas: usize) -> Vec<u32> {
    std::env::set_var("LOBRA_NUM_THREADS", threads);
    // mimic the executor: replicas produce partials under par_map (order
    // preserved), then a fixed-order token-weighted tree reduction
    let ids: Vec<usize> = (0..n_replicas).collect();
    let partials = par_map(ids, |&r| fake_partial(r, 257));
    let (grad, tokens) = tree_reduce(partials, |(mut ga, ta), (gb, tb)| {
        for (a, b) in ga.iter_mut().zip(&gb) {
            *a += b;
        }
        (ga, ta + tb)
    })
    .unwrap();
    let inv = 1.0 / tokens as f32;
    grad.iter().map(|g| (g * inv).to_bits()).collect()
}

#[test]
fn gradient_reduction_deterministic_across_thread_counts() {
    let baseline = reduced_gradient_with_threads("1", 11);
    for threads in ["2", "3", "8", "16"] {
        let got = reduced_gradient_with_threads(threads, 11);
        assert_eq!(
            got, baseline,
            "LOBRA_NUM_THREADS={threads} changed the reduced gradient"
        );
    }
    std::env::remove_var("LOBRA_NUM_THREADS");
}
