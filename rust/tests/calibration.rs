//! Calibration-loop integration tests: fit edge cases, profile
//! persistence (including corrupt-file fallback), sim-replay fidelity
//! (the executor observations must reproduce the cost model they were
//! sampled from), and cost-table invalidation on recalibration.

use std::sync::Arc;

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::costmodel::{
    calibrate, cost_fingerprint, load_profile_or_analytic, world_fingerprint,
    CalibrationStore, CostModel, CostTables, FittedCost, Observation,
};
use lobra::exec::profile_sim_steps;
use lobra::prelude::TaskSet;

fn tmp_path(tag: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("lobra_test_profile_{tag}_{}.json", std::process::id()));
    p.to_string_lossy().into_owned()
}

fn world() -> (ModelDesc, ClusterSpec, TaskSet) {
    (
        ModelDesc::llama2_7b(),
        ClusterSpec::a100_40g(16),
        TaskSet::paper_7b_subset(),
    )
}

/// Diverse shapes spanning the fitted family's rank (distinct `b·s` and
/// `b·s²` directions).
const SHAPES: [(u64, u64); 5] = [(16, 512), (4, 2048), (1, 8192), (8, 512), (2, 2048)];

#[test]
fn collinear_shapes_hit_the_singular_pivot() {
    // every observation at one sequence length: the b·s and b·s² columns
    // are exactly proportional (ratio s), so the normal equations are
    // singular and the fit must be refused, not inverted through noise
    let obs: Vec<Observation> = [1u64, 2, 4, 8, 16]
        .iter()
        .map(|&b| Observation::new(b, 128, 0.01 * b as f64))
        .collect();
    assert!(calibrate::fit(&obs).is_none());

    // the store keeps the observations but reports no fit ...
    let (model, cluster, _) = world();
    let mut store = CalibrationStore::for_world(&model, &cluster);
    let cfg = ParallelConfig::new(1, 1);
    for o in &obs {
        store.record(cfg, o.b, o.s, o.seconds);
    }
    assert_eq!(store.refit(), 0);
    assert!(store.fitted_for(cfg).is_none());
    assert_eq!(store.n_observations(), 5);
    // ... and the resulting profile fits nothing, so it never attaches
    assert!(CostModel::from_profile(&model, &cluster, store.profile()).is_err());
}

#[test]
fn profile_json_round_trip_is_bit_identical() {
    let (model, cluster, tasks) = world();
    let cost = CostModel::calibrated(&model, &cluster);
    let plan = Planner::new(&cost, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .unwrap();
    let mut store = CalibrationStore::new(&cost);
    let n = profile_sim_steps(&cost, &plan, &tasks, 6, 11, &mut store);
    assert!(n > 0);
    assert!(store.refit() > 0);

    let path = tmp_path("roundtrip");
    store.save(&path).unwrap();
    let mut loaded = CalibrationStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.fingerprint(), store.fingerprint());
    assert_eq!(loaded.generation(), store.generation());
    assert_eq!(loaded.n_observations(), store.n_observations());
    assert_eq!(loaded.entries().len(), store.entries().len());
    for (a, b) in store.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.config, b.config);
        assert_eq!(a.recorded, b.recorded);
        match (a.fitted, b.fitted) {
            (Some(x), Some(y)) => {
                assert_eq!(x.beta0.to_bits(), y.beta0.to_bits());
                assert_eq!(x.beta1.to_bits(), y.beta1.to_bits());
                assert_eq!(x.beta2.to_bits(), y.beta2.to_bits());
            }
            (None, None) => {}
            other => panic!("fit lost in round trip for {}: {other:?}", a.config),
        }
        assert_eq!(a.observations.len(), b.observations.len());
        for (oa, ob) in a.observations.iter().zip(&b.observations) {
            assert_eq!(oa.b, ob.b);
            assert_eq!(oa.s, ob.s);
            assert_eq!(oa.seconds.to_bits(), ob.seconds.to_bits());
            assert_eq!(oa.comm.to_bits(), ob.comm.to_bits());
            assert_eq!(oa.bubble.to_bits(), ob.bubble.to_bits());
        }
    }
    assert_eq!(loaded.device_fingerprint(), store.device_fingerprint());
    // the loaded profile keys cost tables identically to the original
    let c1 = CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
    let c2 = CostModel::from_profile(&model, &cluster, loaded.profile()).unwrap();
    assert_eq!(cost_fingerprint(&c1), cost_fingerprint(&c2));
}

#[test]
fn corrupt_profile_falls_back_to_analytic() {
    let (model, cluster, _) = world();
    let analytic_fp = cost_fingerprint(&CostModel::calibrated(&model, &cluster));
    let path = tmp_path("corrupt");

    // not JSON at all
    std::fs::write(&path, "{ this is not json").unwrap();
    let cost = load_profile_or_analytic(&path, &model, &cluster);
    assert!(!cost.is_profiled());
    assert_eq!(cost_fingerprint(&cost), analytic_fp);

    // valid JSON of the wrong kind
    std::fs::write(&path, "{\"kind\": \"something-else\"}").unwrap();
    assert!(!load_profile_or_analytic(&path, &model, &cluster).is_profiled());

    // a valid profile measured on a *different* world must not attach ...
    let truth = FittedCost { beta0: 0.004, beta1: 2.5e-6, beta2: 1.5e-9 };
    let big = ModelDesc::llama2_70b();
    let mut other = CalibrationStore::for_world(&big, &cluster);
    let c = ParallelConfig::new(8, 1);
    for &(b, s) in &SHAPES {
        other.record(c, b, s, truth.predict(b, s));
    }
    other.refit();
    other.save(&path).unwrap();
    assert!(!load_profile_or_analytic(&path, &model, &cluster).is_profiled());
    // ... while its own world loads it fine
    assert!(load_profile_or_analytic(&path, &big, &cluster).is_profiled());

    // missing file
    std::fs::remove_file(&path).ok();
    assert!(!load_profile_or_analytic(&path, &model, &cluster).is_profiled());
}

#[test]
fn sim_replay_fit_matches_the_cost_model() {
    // property: a profile replayed through the SimExecutor is sampled from
    // the analytic model, which lies exactly in the fitted family — the
    // fit subtracts each observation's attributed comm and bubble, so the
    // profiled model (fitted compute + analytic comm) must reproduce the
    // sim's own CostModel at every observed shape, multi-GPU configs
    // included
    let (model, cluster, tasks) = world();
    let cost = CostModel::calibrated(&model, &cluster);
    let plan = Planner::new(&cost, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .unwrap();
    for seed in [3u64, 17, 91] {
        let mut store = CalibrationStore::new(&cost);
        let n = profile_sim_steps(&cost, &plan, &tasks, 8, seed, &mut store);
        assert!(n > 0, "seed {seed}: no observations");
        store.refit();
        let profiled =
            CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
        let mut checked = 0usize;
        for e in store.entries() {
            if e.fitted.is_none() {
                continue;
            }
            for o in &e.observations {
                let want = cost.t_microbatch(e.config, o.b, o.s);
                let got = profiled.t_microbatch(e.config, o.b, o.s);
                assert!(
                    (got - want).abs() / want.max(1e-12) < 1e-3,
                    "seed {seed} {} b={} s={}: profiled {got} vs analytic {want}",
                    e.config,
                    o.b,
                    o.s
                );
                checked += 1;
            }
            assert!(e.rms_rel_error().unwrap() < 1e-3, "seed {seed} {}", e.config);
        }
        assert!(checked > 0, "seed {seed}: no config accumulated a fittable set");
    }
}

#[test]
fn hygiene_rejects_stragglers_before_the_profile_attaches() {
    // regression against a contaminated observation set: cold-start
    // warmup microbatches and mid-run stragglers must not bend the fit
    // the planner will consume
    let (model, cluster, _) = world();
    let cost = CostModel::calibrated(&model, &cluster);
    let c = ParallelConfig::new(1, 1);
    let feed = |store: &mut CalibrationStore| {
        // two cold-start microbatches, 40x slow (compile + cache warmup)
        for _ in 0..2 {
            store.record(c, 4, 512, 40.0 * cost.t_microbatch(c, 4, 512));
        }
        // ... then two clean sweeps with two 25x stragglers injected
        for rep in 0..2 {
            for (i, &(b, s)) in SHAPES.iter().enumerate() {
                let t = cost.t_microbatch(c, b, s);
                let t = if rep == 1 && (i == 1 || i == 3) { 25.0 * t } else { t };
                store.record(c, b, s, t);
            }
        }
    };

    let mut store = CalibrationStore::new(&cost).with_hygiene(2, 0.2);
    feed(&mut store);
    // warmup observations were discarded at record time
    assert_eq!(store.n_observations(), 2 * SHAPES.len());
    store.refit();
    let profiled = CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
    for &(b, s) in &SHAPES {
        let want = cost.t_microbatch(c, b, s);
        let got = profiled.t_microbatch(c, b, s);
        assert!(
            (got - want).abs() / want < 1e-6,
            "hygiene fit diverged at b={b} s={s}: {got} vs {want}"
        );
    }

    // the same feed without hygiene produces a visibly bent fit
    let mut naive = CalibrationStore::new(&cost);
    feed(&mut naive);
    naive.refit();
    let bent = CostModel::from_profile(&model, &cluster, naive.profile()).unwrap();
    let worst = SHAPES
        .iter()
        .map(|&(b, s)| {
            let want = cost.t_microbatch(c, b, s);
            (bent.t_microbatch(c, b, s) - want).abs() / want
        })
        .fold(0.0f64, f64::max);
    assert!(worst > 0.05, "contamination should have bent the naive fit: {worst}");
}

#[test]
fn recalibration_rekeys_cost_tables() {
    // acceptance: recalibration changes cost_fingerprint so the shared
    // CostTableLru never serves a stale analytic (or stale-generation)
    // table to a planner running on measured times
    let (model, cluster, _) = world();
    let analytic = CostModel::calibrated(&model, &cluster);
    let configs = vec![ParallelConfig::new(1, 1), ParallelConfig::new(2, 1)];
    let bounds = vec![512u32, 2048, 8192];
    let tables = CostTables::with_capacity(8);
    let t_analytic = tables.get_or_build(&analytic, &configs, &bounds);

    // calibration pass 1: measured world runs 1.5× slower than analytic
    let c = ParallelConfig::new(1, 1);
    let mut store = CalibrationStore::new(&analytic);
    for &(b, s) in &SHAPES {
        store.record(c, b, s, 1.5 * analytic.t_microbatch(c, b, s));
    }
    let prof1 = CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
    assert_ne!(cost_fingerprint(&analytic), cost_fingerprint(&prof1));
    let t1 = tables.get_or_build(&prof1, &configs, &bounds);
    assert!(
        !Arc::ptr_eq(&t_analytic, &t1),
        "measured world was served the stale analytic table"
    );
    assert_ne!(
        t1.per_seq_cost(c, 2048).to_bits(),
        t_analytic.per_seq_cost(c, 2048).to_bits(),
        "profiled table must tabulate measured times"
    );

    // recalibration: new observations bump the generation → new key again
    store.record(c, 3, 512, 1.5 * analytic.t_microbatch(c, 3, 512));
    let prof2 = CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
    assert_ne!(cost_fingerprint(&prof1), cost_fingerprint(&prof2));
    let t2 = tables.get_or_build(&prof2, &configs, &bounds);
    assert!(!Arc::ptr_eq(&t1, &t2), "stale profile generation was served");

    // the analytic world still hits its original entry ...
    let t_again = tables.get_or_build(&analytic, &configs, &bounds);
    assert!(Arc::ptr_eq(&t_analytic, &t_again));
    // ... and the persistence key (world fingerprint) never moved
    assert_eq!(world_fingerprint(&model, &cluster), store.fingerprint());
}

#[test]
fn calibrate_save_load_plan_end_to_end() {
    // the `lobra calibrate` → `lobra train --profile` loop, sim-backed:
    // profile under the analytic plan, persist, reload, attach, replan
    let (model, cluster, tasks) = world();
    let cost = CostModel::calibrated(&model, &cluster);
    let plan = Planner::new(&cost, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .unwrap();
    let mut store = CalibrationStore::new(&cost);
    let n = profile_sim_steps(&cost, &plan, &tasks, 6, 7, &mut store);
    assert!(n > 0);
    assert!(store.refit() > 0);
    let path = tmp_path("e2e");
    store.save(&path).unwrap();

    let profiled = CostModel::from_profile(
        &model,
        &cluster,
        CalibrationStore::load(&path).unwrap().profile(),
    )
    .unwrap();
    std::fs::remove_file(&path).ok();
    assert!(profiled.is_profiled());
    let replan = Planner::new(&profiled, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .expect("planning from the measured profile failed");
    // the sim profile reproduces the analytic t(b,s) to ~1e-6, so the
    // measured plan's expected step time must land on the analytic one
    let rel = (replan.expected_step_time - plan.expected_step_time).abs()
        / plan.expected_step_time;
    assert!(
        rel < 0.05,
        "measured-profile plan diverged: {} vs {} (rel {rel})",
        replan.expected_step_time,
        plan.expected_step_time
    );
}
