//! Sharded localized replanning certificates (ISSUE 8).
//!
//! Three properties gate the sharded planning path:
//!
//!  1. **Single-shard bit-identity** — `ShardManager` with `n_shards = 1`
//!     is a passthrough to the global `TaskManager`: the same churn
//!     sequence yields the same groups and the same
//!     `expected_step_time` *bits* after every adoption, at more than one
//!     worker-thread count.
//!  2. **Composed-plan feasibility + determinism** — with real sharding
//!     the per-shard plans compose into a global plan that never
//!     oversubscribes the cluster, stays `(gpus, tp)`-sorted, and is
//!     bit-identical across worker-thread counts (the search is
//!     thread-count-invariant, so sharding must be too).
//!  3. **Admission accounting under churn** — serving a generated
//!     thousand-tenant-style churn trace sharded keeps the tenant ledger
//!     consistent (every arrival is admitted, queued, or rejected — never
//!     lost) and reproduces bit-for-bit on the deterministic sim meter.
//!
//! Thread counts are swept with `util::par::with_max_threads` (scoped,
//! thread-local) rather than env mutation — rule R3 snapshots the env
//! once per process.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, ParallelConfig, TaskSet, TaskSpec};
use lobra::coordinator::planner::PlannerOptions;
use lobra::coordinator::runtime::{
    gen_churn_trace, BudgetMeter, ServeOptions, ServeReport, ServeRuntime,
};
use lobra::coordinator::shard::ShardManager;
use lobra::coordinator::tasks::{Event, Outcome, TaskManager};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::util::par::with_max_threads;

fn world(n: u32) -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(n);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

fn fast_opts() -> PlannerOptions {
    let mut o = PlannerOptions::default();
    o.calibration_multiple = 20;
    o.eval_batches = 1;
    o.max_evaluated = 100;
    o
}

fn short(name: &str) -> TaskSpec {
    TaskSpec::new(name, 64, LengthDistribution::fit(210.0, 6.0, 16, 2048))
}

fn long(name: &str) -> TaskSpec {
    TaskSpec::new(name, 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384))
}

fn initial() -> TaskSet {
    TaskSet::new(vec![short("a"), long("b")])
}

/// The churn sequence every identity test replays: arrivals, an exit, a
/// re-arrival — the recurring-context regime the session memo serves.
fn churn_events() -> Vec<Event> {
    vec![
        Event::Arrive(short("c1")),
        Event::Arrive(long("d1")),
        Event::Exit { name: "c1".into() },
        Event::Arrive(short("c2")),
    ]
}

/// Plan snapshot: groups, step-time bits, GPUs used. `None` = drained.
type Snap = Option<(Vec<(ParallelConfig, u32)>, u64, u32)>;

fn snap_groups(groups: &[(ParallelConfig, u32)], step: f64) -> Snap {
    let gpus = {
        let mut n = 0u32;
        for &(c, k) in groups {
            n += c.n() * k;
        }
        n
    };
    Some((groups.to_vec(), step.to_bits(), gpus))
}

/// Replay the churn through a global [`TaskManager`], adopting after every
/// opened replan; returns the plan snapshot after each event.
fn drive_global(threads: usize) -> Vec<Snap> {
    with_max_threads(threads, || {
        let (cost, cluster) = world(16);
        let mut mgr = TaskManager::new(&cost, &cluster, initial(), fast_opts());
        let mut snaps =
            vec![mgr.plan().and_then(|p| snap_groups(&p.groups, p.expected_step_time))];
        for ev in churn_events() {
            if matches!(mgr.apply_event(ev), Outcome::Planning { .. }) {
                while let Some(r) = mgr.pump_replan(10_000) {
                    if r.done {
                        break;
                    }
                }
                mgr.finish_replan();
            }
            snaps.push(
                mgr.plan().and_then(|p| snap_groups(&p.groups, p.expected_step_time)),
            );
        }
        snaps
    })
}

/// Replay the same churn through a [`ShardManager`] with `n_shards`.
fn drive_sharded(threads: usize, n_shards: usize, gpus: u32) -> Vec<Snap> {
    with_max_threads(threads, || {
        let (cost, cluster) = world(gpus);
        let mut mgr =
            ShardManager::new(&cost, &cluster, initial(), fast_opts(), n_shards);
        let mut snaps =
            vec![mgr.plan().and_then(|p| snap_groups(&p.groups, p.expected_step_time))];
        for ev in churn_events() {
            if let Outcome::Planning { .. } = mgr.apply_event(ev) {
                while let Some(r) = mgr.pump_replan(10_000) {
                    if r.done {
                        break;
                    }
                }
                mgr.finish_replan();
            }
            snaps.push(
                mgr.plan().and_then(|p| snap_groups(&p.groups, p.expected_step_time)),
            );
        }
        snaps
    })
}

#[test]
fn single_shard_is_bit_identical_to_global_across_thread_counts() {
    for threads in [1usize, 2] {
        let sharded = drive_sharded(threads, 1, 16);
        let global = drive_global(threads);
        assert_eq!(
            sharded, global,
            "n_shards=1 diverged from the global manager at {threads} threads"
        );
        assert!(
            sharded.iter().all(Option::is_some),
            "churn never drains this sequence"
        );
    }
    // and the single-shard path is itself thread-count-invariant
    assert_eq!(drive_sharded(1, 1, 16), drive_sharded(2, 1, 16));
}

#[test]
fn composed_plans_are_feasible_sorted_and_thread_count_invariant() {
    let gpus = 32u32;
    let one = drive_sharded(1, 2, gpus);
    let two = drive_sharded(2, 2, gpus);
    assert_eq!(one, two, "sharded composition diverged across thread counts");
    for (i, s) in one.iter().enumerate() {
        let (groups, step_bits, used) =
            s.as_ref().unwrap_or_else(|| panic!("snapshot {i} drained"));
        assert!(*used <= gpus, "snapshot {i} oversubscribed: {used} > {gpus}");
        assert!(f64::from_bits(*step_bits) > 0.0, "snapshot {i} zero step time");
        for w in groups.windows(2) {
            assert!(
                (w[0].0.n(), w[0].0.tp) <= (w[1].0.n(), w[1].0.tp),
                "snapshot {i} groups unsorted: {groups:?}"
            );
        }
    }
}

fn serve_sharded(seed: u64) -> (usize, ServeReport) {
    let (cost, cluster) = world(32);
    let mut o = ServeOptions::default();
    o.replan_budget = Some(30.0);
    o.meter = BudgetMeter::SimPerPlan(1e-4);
    o.slice_plans = 4096;
    o.certify_identity = false;
    o.tail_steps = 2;
    o.shards = 2;
    o.rebalance_every = 32;
    o.planner = fast_opts();
    let trace = gen_churn_trace(6, seed);
    let arrivals = trace
        .iter()
        .filter(|e| matches!(e.event, Event::Arrive(_)))
        .count();
    (arrivals, ServeRuntime::new(&cost, &cluster, o).run_trace(&trace))
}

#[test]
fn sharded_churn_trace_keeps_the_admission_ledger_consistent() {
    let (arrivals, report) = serve_sharded(23);
    // every arrival is accounted for: a tenant record (admitted, queued,
    // or still waiting) or an explicit rejection — never silently dropped
    assert_eq!(
        report.tenants.len() + report.rejected_arrivals as usize,
        arrivals,
        "tenant ledger lost an arrival"
    );
    assert!(report.steps_total > 0);
    let admitted =
        report.tenants.iter().filter(|t| t.admitted_at.is_some()).count();
    assert!(admitted > 0, "nothing was ever admitted");
    if let Some(j) = report.jain_fairness() {
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "Jain index out of range: {j}");
    }
    for (tier, tta) in report.tta_by_tier() {
        assert!(tta >= 0.0, "negative time-to-admission for tier {tier}");
    }
    // deterministic sim meter: the whole serve reproduces bit-for-bit
    let (_, again) = serve_sharded(23);
    assert_eq!(report.steps_total, again.steps_total);
    assert_eq!(report.replan_windows, again.replan_windows);
    assert_eq!(report.rejected_arrivals, again.rejected_arrivals);
    assert_eq!(report.queued_admissions, again.queued_admissions);
    assert_eq!(report.preemptions, again.preemptions);
    assert_eq!(report.rebalances, again.rebalances);
    assert_eq!(report.replan_slices_total, again.replan_slices_total);
    assert_eq!(report.plans_enumerated_total, again.plans_enumerated_total);
}

#[test]
fn preemption_never_evicts_an_equal_or_higher_tier() {
    let (cost, cluster) = world(16);
    let initial = TaskSet::new(vec![
        long("bg-1").with_tier(3),
        long("bg-2").with_tier(3),
    ]);
    let mut mgr = ShardManager::new(&cost, &cluster, initial, fast_opts(), 2);
    // same tier: may queue or plan, must never preempt a peer
    mgr.apply_event(Event::Arrive(long("peer").with_tier(3)));
    assert_eq!(mgr.preemptions, 0, "preempted a same-tier tenant");
    // higher priority: whatever the outcome, it is never a rejection —
    // the arrival is servable on this cluster, so it is admitted (possibly
    // after preempting tier-3 tenants) or held in the queue
    let out = mgr.apply_event(Event::Arrive(long("urgent").with_tier(0)));
    assert_ne!(out, Outcome::Rejected, "servable tier-0 arrival rejected");
    // conservation: every tenant is live or held — nobody is silently lost
    // (3 live arrivals so far, minus the same-tier peer if it was queued
    // and stayed there; preempted tenants re-enter the queue)
    assert!(mgr.fleet_tasks().len() + mgr.queue_len() >= 3, "tenants lost");
}
