//! Randomized property tests for the dynamic bucketing DP (Eq. 4).

use lobra::coordinator::bucketing::{
    bucketize, bucketize_bruteforce, buckets_from_boundaries, padding_for,
    BucketingOptions,
};
use lobra::util::Rng;

fn random_lengths(rng: &mut Rng, n: usize, max: u32) -> Vec<u32> {
    (0..n).map(|_| 1 + rng.below(max as u64) as u32).collect()
}

#[test]
fn dp_is_optimal_vs_bruteforce() {
    let mut rng = Rng::new(42);
    for trial in 0..200 {
        let n = 1 + rng.below(40) as usize;
        let lengths = random_lengths(&mut rng, n, 1200);
        let r = 1 + rng.below(4) as usize;
        let opts = BucketingOptions { max_buckets: r, interval: 100, max_intervals: 64 };
        let dp = bucketize(&lengths, &opts);
        let bf = bucketize_bruteforce(&lengths, 100, r);
        assert_eq!(
            dp.padding_tokens, bf,
            "trial {trial}: dp {} != brute force {bf} (lengths {lengths:?}, R={r})",
            dp.padding_tokens
        );
    }
}

#[test]
fn boundaries_cover_and_counts_conserve() {
    let mut rng = Rng::new(7);
    for _ in 0..200 {
        let n = 1 + rng.below(500) as usize;
        let lengths = random_lengths(&mut rng, n, 16384);
        let opts = BucketingOptions::default();
        let b = bucketize(&lengths, &opts);
        assert!(*b.boundaries.last().unwrap() >= *lengths.iter().max().unwrap());
        assert_eq!(b.counts.iter().sum::<u64>(), n as u64);
        assert!(b.boundaries.windows(2).all(|w| w[0] < w[1]), "not ascending");
        assert!(b.boundaries.len() <= opts.max_buckets);
    }
}

#[test]
fn padding_consistent_with_padding_for() {
    let mut rng = Rng::new(9);
    for _ in 0..100 {
        let lengths = random_lengths(&mut rng, 200, 8000);
        let opts = BucketingOptions { max_buckets: 8, interval: 256, max_intervals: 128 };
        let b = bucketize(&lengths, &opts);
        // recompute padding against the chosen boundaries
        let recomputed = padding_for(&lengths, &b.boundaries);
        assert_eq!(b.padding_tokens, recomputed);
    }
}

#[test]
fn monotone_in_max_buckets() {
    let mut rng = Rng::new(11);
    for _ in 0..50 {
        let lengths = random_lengths(&mut rng, 300, 16000);
        let mut prev = u64::MAX;
        for r in [1usize, 2, 4, 8, 16, 32] {
            let b = bucketize(
                &lengths,
                &BucketingOptions { max_buckets: r, interval: 256, max_intervals: 128 },
            );
            assert!(
                b.padding_tokens <= prev,
                "padding increased at R={r}: {} > {prev}",
                b.padding_tokens
            );
            prev = b.padding_tokens;
        }
    }
}

#[test]
fn fixed_boundary_buckets_consistent() {
    let mut rng = Rng::new(13);
    for _ in 0..100 {
        let lengths = random_lengths(&mut rng, 150, 10000);
        let boundaries = vec![512, 2048, 4096, 16384];
        let b = buckets_from_boundaries(&lengths, &boundaries);
        assert_eq!(b.counts.iter().sum::<u64>(), 150);
        assert_eq!(b.padding_tokens, padding_for(&lengths, &boundaries));
        // every length ≤ its bucket boundary
        for &l in &lengths {
            let j = b.bucket_of(l);
            assert!(boundaries[j] >= l || j == boundaries.len() - 1);
        }
    }
}

#[test]
fn degenerate_inputs() {
    let opts = BucketingOptions::default();
    // all identical lengths → 1 bucket, zero padding
    let b = bucketize(&[777; 50], &opts);
    assert_eq!(b.padding_tokens, (777u64.div_ceil(256) * 256 - 777) * 50);
    // single sequence
    let b1 = bucketize(&[5], &opts);
    assert_eq!(b1.counts.iter().sum::<u64>(), 1);
    // empty
    let be = bucketize(&[], &opts);
    assert_eq!(be.padding_tokens, 0);
}
