//! Async planner service certification — the `tests/session_replan.rs`
//! pattern lifted to the off-thread service:
//!
//!  * a plan published by the [`PlannerService`] for a completed (`done`)
//!    search is plan-identical — same `groups`, bit-identical
//!    `expected_step_time` — to a cold `Planner::plan` on the same task
//!    set, across a churn sequence AND across service thread counts (the
//!    scoped worker count changes wall timing, never plans);
//!  * supersession is epoch-correct: when a submit immediately supersedes
//!    another, the terminal published state is the newest epoch with the
//!    newest task set's plan — a stale search can never win;
//!  * an infeasible task set publishes a terminal "no plan" verdict
//!    instead of wedging the service.
//!
//! The waits are bounded polls on the lock-free publication cell — no
//! sleeps inside assertions, so the *plans* checked are exactly what the
//! serving runtime would adopt at a step boundary.

use std::sync::Arc;
use std::time::Duration;

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, TaskSet, TaskSpec};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::runtime::BudgetMeter;
use lobra::coordinator::service::{PlanUpdate, PlannerService};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;

fn world(n_gpus: u32) -> (CostModel, ClusterSpec) {
    let cluster = ClusterSpec::a100_40g(n_gpus);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster)
}

fn spec_pool() -> Vec<TaskSpec> {
    vec![
        TaskSpec::new("qa-short", 128, LengthDistribution::fit(210.0, 6.0, 16, 2048)),
        TaskSpec::new("code-instr", 96, LengthDistribution::fit(280.0, 8.0, 16, 2048)),
        TaskSpec::new("evol-like", 64, LengthDistribution::fit(700.0, 6.5, 16, 8192)),
        TaskSpec::new("meetings", 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384)),
    ]
}

fn fast_opts() -> PlannerOptions {
    let mut opts = PlannerOptions::default();
    opts.calibration_multiple = 25;
    opts.eval_batches = 2;
    opts.max_evaluated = 300;
    opts
}

/// Poll until the service publishes a terminal update for `epoch`.
/// Bounded at ~2 minutes of 1 ms waits so a wedged service fails loudly
/// instead of hanging CI.
fn wait_final(svc: &PlannerService, epoch: u64) -> Arc<PlanUpdate> {
    for _ in 0..120_000u32 {
        if let Some((_, u)) = svc.poll() {
            if u.epoch == epoch {
                return u;
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("planner service never published epoch {epoch}");
}

#[test]
fn async_service_plans_are_cold_identical_across_thread_counts() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = fast_opts();
    let pool = spec_pool();
    // a churn sequence: grow, shrink, re-grow — exercises the service
    // session's warm-start memo between requests
    let sequence: Vec<TaskSet> = vec![
        TaskSet::new(vec![pool[0].clone()]),
        TaskSet::new(vec![pool[0].clone(), pool[2].clone()]),
        TaskSet::new(vec![pool[0].clone(), pool[2].clone(), pool[3].clone()]),
        TaskSet::new(vec![pool[2].clone(), pool[3].clone()]),
        TaskSet::new(vec![pool[1].clone(), pool[2].clone(), pool[3].clone()]),
    ];
    // the ISSUE's acceptance bar: identity must hold for ≥ 2 thread counts
    for threads in [1usize, 4] {
        let mut svc = PlannerService::spawn(
            cost.clone(),
            cluster.clone(),
            opts.clone(),
            BudgetMeter::SimPerPlan(1e-4),
            512, // small slices: every search spans many cancellation checks
            threads,
        );
        for (step, tasks) in sequence.iter().enumerate() {
            let epoch = svc.submit(tasks.clone(), None, true);
            let u = wait_final(&svc, epoch);
            assert!(u.done, "threads={threads} step={step}: unlimited budget must complete");
            assert!(!u.exhausted, "threads={threads} step={step}");
            assert!(u.n_enumerated > 0 && u.slices > 0, "threads={threads} step={step}");
            let plan = u
                .plan
                .clone()
                .unwrap_or_else(|| panic!("threads={threads} step={step}: no plan"));
            let cold = planner.plan(tasks, opts.clone()).expect("plannable world");
            assert_eq!(
                plan.groups, cold.groups,
                "threads={threads} step={step}: async plan diverged from cold"
            );
            assert_eq!(
                plan.expected_step_time.to_bits(),
                cold.expected_step_time.to_bits(),
                "threads={threads} step={step}: step time not bit-identical to cold"
            );
        }
    }
}

#[test]
fn supersession_lands_on_the_newest_epoch_and_task_set() {
    let (cost, cluster) = world(16);
    let planner = Planner::new(&cost, &cluster);
    let opts = fast_opts();
    let pool = spec_pool();
    let small = TaskSet::new(vec![pool[0].clone()]);
    let big = TaskSet::new(vec![pool[0].clone(), pool[2].clone(), pool[3].clone()]);
    let newest = TaskSet::new(vec![pool[1].clone(), pool[3].clone()]);

    let mut svc = PlannerService::spawn(
        cost.clone(),
        cluster.clone(),
        opts.clone(),
        BudgetMeter::SimPerPlan(1e-4),
        256,
        2,
    );
    // settle one search, then fire two back-to-back: the second submit
    // cancels the first mid-flight (or drains it unstarted — both are
    // valid supersession paths; neither may leak a stale-epoch plan)
    let e1 = svc.submit(small.clone(), None, true);
    let u1 = wait_final(&svc, e1);
    assert!(u1.done);
    let e2 = svc.submit(big, None, true);
    let e3 = svc.submit(newest.clone(), None, true);
    assert!(e3 > e2 && e2 > e1, "epochs must be strictly increasing");
    let u3 = wait_final(&svc, e3);
    assert!(u3.done);
    let cold = planner.plan(&newest, opts.clone()).expect("plannable world");
    let plan = u3.plan.clone().expect("feasible world");
    assert_eq!(plan.groups, cold.groups, "superseding search must serve its own task set");
    assert_eq!(plan.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
    // the cell is monotone: once the newest epoch landed, polls never
    // regress to the superseded epoch
    for _ in 0..100 {
        let (cell_epoch, u) = svc.poll().expect("published");
        assert_eq!(cell_epoch, e3);
        assert_eq!(u.epoch, e3);
    }
}

#[test]
fn unplannable_task_set_publishes_terminal_no_plan() {
    // An empty task set is the deterministic "no plan can exist" case
    // (`begin_anytime` rejects it before any enumeration): the service
    // must answer `done` with no plan, not hang or invent one.
    let (cost, cluster) = world(16);
    let opts = fast_opts();
    let mut svc = PlannerService::spawn(
        cost.clone(),
        cluster.clone(),
        opts,
        BudgetMeter::SimPerPlan(1e-4),
        256,
        1,
    );
    let epoch = svc.submit(TaskSet::new(Vec::new()), None, true);
    let u = wait_final(&svc, epoch);
    assert!(u.done, "unplannable verdict is terminal");
    assert!(u.plan.is_none(), "no feasible plan may be invented");
    assert_eq!(u.n_enumerated, 0);
}
