//! Integration tests of the full simulated joint-FT loop (scheduler +
//! dispatcher + bucketing + cost model + ledger) and the tenant manager.

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::dispatcher::DispatchPolicy;
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::coordinator::tasks::{Event, Outcome, TaskManager};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::prelude::{TaskSet, TaskSpec};

fn world_7b16() -> (CostModel, ClusterSpec, TaskSet) {
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    (cost, cluster, TaskSet::paper_7b_subset())
}

#[test]
fn every_step_dispatches_whole_batch() {
    let (cost, cluster, tasks) = world_7b16();
    let planner = Planner::new(&cost, &cluster);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
    let b = tasks.joint_batch() as u64;
    for _ in 0..20 {
        let rep = sched.step().unwrap();
        assert_eq!(rep.dispatch.total_sequences(), b, "lost sequences");
        assert!(rep.step_time > 0.0);
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-9);
        assert!((0.0..1.0).contains(&rep.padding_ratio));
    }
}

#[test]
fn policies_ordering_over_many_seeds() {
    // balanced ≤ length-based on GPU seconds, across seeds (robustness)
    let (cost, cluster, tasks) = world_7b16();
    let planner = Planner::new(&cost, &cluster);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    for seed in [1u64, 17, 99] {
        let mut o_lb = SchedulerOptions::default();
        o_lb.policy = DispatchPolicy::LengthBased;
        o_lb.seed = seed;
        let mut o_bal = SchedulerOptions::default();
        o_bal.seed = seed;
        let lb = Scheduler::new(&cost, &plan, &tasks, o_lb).run_steps(15);
        let bal = Scheduler::new(&cost, &plan, &tasks, o_bal).run_steps(15);
        assert!(
            bal.gpu_seconds_per_step <= lb.gpu_seconds_per_step * 1.01,
            "seed {seed}: balanced {} > length-based {}",
            bal.gpu_seconds_per_step,
            lb.gpu_seconds_per_step
        );
    }
}

#[test]
fn report_aggregation_consistency() {
    let (cost, cluster, tasks) = world_7b16();
    let planner = Planner::new(&cost, &cluster);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
    let rep = sched.run_steps(10);
    assert_eq!(rep.steps, 10);
    let mean_from_steps: f64 =
        sched.steps().iter().map(|s| s.gpu_seconds).sum::<f64>() / 10.0;
    assert!((rep.gpu_seconds_per_step - mean_from_steps).abs() < 1e-9);
    // std within the paper's 10% protocol bound (we assert < 25% — ours is
    // a simulator, the check is that variance is not wild)
    assert!(rep.gpu_seconds_std < rep.gpu_seconds_per_step * 0.25);
}

#[test]
fn task_manager_lifecycle_roundtrip() {
    let (cost, cluster, _) = world_7b16();
    let initial = TaskSet::new(vec![
        TaskSpec::new("a", 64, LengthDistribution::fit(200.0, 2.0, 16, 1024)),
        TaskSpec::new("b", 64, LengthDistribution::fit(400.0, 1.5, 16, 2048)),
    ]);
    let mut mgr = TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
    assert!(mgr.plan().is_some());
    // arrival of a long task
    let out = mgr.handle(Event::Arrive(TaskSpec::new(
        "long",
        16,
        LengthDistribution::fit(5000.0, 0.8, 64, 14000),
    )));
    assert_ne!(out, Outcome::Drained);
    assert_eq!(mgr.tasks().len(), 3);
    // exits back down to empty
    for name in ["a", "b", "long"] {
        mgr.handle(Event::Exit { name: name.into() });
    }
    assert!(mgr.plan().is_none());
    assert!(mgr.tasks().is_empty());
}

#[test]
fn failure_injection_unschedulable_long_tail() {
    // a task whose sequences exceed every config's capacity must make
    // dispatch fail gracefully (None), not panic
    let (cost, cluster, _) = world_7b16();
    let tasks = TaskSet::new(vec![TaskSpec::new(
        "t",
        8,
        LengthDistribution::fit(200.0, 2.0, 16, 1024),
    )]);
    let planner = Planner::new(&cost, &cluster);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    // now feed the scheduler a *different* task set with monstrous lengths
    let monster = TaskSet::new(vec![TaskSpec::new(
        "monster",
        8,
        LengthDistribution::lognormal(12.0, 0.1, 100_000, 200_000),
    )]);
    let mut sched = Scheduler::new(&cost, &plan, &monster, SchedulerOptions::default());
    assert!(sched.step().is_none(), "expected graceful failure");
}

#[test]
fn single_task_single_replica_still_works() {
    let (cost, _, _) = world_7b16();
    let cluster1 = ClusterSpec::a100_40g(2);
    let cost1 = CostModel::calibrated(&cost.model, &cluster1);
    let tasks = TaskSet::new(vec![TaskSpec::new(
        "only",
        16,
        LengthDistribution::fit(300.0, 1.5, 16, 2048),
    )]);
    let planner = Planner::new(&cost1, &cluster1);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let rep = Scheduler::new(&cost1, &plan, &tasks, SchedulerOptions::default())
        .run_steps(5);
    assert_eq!(rep.steps, 5);
    assert!(rep.gpu_seconds_per_step > 0.0);
}

#[test]
fn seeds_reproduce_exactly() {
    let (cost, cluster, tasks) = world_7b16();
    let planner = Planner::new(&cost, &cluster);
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let r1 = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default()).run_steps(8);
    let r2 = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default()).run_steps(8);
    assert_eq!(r1.gpu_seconds_per_step, r2.gpu_seconds_per_step);
    assert_eq!(r1.mean_padding_ratio, r2.mean_padding_ratio);
}
