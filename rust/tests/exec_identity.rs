//! Certifies the exec-layer refactor changed *where* the step arithmetic
//! lives, not *what* it computes: `Scheduler::step` (now a thin loop over
//! `SimExecutor`) produces bit-identical step times / GPU-seconds to the
//! pre-refactor inline computation (re-implemented here verbatim), across
//! seeds, dispatch policies, and bucketing modes.
//!
//! The `LOBRA_NUM_THREADS` determinism property lives in its own binary
//! (`tests/par_determinism.rs`): it mutates the process environment, which
//! must not race with this binary's concurrent env readers.

use lobra::cluster::ClusterSpec;
use lobra::config::{ModelDesc, TaskSet};
use lobra::coordinator::bucketing::bucketize;
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::costmodel::CostModel;
use lobra::data::MultiTaskSampler;

fn world() -> (CostModel, DeploymentPlan, TaskSet) {
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
    let tasks = TaskSet::paper_7b_subset();
    let plan = Planner::new(&cost, &cluster)
        .plan(&tasks, PlannerOptions::default())
        .unwrap();
    (cost, plan, tasks)
}

/// The pre-refactor `Scheduler::step` arithmetic, verbatim: sample →
/// bucketize → dispatch → report the solve's predicted step time.
fn legacy_step_times(
    cost: &CostModel,
    plan: &DeploymentPlan,
    tasks: &TaskSet,
    opts: &SchedulerOptions,
    steps: usize,
) -> Vec<(u64, u64)> {
    // a never-stepped scheduler reproduces the fixed-boundary calibration
    // (seeded identically) and serves as the bucketing oracle
    let oracle = Scheduler::new(cost, plan, tasks, opts.clone());
    let mut sampler = MultiTaskSampler::new(tasks, opts.seed);
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let lengths = sampler.next_batch().lengths();
        let buckets = if opts.dynamic_bucketing {
            bucketize(&lengths, &opts.bucketing)
        } else {
            oracle.buckets_for(&lengths)
        };
        let dispatch = Dispatcher::new(cost, plan)
            .dispatch(&buckets, opts.policy)
            .expect("legacy dispatch must succeed");
        let step_time = dispatch.predicted_step_time;
        let gpu_seconds = plan.gpus_used() as f64 * step_time;
        out.push((step_time.to_bits(), gpu_seconds.to_bits()));
    }
    out
}

#[test]
fn executor_step_times_bit_identical_to_pre_refactor() {
    let (cost, plan, tasks) = world();
    for seed in [1u64, 7, 42] {
        for policy in [DispatchPolicy::Balanced, DispatchPolicy::LengthBased] {
            for dynamic in [true, false] {
                let mut opts = SchedulerOptions::default();
                opts.seed = seed;
                opts.policy = policy;
                opts.dynamic_bucketing = dynamic;
                let legacy = legacy_step_times(&cost, &plan, &tasks, &opts, 8);
                let mut sched = Scheduler::new(&cost, &plan, &tasks, opts);
                for (i, &(t_bits, g_bits)) in legacy.iter().enumerate() {
                    let rep = sched.step().unwrap();
                    assert_eq!(
                        rep.step_time.to_bits(),
                        t_bits,
                        "seed {seed} {policy:?} dynamic={dynamic} step {i}: step_time drifted"
                    );
                    assert_eq!(
                        rep.gpu_seconds.to_bits(),
                        g_bits,
                        "seed {seed} {policy:?} dynamic={dynamic} step {i}: gpu_seconds drifted"
                    );
                }
            }
        }
    }
}

#[test]
fn executor_reports_dispatch_solve_not_round_robin() {
    // the dispatch the report carries is the MINMAX solve the executor ran:
    // its replica times must re-derive the reported step time exactly
    let (cost, plan, tasks) = world();
    let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
    for _ in 0..5 {
        let rep = sched.step().unwrap();
        let busiest = rep
            .dispatch
            .replica_times
            .iter()
            .map(|&(_, t)| t)
            .fold(0.0f64, f64::max);
        let sync = cost.sync_time(plan.n_replicas(), plan.n_tasks.max(1));
        assert_eq!(rep.step_time.to_bits(), (busiest + sync).to_bits());
        // per-replica loads recorded by the solve partition the demand
        let assigned: u64 = rep
            .dispatch
            .replica_assignments
            .iter()
            .flatten()
            .map(|l| l.count)
            .sum();
        assert_eq!(assigned, rep.dispatch.total_sequences());
    }
}

