//! Offline in-tree stand-in for the `anyhow` crate.
//!
//! Provides the exact subset this repository uses — [`Error`], [`Result`],
//! [`anyhow!`], [`bail!`], and [`Context`] — so the offline build needs no
//! crates.io access. Swap the path dependency for the real `anyhow` when a
//! registry is available; no call sites need to change.

use std::fmt;

/// A message-carrying error (message only — no backtraces or chains).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Any std error converts via `?`, like the real crate.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to an error (message-prefix semantics).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::{Context, Result};

    #[test]
    fn macros_and_context() {
        fn fails() -> Result<()> {
            bail!("bad {}", 42)
        }
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad 42");
        let e = anyhow!("x={}", 1);
        assert_eq!(format!("{e:?}"), "x=1");
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}
