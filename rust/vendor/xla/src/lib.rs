//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container building this repository has no XLA/PJRT shared library,
//! so the runtime engine compiles against this API-compatible stub; every
//! constructor returns an error at *runtime* ("PJRT unavailable"), which
//! `Engine::load` surfaces as a normal error. Swap this path dependency for
//! the real bindings to run `lobra train` end to end — the planning and
//! simulation paths never touch this crate.

use std::fmt;

/// Error type mirroring xla-rs's (callers only consume `Debug`).
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime not available in this offline build"
    )))
}

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        let e = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(format!("{e:?}").contains("PJRT runtime not available"));
    }
}
