//! Native (pure-Rust) realization of the LobRA transformer train step.
//!
//! The vendored `xla` crate is a path stub offline, so `Engine::load` can
//! never execute a compiled artifact in this container. This module
//! reproduces the Python reference graph (`python/compile/model.py`) in
//! plain Rust so tp/pp parallel configs can actually *run*: the
//! `StagedEngine` (`runtime::staged`) drives the per-layer forward /
//! backward building blocks exposed here through a 1F1B microbatch
//! pipeline, and tensor parallelism shards the four base matmuls per
//! layer column/row-wise (see [`proj_forward`]).
//!
//! Numerics contract:
//! - parameters are flat `f32` vectors ([`ParamVector`], same layout
//!   discipline as the manifest path: a `ParamEntry` table with per-leaf
//!   init rules);
//! - activations and gradient accumulation are `f64` (this is what makes
//!   the finite-difference gradient check in the tests sharp), cast to
//!   `f32` only at the microbatch boundary;
//! - every reduction is an explicit fixed-order loop or a
//!   [`tree_reduce`] combine, so results are bitwise independent of
//!   thread count (detlint R5/R6 apply to this file).
//!
//! Tensor-parallel sharding follows Megatron: `qkv`/`up` are
//! column-parallel (forward needs no communication — the per-element
//! accumulation order over the contraction dim is identical for every
//! tp, so tp>1 forward is *bit-identical* to tp=1 here), `out`/`down`
//! are row-parallel (forward partial sums combine through a timed
//! deterministic tree all-reduce). LoRA adapters are rank-`r` skinny and
//! replicated on every tp rank, as in the paper's setup.

use super::engine::StepOutput;
use super::manifest::{InitKind, ParamEntry};
use super::params::ParamVector;
use crate::util::clock::Stopwatch;
use crate::util::par::tree_reduce;
use anyhow::{anyhow, Result};

/// PAD token id (python/compile/model.py: `PAD_ID = 0`).
pub const PAD_ID: i32 = 0;
const LN_EPS: f64 = 1e-5;
const MASK_NEG: f64 = -1e30;

/// Architecture + microbatch-shape description for the native model.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_tasks: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub rope_theta: f64,
    /// Microbatch `(b, s)` shapes this model executes, ascending by seq.
    pub shapes: Vec<(u64, u64)>,
}

impl NativeSpec {
    /// Smallest spec that still exercises every code path (multi-head
    /// attention with RoPE, multi-task LoRA, two shapes). Sized so debug
    /// (unoptimized) test builds run full pipelines in milliseconds.
    pub fn micro() -> Self {
        Self {
            name: "native-micro".to_string(),
            vocab: 64,
            d_model: 16,
            n_layers: 4,
            n_heads: 2,
            d_ff: 32,
            n_tasks: 2,
            lora_rank: 2,
            lora_alpha: 4.0,
            rope_theta: 10_000.0,
            shapes: vec![(4, 8), (2, 16)],
        }
    }
}

/// The four LoRA-adapted projections per transformer layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Proj {
    Qkv,
    Out,
    Up,
    Down,
}

pub(crate) const PROJS: [Proj; 4] = [Proj::Qkv, Proj::Out, Proj::Up, Proj::Down];

impl Proj {
    fn idx(self) -> usize {
        match self {
            Proj::Qkv => 0,
            Proj::Out => 1,
            Proj::Up => 2,
            Proj::Down => 3,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Proj::Qkv => "qkv",
            Proj::Out => "out",
            Proj::Up => "up",
            Proj::Down => "down",
        }
    }

    fn dims(self, d: usize, ff: usize) -> (usize, usize) {
        match self {
            Proj::Qkv => (d, 3 * d),
            Proj::Out => (d, d),
            Proj::Up => (d, ff),
            Proj::Down => (ff, d),
        }
    }

    /// Row-parallel projections shard the contraction dim under tp, so
    /// their forward needs the all-reduce; column-parallel ones don't.
    fn row_parallel(self) -> bool {
        matches!(self, Proj::Out | Proj::Down)
    }
}

/// Base-parameter offsets for one layer (into the flat base vector).
#[derive(Debug, Clone, Copy)]
struct LayerOffsets {
    ln1_g: usize,
    ln1_b: usize,
    ln2_g: usize,
    ln2_b: usize,
    /// `w[Proj::idx()]` — the four dense weights, row-major `[fin, fout]`.
    w: [usize; 4],
}

/// LoRA-parameter offsets for one layer (into the flat LoRA vector).
/// For each projection the `B` stack `[T, fin, r]` is immediately
/// followed by the `A` stack `[T, r, fout]` (layer_backward relies on
/// that adjacency to split one mutable gradient slice).
#[derive(Debug, Clone, Copy)]
struct LoraLayerOffsets {
    b: [usize; 4],
    a: [usize; 4],
}

/// Per-projection geometry passed to the sharded matmul kernels.
struct ProjDims {
    fin: usize,
    fout: usize,
    rank: usize,
    scale: f64,
    row_parallel: bool,
}

/// Forward activations one layer must retain for its backward pass.
pub(crate) struct LayerCache {
    rstd1: Vec<f64>,
    xhat1: Vec<f64>,
    xn1: Vec<f64>,
    u_qkv: Vec<f64>,
    q: Vec<f64>,
    k: Vec<f64>,
    v: Vec<f64>,
    probs: Vec<f64>,
    ctx: Vec<f64>,
    u_out: Vec<f64>,
    rstd2: Vec<f64>,
    xhat2: Vec<f64>,
    xn2: Vec<f64>,
    u_up: Vec<f64>,
    up: Vec<f64>,
    act: Vec<f64>,
    u_down: Vec<f64>,
}

/// Loss-head outputs (all `f64`; cast at the StepOutput boundary).
pub(crate) struct LossParts {
    pub(crate) mean_loss: f64,
    pub(crate) total_tokens: f64,
    pub(crate) task_loss: Vec<f64>,
    pub(crate) task_tokens: Vec<f64>,
}

/// The native model: spec + param tables + precomputed leaf offsets.
pub struct NativeModel {
    spec: NativeSpec,
    base_table: Vec<ParamEntry>,
    lora_table: Vec<ParamEntry>,
    base_len: u64,
    lora_len: u64,
    embed: usize,
    layers: Vec<LayerOffsets>,
    lora_layers: Vec<LoraLayerOffsets>,
    lnf_g: usize,
    lnf_b: usize,
}

impl NativeModel {
    pub fn new(spec: NativeSpec) -> Result<Self> {
        if spec.d_model == 0 || spec.n_heads == 0 || spec.d_model % spec.n_heads != 0 {
            return Err(anyhow!(
                "d_model {} must be a positive multiple of n_heads {}",
                spec.d_model,
                spec.n_heads
            ));
        }
        let head_dim = spec.d_model / spec.n_heads;
        if head_dim % 2 != 0 {
            return Err(anyhow!("head_dim {head_dim} must be even for RoPE"));
        }
        if spec.vocab == 0 || spec.n_layers == 0 || spec.d_ff == 0 {
            return Err(anyhow!("vocab/n_layers/d_ff must all be positive"));
        }
        if spec.n_tasks == 0 || spec.lora_rank == 0 {
            return Err(anyhow!("n_tasks/lora_rank must be positive"));
        }
        if spec.shapes.is_empty() {
            return Err(anyhow!("spec needs at least one microbatch shape"));
        }
        let (d, ff, t, r) = (spec.d_model, spec.d_ff, spec.n_tasks, spec.lora_rank);
        let dense_std = |fin: usize| InitKind::Normal { std: 1.0 / (fin as f64).sqrt() };

        let mut base_table = Vec::new();
        let mut off = 0u64;
        let embed = push_leaf(
            &mut base_table,
            &mut off,
            "['embed']".to_string(),
            vec![spec.vocab as u64, d as u64],
            InitKind::Normal { std: 0.02 },
        );
        let mut layers = Vec::with_capacity(spec.n_layers);
        for li in 0..spec.n_layers {
            let ln1_g = push_leaf(
                &mut base_table,
                &mut off,
                format!("['layers'][{li}]['ln1_g']"),
                vec![d as u64],
                InitKind::Ones,
            );
            let ln1_b = push_leaf(
                &mut base_table,
                &mut off,
                format!("['layers'][{li}]['ln1_b']"),
                vec![d as u64],
                InitKind::Zeros,
            );
            let ln2_g = push_leaf(
                &mut base_table,
                &mut off,
                format!("['layers'][{li}]['ln2_g']"),
                vec![d as u64],
                InitKind::Ones,
            );
            let ln2_b = push_leaf(
                &mut base_table,
                &mut off,
                format!("['layers'][{li}]['ln2_b']"),
                vec![d as u64],
                InitKind::Zeros,
            );
            let mut w = [0usize; 4];
            for p in PROJS {
                let (fin, fout) = p.dims(d, ff);
                w[p.idx()] = push_leaf(
                    &mut base_table,
                    &mut off,
                    format!("['layers'][{li}]['w_{}']", p.tag()),
                    vec![fin as u64, fout as u64],
                    dense_std(fin),
                );
            }
            layers.push(LayerOffsets { ln1_g, ln1_b, ln2_g, ln2_b, w });
        }
        let lnf_g = push_leaf(
            &mut base_table,
            &mut off,
            "['ln_f_g']".to_string(),
            vec![d as u64],
            InitKind::Ones,
        );
        let lnf_b = push_leaf(
            &mut base_table,
            &mut off,
            "['ln_f_b']".to_string(),
            vec![d as u64],
            InitKind::Zeros,
        );
        let base_len = off;

        let mut lora_table = Vec::new();
        let mut loff = 0u64;
        let mut lora_layers = Vec::with_capacity(spec.n_layers);
        for li in 0..spec.n_layers {
            let mut b_off = [0usize; 4];
            let mut a_off = [0usize; 4];
            for p in PROJS {
                let (fin, fout) = p.dims(d, ff);
                b_off[p.idx()] = push_leaf(
                    &mut lora_table,
                    &mut loff,
                    format!("['layers'][{li}]['{}_lora_b']", p.tag()),
                    vec![t as u64, fin as u64, r as u64],
                    dense_std(fin),
                );
                a_off[p.idx()] = push_leaf(
                    &mut lora_table,
                    &mut loff,
                    format!("['layers'][{li}]['{}_lora_a']", p.tag()),
                    vec![t as u64, r as u64, fout as u64],
                    InitKind::Zeros,
                );
            }
            lora_layers.push(LoraLayerOffsets { b: b_off, a: a_off });
        }
        let lora_len = loff;

        Ok(Self {
            spec,
            base_table,
            lora_table,
            base_len,
            lora_len,
            embed,
            layers,
            lora_layers,
            lnf_g,
            lnf_b,
        })
    }

    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    pub fn shapes(&self) -> Vec<(u64, u64)> {
        self.spec.shapes.clone()
    }

    pub fn n_layers(&self) -> usize {
        self.spec.n_layers
    }

    pub fn base_param_count(&self) -> u64 {
        self.base_len
    }

    pub fn lora_param_count(&self) -> u64 {
        self.lora_len
    }

    pub fn base_table(&self) -> &[ParamEntry] {
        &self.base_table
    }

    pub fn lora_table(&self) -> &[ParamEntry] {
        &self.lora_table
    }

    /// Fresh base/LoRA vectors from the per-leaf init rules. Same
    /// contract as `Engine::init_params`: one seed drives both, with a
    /// fixed LoRA offset ("LoRA" in ASCII).
    pub fn init_params(&self, seed: u64) -> (ParamVector, ParamVector) {
        let base = ParamVector::init(&self.base_table, self.base_len, seed);
        let lora = ParamVector::init(&self.lora_table, self.lora_len, seed ^ 0x4c6f_5241);
        (base, lora)
    }

    /// Validate a microbatch against the model contract; returns `(b, s)`
    /// as `usize`. Mirrors `Engine::run`'s checks (sorted seg_ids etc.).
    pub(crate) fn validate(
        &self,
        shape: (u64, u64),
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<(usize, usize)> {
        let (b, s) = (shape.0 as usize, shape.1 as usize);
        if b == 0 || s == 0 {
            return Err(anyhow!("degenerate microbatch shape {shape:?}"));
        }
        if tokens.len() != b * s {
            return Err(anyhow!("tokens len {} != {b}x{s}", tokens.len()));
        }
        if seg_ids.len() != b {
            return Err(anyhow!("seg_ids len {} != {b}", seg_ids.len()));
        }
        if !seg_ids.windows(2).all(|w| w[0] <= w[1]) {
            return Err(anyhow!("seg_ids must be sorted (kernel layout contract)"));
        }
        for &g in seg_ids {
            if g < 0 || g as usize >= self.spec.n_tasks {
                return Err(anyhow!("seg id {g} outside 0..{}", self.spec.n_tasks));
            }
        }
        for &tok in tokens {
            if tok < 0 || tok as usize >= self.spec.vocab {
                return Err(anyhow!("token {tok} outside vocab 0..{}", self.spec.vocab));
            }
        }
        Ok((b, s))
    }

    /// `h = embed[tokens]` (frozen lookup, `[b*s, d]` in f64).
    pub(crate) fn embed_forward(&self, base: &[f32], tokens: &[i32], b: usize, s: usize) -> Vec<f64> {
        let d = self.spec.d_model;
        let embed = &base[self.embed..self.embed + self.spec.vocab * d];
        let mut h = vec![0f64; b * s * d];
        for (m, &tok) in tokens.iter().enumerate() {
            let row = &embed[tok as usize * d..tok as usize * d + d];
            let hr = &mut h[m * d..(m + 1) * d];
            for c in 0..d {
                hr[c] = row[c] as f64;
            }
        }
        h
    }

    fn proj_dims(&self, p: Proj) -> ProjDims {
        let (fin, fout) = p.dims(self.spec.d_model, self.spec.d_ff);
        ProjDims {
            fin,
            fout,
            rank: self.spec.lora_rank,
            scale: self.spec.lora_alpha / self.spec.lora_rank as f64,
            row_parallel: p.row_parallel(),
        }
    }

    fn lora_pair<'a>(&self, lora: &'a [f32], li: usize, p: Proj) -> (&'a [f32], &'a [f32]) {
        let (fin, fout) = p.dims(self.spec.d_model, self.spec.d_ff);
        let (t, r) = (self.spec.n_tasks, self.spec.lora_rank);
        let bo = self.lora_layers[li].b[p.idx()];
        let ao = self.lora_layers[li].a[p.idx()];
        (&lora[bo..bo + t * fin * r], &lora[ao..ao + t * r * fout])
    }

    /// Mutable `(dB, dA)` slices for one projection's gradient region.
    /// Relies on the B-then-A adjacency set up in `new`.
    fn lora_pair_mut<'a>(
        &self,
        grad: &'a mut [f64],
        li: usize,
        p: Proj,
    ) -> (&'a mut [f64], &'a mut [f64]) {
        let (fin, fout) = p.dims(self.spec.d_model, self.spec.d_ff);
        let (t, r) = (self.spec.n_tasks, self.spec.lora_rank);
        let bo = self.lora_layers[li].b[p.idx()];
        let blen = t * fin * r;
        let alen = t * r * fout;
        grad[bo..bo + blen + alen].split_at_mut(blen)
    }

    /// One transformer layer forward. `tp` shards the four base matmuls;
    /// all-reduce time for the row-parallel combines accumulates into
    /// `comm`. Returns the residual-stream output and the backward cache.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn layer_forward(
        &self,
        li: usize,
        tp: usize,
        base: &[f32],
        lora: &[f32],
        h: &[f64],
        tokens: &[i32],
        row_task: &[usize],
        b: usize,
        s: usize,
        comm: &mut f64,
    ) -> (Vec<f64>, LayerCache) {
        let d = self.spec.d_model;
        let ff = self.spec.d_ff;
        let nh = self.spec.n_heads;
        let dh = d / nh;
        let half = dh / 2;
        let rows = b * s;
        let lo = self.layers[li];

        // ln1 -> qkv projection
        let g1 = &base[lo.ln1_g..lo.ln1_g + d];
        let bb1 = &base[lo.ln1_b..lo.ln1_b + d];
        let (xn1, xhat1, rstd1) = ln_forward(h, rows, d, g1, bb1);
        let dq_dims = self.proj_dims(Proj::Qkv);
        let wq = &base[lo.w[0]..lo.w[0] + d * 3 * d];
        let (bq, aq) = self.lora_pair(lora, li, Proj::Qkv);
        let (y_qkv, u_qkv) = proj_forward(wq, bq, aq, &xn1, rows, row_task, &dq_dims, tp, comm);

        // split heads + RoPE on q/k
        let (cos_t, sin_t) = rope_tables(s, half, self.spec.rope_theta);
        let mut q = vec![0f64; b * nh * s * dh];
        let mut k = vec![0f64; b * nh * s * dh];
        let mut v = vec![0f64; b * nh * s * dh];
        for i in 0..b {
            for j in 0..s {
                let src = (i * s + j) * 3 * d;
                for hh in 0..nh {
                    let dst = ((i * nh + hh) * s + j) * dh;
                    for kk in 0..dh {
                        q[dst + kk] = y_qkv[src + hh * dh + kk];
                        k[dst + kk] = y_qkv[src + d + hh * dh + kk];
                        v[dst + kk] = y_qkv[src + 2 * d + hh * dh + kk];
                    }
                }
            }
        }
        apply_rope(&mut q, b * nh, s, dh, &cos_t, &sin_t, false);
        apply_rope(&mut k, b * nh, s, dh, &cos_t, &sin_t, false);

        // causal+pad masked attention
        let inv_sqrt = 1.0 / (dh as f64).sqrt();
        let mut probs = vec![0f64; b * nh * s * s];
        let mut score_row = vec![0f64; s];
        for i in 0..b {
            for hh in 0..nh {
                for j in 0..s {
                    let qb = ((i * nh + hh) * s + j) * dh;
                    for (p, slot) in score_row.iter_mut().enumerate() {
                        if p <= j && tokens[i * s + p] != PAD_ID {
                            let kb = ((i * nh + hh) * s + p) * dh;
                            let mut acc = 0f64;
                            for kk in 0..dh {
                                acc += q[qb + kk] * k[kb + kk];
                            }
                            *slot = acc * inv_sqrt;
                        } else {
                            *slot = MASK_NEG;
                        }
                    }
                    let mut mx = score_row[0];
                    for &sc in &score_row[1..] {
                        if sc > mx {
                            mx = sc;
                        }
                    }
                    let mut denom = 0f64;
                    for slot in score_row.iter_mut() {
                        *slot = (*slot - mx).exp();
                        denom += *slot;
                    }
                    let pb = ((i * nh + hh) * s + j) * s;
                    for (p, &e) in score_row.iter().enumerate() {
                        probs[pb + p] = e / denom;
                    }
                }
            }
        }

        // context + out projection + residual
        let mut ctx = vec![0f64; rows * d];
        for i in 0..b {
            for hh in 0..nh {
                for j in 0..s {
                    let pb = ((i * nh + hh) * s + j) * s;
                    let cb = (i * s + j) * d + hh * dh;
                    for p in 0..s {
                        let pv = probs[pb + p];
                        let vb = ((i * nh + hh) * s + p) * dh;
                        for kk in 0..dh {
                            ctx[cb + kk] += pv * v[vb + kk];
                        }
                    }
                }
            }
        }
        let do_dims = self.proj_dims(Proj::Out);
        let wo = &base[lo.w[1]..lo.w[1] + d * d];
        let (bo, ao) = self.lora_pair(lora, li, Proj::Out);
        let (y_out, u_out) = proj_forward(wo, bo, ao, &ctx, rows, row_task, &do_dims, tp, comm);
        let mut h_mid = vec![0f64; rows * d];
        for idx in 0..rows * d {
            h_mid[idx] = h[idx] + y_out[idx];
        }

        // ln2 -> up -> gelu -> down + residual
        let g2 = &base[lo.ln2_g..lo.ln2_g + d];
        let bb2 = &base[lo.ln2_b..lo.ln2_b + d];
        let (xn2, xhat2, rstd2) = ln_forward(&h_mid, rows, d, g2, bb2);
        let du_dims = self.proj_dims(Proj::Up);
        let wu = &base[lo.w[2]..lo.w[2] + d * ff];
        let (bu, au) = self.lora_pair(lora, li, Proj::Up);
        let (up, u_up) = proj_forward(wu, bu, au, &xn2, rows, row_task, &du_dims, tp, comm);
        let mut act = vec![0f64; rows * ff];
        for idx in 0..rows * ff {
            act[idx] = gelu(up[idx]);
        }
        let dd_dims = self.proj_dims(Proj::Down);
        let wd = &base[lo.w[3]..lo.w[3] + ff * d];
        let (bd, ad) = self.lora_pair(lora, li, Proj::Down);
        let (y_down, u_down) = proj_forward(wd, bd, ad, &act, rows, row_task, &dd_dims, tp, comm);
        let mut h_out = h_mid;
        for idx in 0..rows * d {
            h_out[idx] += y_down[idx];
        }

        let cache = LayerCache {
            rstd1,
            xhat1,
            xn1,
            u_qkv,
            q,
            k,
            v,
            probs,
            ctx,
            u_out,
            rstd2,
            xhat2,
            xn2,
            u_up,
            up,
            act,
            u_down,
        };
        (h_out, cache)
    }

    /// One transformer layer backward: consumes the forward cache,
    /// accumulates LoRA gradients into the full-length `grad` buffer
    /// (only this layer's regions are touched) and returns `dL/dh_in`.
    /// The base weights are frozen, so no base gradients exist.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn layer_backward(
        &self,
        li: usize,
        tp: usize,
        base: &[f32],
        lora: &[f32],
        dh_out: &[f64],
        cache: &LayerCache,
        tokens: &[i32],
        row_task: &[usize],
        b: usize,
        s: usize,
        grad: &mut [f64],
        comm: &mut f64,
    ) -> Vec<f64> {
        let d = self.spec.d_model;
        let ff = self.spec.d_ff;
        let nh = self.spec.n_heads;
        let dh = d / nh;
        let half = dh / 2;
        let rows = b * s;
        let lo = self.layers[li];

        // MLP backward (down -> gelu -> up -> ln2)
        let dd_dims = self.proj_dims(Proj::Down);
        let wd = &base[lo.w[3]..lo.w[3] + ff * d];
        let (bd, ad) = self.lora_pair(lora, li, Proj::Down);
        let dact = {
            let (db, da) = self.lora_pair_mut(grad, li, Proj::Down);
            proj_backward(
                wd, bd, ad, &cache.act, &cache.u_down, dh_out, rows, row_task, &dd_dims, tp, db,
                da, comm,
            )
        };
        let mut dup = vec![0f64; rows * ff];
        for idx in 0..rows * ff {
            dup[idx] = dact[idx] * gelu_grad(cache.up[idx]);
        }
        let du_dims = self.proj_dims(Proj::Up);
        let wu = &base[lo.w[2]..lo.w[2] + d * ff];
        let (bu, au) = self.lora_pair(lora, li, Proj::Up);
        let dxn2 = {
            let (db, da) = self.lora_pair_mut(grad, li, Proj::Up);
            proj_backward(
                wu, bu, au, &cache.xn2, &cache.u_up, &dup, rows, row_task, &du_dims, tp, db, da,
                comm,
            )
        };
        let g2 = &base[lo.ln2_g..lo.ln2_g + d];
        let dln2 = ln_backward(&dxn2, &cache.xhat2, &cache.rstd2, g2, rows, d);
        let mut dh_mid = vec![0f64; rows * d];
        for idx in 0..rows * d {
            dh_mid[idx] = dh_out[idx] + dln2[idx];
        }

        // attention backward (out -> softmax -> rope -> qkv -> ln1)
        let do_dims = self.proj_dims(Proj::Out);
        let wo = &base[lo.w[1]..lo.w[1] + d * d];
        let (bo, ao) = self.lora_pair(lora, li, Proj::Out);
        let dctx = {
            let (db, da) = self.lora_pair_mut(grad, li, Proj::Out);
            proj_backward(
                wo, bo, ao, &cache.ctx, &cache.u_out, &dh_mid, rows, row_task, &do_dims, tp, db,
                da, comm,
            )
        };
        let inv_sqrt = 1.0 / (dh as f64).sqrt();
        let mut dq = vec![0f64; b * nh * s * dh];
        let mut dk = vec![0f64; b * nh * s * dh];
        let mut dv = vec![0f64; b * nh * s * dh];
        let mut dp_row = vec![0f64; s];
        for i in 0..b {
            for hh in 0..nh {
                for j in 0..s {
                    let pb = ((i * nh + hh) * s + j) * s;
                    let cb = (i * s + j) * d + hh * dh;
                    for (p, slot) in dp_row.iter_mut().enumerate() {
                        let vb = ((i * nh + hh) * s + p) * dh;
                        let mut acc = 0f64;
                        for kk in 0..dh {
                            acc += dctx[cb + kk] * cache.v[vb + kk];
                        }
                        *slot = acc;
                    }
                    for p in 0..s {
                        let pv = cache.probs[pb + p];
                        let vb = ((i * nh + hh) * s + p) * dh;
                        for kk in 0..dh {
                            dv[vb + kk] += pv * dctx[cb + kk];
                        }
                    }
                    let mut dot = 0f64;
                    for p in 0..s {
                        dot += dp_row[p] * cache.probs[pb + p];
                    }
                    let qb = ((i * nh + hh) * s + j) * dh;
                    for p in 0..s {
                        let allowed = p <= j && tokens[i * s + p] != PAD_ID;
                        if !allowed {
                            continue;
                        }
                        let ds = cache.probs[pb + p] * (dp_row[p] - dot) * inv_sqrt;
                        let kb = ((i * nh + hh) * s + p) * dh;
                        for kk in 0..dh {
                            dq[qb + kk] += ds * cache.k[kb + kk];
                            dk[kb + kk] += ds * cache.q[qb + kk];
                        }
                    }
                }
            }
        }
        let (cos_t, sin_t) = rope_tables(s, half, self.spec.rope_theta);
        apply_rope(&mut dq, b * nh, s, dh, &cos_t, &sin_t, true);
        apply_rope(&mut dk, b * nh, s, dh, &cos_t, &sin_t, true);
        let mut dy_qkv = vec![0f64; rows * 3 * d];
        for i in 0..b {
            for j in 0..s {
                let dst = (i * s + j) * 3 * d;
                for hh in 0..nh {
                    let src = ((i * nh + hh) * s + j) * dh;
                    for kk in 0..dh {
                        dy_qkv[dst + hh * dh + kk] = dq[src + kk];
                        dy_qkv[dst + d + hh * dh + kk] = dk[src + kk];
                        dy_qkv[dst + 2 * d + hh * dh + kk] = dv[src + kk];
                    }
                }
            }
        }
        let dq_dims = self.proj_dims(Proj::Qkv);
        let wq = &base[lo.w[0]..lo.w[0] + d * 3 * d];
        let (bq, aq) = self.lora_pair(lora, li, Proj::Qkv);
        let dxn1 = {
            let (db, da) = self.lora_pair_mut(grad, li, Proj::Qkv);
            proj_backward(
                wq, bq, aq, &cache.xn1, &cache.u_qkv, &dy_qkv, rows, row_task, &dq_dims, tp, db,
                da, comm,
            )
        };
        let g1 = &base[lo.ln1_g..lo.ln1_g + d];
        let dln1 = ln_backward(&dxn1, &cache.xhat1, &cache.rstd1, g1, rows, d);
        let mut dh_in = vec![0f64; rows * d];
        for idx in 0..rows * d {
            dh_in[idx] = dh_mid[idx] + dln1[idx];
        }
        dh_in
    }

    /// Final-LN + tied-embedding head + next-token loss. When
    /// `want_grad`, also returns `dL/dh` for the residual stream entering
    /// the head (the embedding is frozen, so no head gradient exists).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn head_loss(
        &self,
        base: &[f32],
        h: &[f64],
        tokens: &[i32],
        seg_ids: &[i32],
        b: usize,
        s: usize,
        want_grad: bool,
    ) -> (LossParts, Option<Vec<f64>>) {
        let d = self.spec.d_model;
        let vocab = self.spec.vocab;
        let rows = b * s;
        let gf = &base[self.lnf_g..self.lnf_g + d];
        let bf = &base[self.lnf_b..self.lnf_b + d];
        let (hf, xhatf, rstdf) = ln_forward(h, rows, d, gf, bf);
        let embed = &base[self.embed..self.embed + vocab * d];

        // logits = hf @ embed^T, kept per-row (micro-scale vocab)
        let mut logits = vec![0f64; rows * vocab];
        for m in 0..rows {
            let hr = &hf[m * d..(m + 1) * d];
            let lr = &mut logits[m * vocab..(m + 1) * vocab];
            for (vv, slot) in lr.iter_mut().enumerate() {
                let er = &embed[vv * d..vv * d + d];
                let mut acc = 0f64;
                for c in 0..d {
                    acc += hr[c] * er[c] as f64;
                }
                *slot = acc;
            }
        }

        let mut nll_sum = 0f64;
        let mut total = 0f64;
        let mut task_loss = vec![0f64; self.spec.n_tasks];
        let mut task_tokens = vec![0f64; self.spec.n_tasks];
        for i in 0..b {
            for j in 0..s.saturating_sub(1) {
                let tgt = tokens[i * s + j + 1];
                if tgt == PAD_ID {
                    continue;
                }
                let m = i * s + j;
                let lr = &logits[m * vocab..(m + 1) * vocab];
                let mut mx = lr[0];
                for &x in &lr[1..] {
                    if x > mx {
                        mx = x;
                    }
                }
                let mut denom = 0f64;
                for &x in lr {
                    denom += (x - mx).exp();
                }
                let nll = mx + denom.ln() - lr[tgt as usize];
                nll_sum += nll;
                total += 1.0;
                let t = seg_ids[i] as usize;
                task_loss[t] += nll;
                task_tokens[t] += 1.0;
            }
        }
        let loss_denom = total.max(1.0);
        let parts = LossParts {
            mean_loss: nll_sum / loss_denom,
            total_tokens: total,
            task_loss,
            task_tokens,
        };
        if !want_grad {
            return (parts, None);
        }

        let mut dhf = vec![0f64; rows * d];
        for i in 0..b {
            for j in 0..s.saturating_sub(1) {
                let tgt = tokens[i * s + j + 1];
                if tgt == PAD_ID {
                    continue;
                }
                let m = i * s + j;
                let lr = &logits[m * vocab..(m + 1) * vocab];
                let mut mx = lr[0];
                for &x in &lr[1..] {
                    if x > mx {
                        mx = x;
                    }
                }
                let mut denom = 0f64;
                for &x in lr {
                    denom += (x - mx).exp();
                }
                let dr = &mut dhf[m * d..(m + 1) * d];
                for (vv, &x) in lr.iter().enumerate() {
                    let p = (x - mx).exp() / denom;
                    let one = if vv == tgt as usize { 1.0 } else { 0.0 };
                    let dl = (p - one) / loss_denom;
                    let er = &embed[vv * d..vv * d + d];
                    for c in 0..d {
                        dr[c] += dl * er[c] as f64;
                    }
                }
            }
        }
        let dh = ln_backward(&dhf, &xhatf, &rstdf, gf, rows, d);
        (parts, Some(dh))
    }

    /// Execute one fwd+bwd microbatch unstaged (tp=1, single partition).
    /// The staged engine with pp=1 × tp=1 runs the exact same call
    /// sequence, which is what makes the identity certificate bitwise.
    pub fn train_step(
        &self,
        base: &ParamVector,
        lora: &ParamVector,
        shape: (u64, u64),
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<StepOutput> {
        let (b, s) = self.validate(shape, tokens, seg_ids)?;
        if base.len() as u64 != self.base_len {
            return Err(anyhow!("base params {} != spec {}", base.len(), self.base_len));
        }
        if lora.len() as u64 != self.lora_len {
            return Err(anyhow!("lora params {} != spec {}", lora.len(), self.lora_len));
        }
        let row_task = row_tasks(seg_ids, b, s);
        let mut comm = 0f64;
        let mut h = self.embed_forward(&base.data, tokens, b, s);
        let mut caches = Vec::with_capacity(self.spec.n_layers);
        for li in 0..self.spec.n_layers {
            let (h_next, cache) = self.layer_forward(
                li, 1, &base.data, &lora.data, &h, tokens, &row_task, b, s, &mut comm,
            );
            h = h_next;
            caches.push(cache);
        }
        let (parts, dh_opt) = self.head_loss(&base.data, &h, tokens, seg_ids, b, s, true);
        let Some(mut dh) = dh_opt else {
            return Err(anyhow!("head_loss produced no gradient"));
        };
        let mut grad = vec![0f64; self.lora_len as usize];
        for li in (0..self.spec.n_layers).rev() {
            dh = self.layer_backward(
                li,
                1,
                &base.data,
                &lora.data,
                &dh,
                &caches[li],
                tokens,
                &row_task,
                b,
                s,
                &mut grad,
                &mut comm,
            );
        }
        Ok(step_output(&parts, &grad))
    }

    /// Forward-only loss (same outputs as `Engine::eval_loss`).
    pub fn eval_loss(
        &self,
        base: &ParamVector,
        lora: &ParamVector,
        shape: (u64, u64),
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let (b, s) = self.validate(shape, tokens, seg_ids)?;
        let row_task = row_tasks(seg_ids, b, s);
        let mut comm = 0f64;
        let mut h = self.embed_forward(&base.data, tokens, b, s);
        for li in 0..self.spec.n_layers {
            let (h_next, _) = self.layer_forward(
                li, 1, &base.data, &lora.data, &h, tokens, &row_task, b, s, &mut comm,
            );
            h = h_next;
        }
        let (parts, _) = self.head_loss(&base.data, &h, tokens, seg_ids, b, s, false);
        Ok((
            parts.mean_loss as f32,
            parts.total_tokens as f32,
            parts.task_loss.iter().map(|&x| x as f32).collect(),
            parts.task_tokens.iter().map(|&x| x as f32).collect(),
        ))
    }
}

/// Cast the f64 loss head + gradient accumulators down to the f32
/// `StepOutput` contract shared with the PJRT engine.
pub(crate) fn step_output(parts: &LossParts, grad: &[f64]) -> StepOutput {
    StepOutput {
        loss: parts.mean_loss as f32,
        grad: grad.iter().map(|&x| x as f32).collect(),
        tokens: parts.total_tokens as f32,
        task_loss: parts.task_loss.iter().map(|&x| x as f32).collect(),
        task_tokens: parts.task_tokens.iter().map(|&x| x as f32).collect(),
    }
}

/// Per-row task ids: row `m` belongs to sequence `m / s`.
pub(crate) fn row_tasks(seg_ids: &[i32], b: usize, s: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(b * s);
    for &g in seg_ids.iter().take(b) {
        for _ in 0..s {
            out.push(g as usize);
        }
    }
    out
}

fn push_leaf(
    table: &mut Vec<ParamEntry>,
    off: &mut u64,
    name: String,
    shape: Vec<u64>,
    init: InitKind,
) -> usize {
    let size: u64 = shape.iter().product();
    let entry_off = *off;
    table.push(ParamEntry { name, shape, offset: entry_off, size, init });
    *off += size;
    entry_off as usize
}

/// LayerNorm forward: returns `(xn, xhat, rstd)` where
/// `xn = xhat * g + b`, `xhat = (x - mu) * rstd`, `rstd = 1/sqrt(var+eps)`.
fn ln_forward(
    x: &[f64],
    rows: usize,
    d: usize,
    g: &[f32],
    b: &[f32],
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut xn = vec![0f64; rows * d];
    let mut xhat = vec![0f64; rows * d];
    let mut rstd = vec![0f64; rows];
    let inv_d = 1.0 / d as f64;
    for m in 0..rows {
        let xr = &x[m * d..(m + 1) * d];
        let mut mu = 0f64;
        for &v in xr {
            mu += v;
        }
        mu *= inv_d;
        let mut var = 0f64;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var *= inv_d;
        let r = 1.0 / (var + LN_EPS).sqrt();
        rstd[m] = r;
        let xh = &mut xhat[m * d..(m + 1) * d];
        let xo = &mut xn[m * d..(m + 1) * d];
        for c in 0..d {
            let h = (xr[c] - mu) * r;
            xh[c] = h;
            xo[c] = h * g[c] as f64 + b[c] as f64;
        }
    }
    (xn, xhat, rstd)
}

/// LayerNorm backward wrt its input (`g`/`b` are frozen base params):
/// `dx = rstd * (dxh - mean(dxh) - xhat * mean(dxh * xhat))` with
/// `dxh = dxn * g`.
fn ln_backward(dxn: &[f64], xhat: &[f64], rstd: &[f64], g: &[f32], rows: usize, d: usize) -> Vec<f64> {
    let mut dx = vec![0f64; rows * d];
    let inv_d = 1.0 / d as f64;
    let mut dxh = vec![0f64; d];
    for m in 0..rows {
        let dnr = &dxn[m * d..(m + 1) * d];
        let xhr = &xhat[m * d..(m + 1) * d];
        let mut m1 = 0f64;
        let mut m2 = 0f64;
        for c in 0..d {
            let v = dnr[c] * g[c] as f64;
            dxh[c] = v;
            m1 += v;
            m2 += v * xhr[c];
        }
        m1 *= inv_d;
        m2 *= inv_d;
        let dxr = &mut dx[m * d..(m + 1) * d];
        for c in 0..d {
            dxr[c] = rstd[m] * (dxh[c] - m1 - xhr[c] * m2);
        }
    }
    dx
}

/// GeLU, tanh approximation (matches `jax.nn.gelu(approximate=True)`).
fn gelu(x: f64) -> f64 {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_grad(x: f64) -> f64 {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    let t = (c * (x + 0.044715 * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * c * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Per-position RoPE cos/sin tables, `[s, half]` row-major.
fn rope_tables(s: usize, half: usize, theta: f64) -> (Vec<f64>, Vec<f64>) {
    let mut cos_t = vec![0f64; s * half];
    let mut sin_t = vec![0f64; s * half];
    for j in 0..s {
        for i in 0..half {
            let freq = 1.0 / theta.powf(i as f64 / half as f64);
            let ang = j as f64 * freq;
            cos_t[j * half + i] = ang.cos();
            sin_t[j * half + i] = ang.sin();
        }
    }
    (cos_t, sin_t)
}

/// Rotate `[x1, x2]` halves per head row; `inverse` applies the
/// transpose rotation (the exact backward of the forward rotation).
fn apply_rope(
    buf: &mut [f64],
    head_rows: usize,
    s: usize,
    dh: usize,
    cos_t: &[f64],
    sin_t: &[f64],
    inverse: bool,
) {
    let half = dh / 2;
    for row in 0..head_rows {
        for j in 0..s {
            let base = (row * s + j) * dh;
            for i in 0..half {
                let c = cos_t[j * half + i];
                let sn = if inverse { -sin_t[j * half + i] } else { sin_t[j * half + i] };
                let x1 = buf[base + i];
                let x2 = buf[base + half + i];
                buf[base + i] = x1 * c - x2 * sn;
                buf[base + half + i] = x1 * sn + x2 * c;
            }
        }
    }
}

/// Sharded dense projection forward with replicated LoRA:
/// `y = x @ W + scale * (x @ B_task) @ A_task`. Returns `(y, u)` where
/// `u = x @ B_task` (cached for backward).
///
/// Column-parallel (`!row_parallel`): output columns shard across tp;
/// every rank holds full `x`, no communication, and the per-element
/// accumulation order is tp-invariant (bitwise identical for any tp).
/// Row-parallel: the contraction dim shards; per-rank partial sums are
/// combined by a deterministic [`tree_reduce`] whose wall time
/// accumulates into `comm`.
#[allow(clippy::too_many_arguments)]
fn proj_forward(
    w: &[f32],
    bmat: &[f32],
    amat: &[f32],
    x: &[f64],
    rows: usize,
    row_task: &[usize],
    dims: &ProjDims,
    tp: usize,
    comm: &mut f64,
) -> (Vec<f64>, Vec<f64>) {
    let (fin, fout, r) = (dims.fin, dims.fout, dims.rank);
    let tp = tp.max(1);
    let mut y;
    if !dims.row_parallel {
        y = vec![0f64; rows * fout];
        for shard in 0..tp {
            let c0 = shard * fout / tp;
            let c1 = (shard + 1) * fout / tp;
            for m in 0..rows {
                let xr = &x[m * fin..(m + 1) * fin];
                let yr = &mut y[m * fout..(m + 1) * fout];
                for (kk, &xv) in xr.iter().enumerate() {
                    let wrow = &w[kk * fout..kk * fout + fout];
                    for c in c0..c1 {
                        yr[c] += xv * wrow[c] as f64;
                    }
                }
            }
        }
    } else {
        let mut partials: Vec<Vec<f64>> = Vec::with_capacity(tp);
        for shard in 0..tp {
            let k0 = shard * fin / tp;
            let k1 = (shard + 1) * fin / tp;
            let mut part = vec![0f64; rows * fout];
            for m in 0..rows {
                let xr = &x[m * fin..(m + 1) * fin];
                let pr = &mut part[m * fout..(m + 1) * fout];
                for kk in k0..k1 {
                    let xv = xr[kk];
                    let wrow = &w[kk * fout..kk * fout + fout];
                    for c in 0..fout {
                        pr[c] += xv * wrow[c] as f64;
                    }
                }
            }
            partials.push(part);
        }
        y = combine_partials(partials, comm, rows * fout);
    }
    // LoRA path: rank-r skinny, replicated on every tp rank, applied
    // after the base combine so its accumulation order never depends on
    // the sharding.
    let mut u = vec![0f64; rows * r];
    for m in 0..rows {
        let t = row_task[m];
        let xr = &x[m * fin..(m + 1) * fin];
        let ur = &mut u[m * r..(m + 1) * r];
        for (kk, &xv) in xr.iter().enumerate() {
            let brow = &bmat[(t * fin + kk) * r..(t * fin + kk) * r + r];
            for rr in 0..r {
                ur[rr] += xv * brow[rr] as f64;
            }
        }
        let yr = &mut y[m * fout..(m + 1) * fout];
        for rr in 0..r {
            let uv = dims.scale * ur[rr];
            let arow = &amat[(t * r + rr) * fout..(t * r + rr) * fout + fout];
            for c in 0..fout {
                yr[c] += uv * arow[c] as f64;
            }
        }
    }
    (y, u)
}

/// Backward of [`proj_forward`]: accumulates `dB`/`dA` (the only
/// trainable params) and returns `dL/dx`. The communication pattern is
/// the transpose of forward: column-parallel layers all-reduce `dx`
/// partials here, row-parallel layers write disjoint `dx` rows.
#[allow(clippy::too_many_arguments)]
fn proj_backward(
    w: &[f32],
    bmat: &[f32],
    amat: &[f32],
    x: &[f64],
    u: &[f64],
    dy: &[f64],
    rows: usize,
    row_task: &[usize],
    dims: &ProjDims,
    tp: usize,
    db: &mut [f64],
    da: &mut [f64],
    comm: &mut f64,
) -> Vec<f64> {
    let (fin, fout, r) = (dims.fin, dims.fout, dims.rank);
    let tp = tp.max(1);
    let mut dx;
    if !dims.row_parallel {
        // forward sharded output columns -> the c-sum in dx shards here
        let mut partials: Vec<Vec<f64>> = Vec::with_capacity(tp);
        for shard in 0..tp {
            let c0 = shard * fout / tp;
            let c1 = (shard + 1) * fout / tp;
            let mut part = vec![0f64; rows * fin];
            for m in 0..rows {
                let dyr = &dy[m * fout..(m + 1) * fout];
                let pr = &mut part[m * fin..(m + 1) * fin];
                for kk in 0..fin {
                    let wrow = &w[kk * fout..kk * fout + fout];
                    let mut acc = 0f64;
                    for c in c0..c1 {
                        acc += dyr[c] * wrow[c] as f64;
                    }
                    pr[kk] += acc;
                }
            }
            partials.push(part);
        }
        dx = combine_partials(partials, comm, rows * fin);
    } else {
        // forward sharded the contraction dim -> dx rows are disjoint
        dx = vec![0f64; rows * fin];
        for shard in 0..tp {
            let k0 = shard * fin / tp;
            let k1 = (shard + 1) * fin / tp;
            for m in 0..rows {
                let dyr = &dy[m * fout..(m + 1) * fout];
                let dxr = &mut dx[m * fin..(m + 1) * fin];
                for kk in k0..k1 {
                    let wrow = &w[kk * fout..kk * fout + fout];
                    let mut acc = 0f64;
                    for c in 0..fout {
                        acc += dyr[c] * wrow[c] as f64;
                    }
                    dxr[kk] += acc;
                }
            }
        }
    }
    // LoRA grads + the LoRA share of dx (replicated path, tp-invariant)
    let mut dv = vec![0f64; r];
    for m in 0..rows {
        let t = row_task[m];
        let dyr = &dy[m * fout..(m + 1) * fout];
        let ur = &u[m * r..(m + 1) * r];
        for rr in 0..r {
            let arow = &amat[(t * r + rr) * fout..(t * r + rr) * fout + fout];
            let darow = &mut da[(t * r + rr) * fout..(t * r + rr) * fout + fout];
            let mut acc = 0f64;
            let uscaled = dims.scale * ur[rr];
            for c in 0..fout {
                acc += dyr[c] * arow[c] as f64;
                darow[c] += uscaled * dyr[c];
            }
            dv[rr] = dims.scale * acc;
        }
        let xr = &x[m * fin..(m + 1) * fin];
        let dxr = &mut dx[m * fin..(m + 1) * fin];
        for kk in 0..fin {
            let brow = &bmat[(t * fin + kk) * r..(t * fin + kk) * r + r];
            let dbrow = &mut db[(t * fin + kk) * r..(t * fin + kk) * r + r];
            let xv = xr[kk];
            let mut acc = 0f64;
            for rr in 0..r {
                dbrow[rr] += xv * dv[rr];
                acc += dv[rr] * brow[rr] as f64;
            }
            dxr[kk] += acc;
        }
    }
    dx
}

/// Combine per-shard partial sums with the deterministic tree all-reduce
/// ordering, timing the combine as communication. A single partial is
/// taken as-is (tp=1: zero comm, bit-identical to an unsharded loop).
fn combine_partials(mut partials: Vec<Vec<f64>>, comm: &mut f64, len: usize) -> Vec<f64> {
    if partials.len() == 1 {
        return partials.swap_remove(0);
    }
    let sw = Stopwatch::start();
    let combined = tree_reduce(partials, |mut a, b| {
        for (av, &bv) in a.iter_mut().zip(b.iter()) {
            *av += bv;
        }
        a
    });
    *comm += sw.elapsed_secs();
    combined.unwrap_or_else(|| vec![0f64; len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn micro() -> NativeModel {
        NativeModel::new(NativeSpec::micro()).unwrap()
    }

    /// A microbatch with real content: distinct tokens per row, one row
    /// ending in PADs, sorted seg ids.
    fn batch(model: &NativeModel, b: usize, s: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let vocab = model.spec().vocab as u64;
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::with_capacity(b * s);
        for i in 0..b {
            let real = if i == b - 1 { s / 2 } else { s };
            for j in 0..s {
                if j < real {
                    // 1.. so PAD never appears as a real token
                    tokens.push((1 + rng.next_u64() % (vocab - 1)) as i32);
                } else {
                    tokens.push(PAD_ID);
                }
            }
        }
        let n_tasks = model.spec().n_tasks;
        let seg_ids: Vec<i32> = (0..b).map(|i| (i * n_tasks / b) as i32).collect();
        (tokens, seg_ids)
    }

    /// LoRA init has A = 0, which zeroes every dB; randomize the whole
    /// vector so the gradient check exercises all paths.
    fn randomized_lora(model: &NativeModel, seed: u64) -> ParamVector {
        let mut lora = ParamVector::zeros(model.lora_param_count());
        let mut rng = Rng::new(seed);
        for x in &mut lora.data {
            *x = rng.normal_ms(0.0, 0.05) as f32;
        }
        lora
    }

    #[test]
    fn init_rules_shape_the_vectors() {
        let m = micro();
        let (base, lora) = m.init_params(11);
        assert_eq!(base.len() as u64, m.base_param_count());
        assert_eq!(lora.len() as u64, m.lora_param_count());
        // ln gains are ones
        let e = m
            .base_table()
            .iter()
            .find(|e| e.name.contains("ln1_g"))
            .unwrap();
        assert!(base.leaf(e).iter().all(|&x| x == 1.0));
        // LoRA A stacks init to zero, B stacks don't
        for e in m.lora_table() {
            if e.name.contains("_lora_a") {
                assert!(lora.leaf(e).iter().all(|&x| x == 0.0), "{}", e.name);
            } else {
                assert!(lora.leaf(e).iter().any(|&x| x != 0.0), "{}", e.name);
            }
        }
    }

    #[test]
    fn train_step_is_deterministic() {
        let m = micro();
        let (base, _) = m.init_params(3);
        let lora = randomized_lora(&m, 4);
        let (tokens, seg) = batch(&m, 4, 8, 5);
        let a = m.train_step(&base, &lora, (4, 8), &tokens, &seg).unwrap();
        let b = m.train_step(&base, &lora, (4, 8), &tokens, &seg).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.tokens, b.tokens);
        for (x, y) in a.grad.iter().zip(&b.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn eval_matches_train_loss() {
        let m = micro();
        let (base, _) = m.init_params(9);
        let lora = randomized_lora(&m, 10);
        let (tokens, seg) = batch(&m, 2, 16, 6);
        let t = m.train_step(&base, &lora, (2, 16), &tokens, &seg).unwrap();
        let (loss, toks, task_loss, task_tokens) =
            m.eval_loss(&base, &lora, (2, 16), &tokens, &seg).unwrap();
        assert_eq!(t.loss.to_bits(), loss.to_bits());
        assert_eq!(t.tokens, toks);
        assert_eq!(t.task_loss, task_loss);
        assert_eq!(t.task_tokens, task_tokens);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let m = micro();
        let (base, _) = m.init_params(21);
        let lora = randomized_lora(&m, 22);
        let (tokens, seg) = batch(&m, 4, 8, 23);
        let out = m.train_step(&base, &lora, (4, 8), &tokens, &seg).unwrap();

        // directional derivative along a random unit-ish direction
        let mut rng = Rng::new(99);
        let dir: Vec<f64> = (0..lora.len()).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let eps = 1e-3f64;
        let loss_at = |delta: f64| -> f64 {
            let mut p = lora.clone();
            for (x, dv) in p.data.iter_mut().zip(&dir) {
                *x += (delta * dv) as f32;
            }
            let (l, _, _, _) = m.eval_loss(&base, &p, (4, 8), &tokens, &seg).unwrap();
            l as f64
        };
        let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
        let mut analytic = 0f64;
        for (gv, dv) in out.grad.iter().zip(&dir) {
            analytic += *gv as f64 * dv;
        }
        let scale = fd.abs().max(analytic.abs()).max(1e-6);
        assert!(
            (fd - analytic).abs() / scale < 2e-2,
            "directional: fd={fd} analytic={analytic}"
        );

        // a few individual coordinates (spread across layers/projections)
        let n = lora.len();
        for &idx in &[0, n / 5, n / 3, n / 2, 2 * n / 3, n - 1] {
            let mut plus = lora.clone();
            plus.data[idx] += eps as f32;
            let mut minus = lora.clone();
            minus.data[idx] -= eps as f32;
            let (lp, _, _, _) = m.eval_loss(&base, &plus, (4, 8), &tokens, &seg).unwrap();
            let (lm, _, _, _) = m.eval_loss(&base, &minus, (4, 8), &tokens, &seg).unwrap();
            let fd = (lp as f64 - lm as f64) / (2.0 * eps);
            let an = out.grad[idx] as f64;
            let scale = fd.abs().max(an.abs()).max(1e-4);
            assert!(
                (fd - an).abs() / scale < 5e-2,
                "coord {idx}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn pad_only_row_changes_nothing() {
        let m = micro();
        let (base, _) = m.init_params(31);
        let lora = randomized_lora(&m, 32);
        let s = 8usize;
        let mut rng = Rng::new(33);
        let row: Vec<i32> = (0..s).map(|_| (1 + rng.next_u64() % 63) as i32).collect();
        let seg1 = vec![0i32];
        let one = m.train_step(&base, &lora, (1, 8), &row, &seg1).unwrap();
        // same sequence plus an all-PAD row: identical loss + grad bits
        let mut tokens = row.clone();
        tokens.extend(std::iter::repeat(PAD_ID).take(s));
        let seg2 = vec![0i32, 0];
        let two = m.train_step(&base, &lora, (2, 8), &tokens, &seg2).unwrap();
        assert_eq!(one.loss.to_bits(), two.loss.to_bits());
        assert_eq!(one.tokens, two.tokens);
        for (x, y) in one.grad.iter().zip(&two.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn column_parallel_forward_is_tp_invariant_bitwise() {
        // qkv/up forward never communicates: any tp must be bit-identical
        let dims = ProjDims { fin: 16, fout: 48, rank: 2, scale: 2.0, row_parallel: false };
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..16 * 48).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let bm: Vec<f32> = (0..2 * 16 * 2).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let am: Vec<f32> = (0..2 * 2 * 48).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let x: Vec<f64> = (0..5 * 16).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let tasks = vec![0usize, 0, 1, 1, 1];
        let mut c1 = 0f64;
        let (y1, u1) = proj_forward(&w, &bm, &am, &x, 5, &tasks, &dims, 1, &mut c1);
        for tp in [2, 3, 5] {
            let mut ct = 0f64;
            let (yt, ut) = proj_forward(&w, &bm, &am, &x, 5, &tasks, &dims, tp, &mut ct);
            for (a, b) in y1.iter().zip(&yt) {
                assert_eq!(a.to_bits(), b.to_bits(), "tp={tp}");
            }
            assert_eq!(u1, ut);
            assert_eq!(ct, 0.0, "column-parallel forward must not communicate");
        }
    }

    #[test]
    fn row_parallel_forward_matches_unsharded_numerically() {
        let dims = ProjDims { fin: 48, fout: 16, rank: 2, scale: 2.0, row_parallel: true };
        let mut rng = Rng::new(8);
        let w: Vec<f32> = (0..48 * 16).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let bm: Vec<f32> = (0..2 * 48 * 2).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let am: Vec<f32> = (0..2 * 2 * 16).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect();
        let x: Vec<f64> = (0..5 * 48).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let tasks = vec![0usize, 1, 1, 1, 1];
        let mut c1 = 0f64;
        let (y1, _) = proj_forward(&w, &bm, &am, &x, 5, &tasks, &dims, 1, &mut c1);
        for tp in [2, 4] {
            let mut ct = 0f64;
            let (yt, _) = proj_forward(&w, &bm, &am, &x, 5, &tasks, &dims, tp, &mut ct);
            for (a, b) in y1.iter().zip(&yt) {
                let scale = a.abs().max(1.0);
                assert!((a - b).abs() / scale < 1e-12, "tp={tp}: {a} vs {b}");
            }
            assert!(ct >= 0.0);
        }
    }

    #[test]
    fn uneven_tp_shards_cover_every_column() {
        // fout=48 with tp=5 gives uneven shard widths; the sharding must
        // still partition (no divisibility requirement)
        let fout = 48usize;
        let tp = 5usize;
        let mut covered = vec![false; fout];
        for shard in 0..tp {
            for c in (shard * fout / tp)..((shard + 1) * fout / tp) {
                assert!(!covered[c], "column {c} assigned twice");
                covered[c] = true;
            }
        }
        assert!(covered.iter().all(|&x| x));
    }

    #[test]
    fn rope_inverse_undoes_forward() {
        let (cos_t, sin_t) = rope_tables(6, 4, 10_000.0);
        let mut rng = Rng::new(12);
        let orig: Vec<f64> = (0..2 * 6 * 8).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let mut buf = orig.clone();
        apply_rope(&mut buf, 2, 6, 8, &cos_t, &sin_t, false);
        apply_rope(&mut buf, 2, 6, 8, &cos_t, &sin_t, true);
        for (a, b) in orig.iter().zip(&buf) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn validate_rejects_malformed_batches() {
        let m = micro();
        let (tokens, seg) = batch(&m, 4, 8, 1);
        assert!(m.validate((4, 8), &tokens, &seg).is_ok());
        assert!(m.validate((4, 9), &tokens, &seg).is_err());
        assert!(m.validate((4, 8), &tokens, &seg[..3]).is_err());
        let unsorted = vec![1i32, 0, 0, 0];
        assert!(m.validate((4, 8), &tokens, &unsorted).is_err());
        let bad_task = vec![0i32, 0, 0, 99];
        assert!(m.validate((4, 8), &tokens, &bad_task).is_err());
        let mut bad_tok = tokens.clone();
        bad_tok[0] = 1_000;
        assert!(m.validate((4, 8), &bad_tok, &seg).is_err());
    }
}
