//! `StagedEngine`: tp × pp execution of the native model.
//!
//! The layer stack splits into `pp` contiguous partitions, each running
//! on its own OS thread (`util::par::scoped_pipeline` — stages block on
//! channel recvs, so they cannot share a bounded worker pool). A
//! microbatch pipeline with the mLoRA-style 1F1B schedule flows
//! activations forward and residual-stream gradients backward over
//! `std::sync::mpsc` channels; the schedule is a pure function of
//! `(pp, stage, n_microbatches)`, so message order — and therefore every
//! computed value — is deterministic for any thread timing or
//! `LOBRA_NUM_THREADS`. Within a stage, tp > 1 shards the base matmuls
//! column/row-wise (`runtime::native::proj_forward`) with the
//! `tree_reduce` combine ordering; the shards execute sequentially
//! in-thread, which models the per-rank compute exactly once and keeps
//! tp results thread-count-invariant by construction.
//!
//! Identity story (certified in `tests/staged_pipeline.rs`): with
//! pp=1 × tp=1 the single stage executes embed → layers → head → layers
//! in exactly the call sequence of `NativeModel::train_step`, so staged
//! and unstaged are bit-identical. LoRA gradients accumulate in
//! fixed order: per microbatch each stage owns disjoint layer regions,
//! merged stage-major after the pipeline drains.
//!
//! Timing: each stage's per-microbatch busy time (compute + its tp
//! combines, recv waits excluded) is measured with `Stopwatch`. The
//! per-microbatch attributed wall time is
//! `seconds(m) = max_stage busy(m) + bubble_share`, where
//! `bubble_share = max(0, (T_wall - Σ_m busy(m)) / M)` spreads the
//! pipeline fill/drain bubble evenly (zero when pp = 1 — there is no
//! pipeline to have a bubble); `comm(m)` is the tp-combine time
//! of the critical (max-busy) stage. `CalibrationStore::fit` subtracts
//! both back out so fitted compute never absorbs bubble or comm.

use super::engine::StepOutput;
use super::native::{row_tasks, step_output, LayerCache, LossParts, NativeModel};
use super::params::ParamVector;
use crate::util::clock::Stopwatch;
use crate::util::par::scoped_pipeline;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// One microbatch for the pipeline (`tokens` row-major `[b, s]`,
/// PAD = 0; `seg_ids` `[b]` sorted task ids — the `Engine` contract).
#[derive(Debug, Clone)]
pub struct StageMb {
    pub shape: (u64, u64),
    pub tokens: Vec<i32>,
    pub seg_ids: Vec<i32>,
}

/// Per-microbatch timing attribution from a pipeline run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MbTiming {
    /// Attributed wall seconds: critical-stage busy + bubble share.
    pub seconds: f64,
    /// Tensor-parallel combine seconds on the critical stage.
    pub comm: f64,
    /// This microbatch's share of the pipeline fill/drain bubble.
    pub bubble: f64,
}

/// Layer range `[lo, hi)` owned by `stage` when `n_layers` split into
/// `pp` partitions: earlier stages take the remainder layers.
pub fn layer_range_for_stage(n_layers: usize, pp: usize, stage: usize) -> (usize, usize) {
    let base = n_layers / pp;
    let rem = n_layers % pp;
    let lo = stage * base + stage.min(rem);
    let hi = lo + base + usize::from(stage < rem);
    (lo, hi)
}

/// A pp-staged, tp-sharded executor over the native model.
pub struct StagedEngine {
    model: Arc<NativeModel>,
    base: Arc<ParamVector>,
    tp: usize,
    pp: usize,
}

type Msg = (usize, Vec<f64>);

/// Everything one stage thread needs; built before spawn so every stage
/// closure has the same type.
struct StageCtx<'a> {
    model: &'a NativeModel,
    base: &'a [f32],
    lora: &'a [f32],
    mbs: &'a [StageMb],
    row_tasks: &'a [Vec<usize>],
    stage: usize,
    pp: usize,
    tp: usize,
    fwd_rx: Option<Receiver<Msg>>,
    fwd_tx: Option<Sender<Msg>>,
    bwd_rx: Option<Receiver<Msg>>,
    bwd_tx: Option<Sender<Msg>>,
}

/// One stage's pipeline products, per microbatch.
struct StageOut {
    /// Full-length LoRA gradient buffers (only this stage's layer
    /// regions are nonzero; regions are disjoint across stages).
    grads: Vec<Vec<f64>>,
    /// Busy seconds (compute + tp combines; recv waits excluded).
    busy: Vec<f64>,
    /// Tensor-parallel combine seconds.
    comm: Vec<f64>,
    /// Loss-head outputs; `Some` only on the last stage.
    parts: Vec<Option<LossParts>>,
}

impl StagedEngine {
    /// Build a `tp × pp` staged engine over a shared model + frozen base.
    pub fn new(
        model: Arc<NativeModel>,
        base: Arc<ParamVector>,
        tp: usize,
        pp: usize,
    ) -> Result<Self> {
        if tp == 0 || pp == 0 {
            return Err(anyhow!("tp and pp must be >= 1, got tp={tp} pp={pp}"));
        }
        if pp > model.n_layers() {
            return Err(anyhow!(
                "pp={pp} exceeds the {}-layer stack (a stage needs >= 1 layer)",
                model.n_layers()
            ));
        }
        if base.len() as u64 != model.base_param_count() {
            return Err(anyhow!(
                "base params {} != spec {}",
                base.len(),
                model.base_param_count()
            ));
        }
        Ok(Self { model, base, tp, pp })
    }

    pub fn tp(&self) -> usize {
        self.tp
    }

    pub fn pp(&self) -> usize {
        self.pp
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Run the 1F1B pipeline over `mbs`, returning per-microbatch step
    /// outputs (loss head from the last stage, gradients merged
    /// stage-major) and timing attributions.
    pub fn run(&self, lora: &ParamVector, mbs: &[StageMb]) -> Result<Vec<(StepOutput, MbTiming)>> {
        if mbs.is_empty() {
            return Ok(Vec::new());
        }
        if lora.len() as u64 != self.model.lora_param_count() {
            return Err(anyhow!(
                "lora params {} != spec {}",
                lora.len(),
                self.model.lora_param_count()
            ));
        }
        for mb in mbs {
            self.model.validate(mb.shape, &mb.tokens, &mb.seg_ids)?;
        }
        let row_task_all: Vec<Vec<usize>> = mbs
            .iter()
            .map(|mb| row_tasks(&mb.seg_ids, mb.shape.0 as usize, mb.shape.1 as usize))
            .collect();

        let pp = self.pp;
        let mut fwd_tx: Vec<Option<Sender<Msg>>> = (0..pp).map(|_| None).collect();
        let mut fwd_rx: Vec<Option<Receiver<Msg>>> = (0..pp).map(|_| None).collect();
        let mut bwd_tx: Vec<Option<Sender<Msg>>> = (0..pp).map(|_| None).collect();
        let mut bwd_rx: Vec<Option<Receiver<Msg>>> = (0..pp).map(|_| None).collect();
        for i in 0..pp.saturating_sub(1) {
            let (tx, rx) = channel();
            fwd_tx[i] = Some(tx);
            fwd_rx[i + 1] = Some(rx);
            let (tx, rx) = channel();
            bwd_tx[i + 1] = Some(tx);
            bwd_rx[i] = Some(rx);
        }
        let mut ctxs = Vec::with_capacity(pp);
        for stage in 0..pp {
            ctxs.push(StageCtx {
                model: &self.model,
                base: &self.base.data,
                lora: &lora.data,
                mbs,
                row_tasks: &row_task_all,
                stage,
                pp,
                tp: self.tp,
                fwd_rx: fwd_rx[stage].take(),
                fwd_tx: fwd_tx[stage].take(),
                bwd_rx: bwd_rx[stage].take(),
                bwd_tx: bwd_tx[stage].take(),
            });
        }

        let wall = Stopwatch::start();
        let results: Vec<Result<StageOut>> =
            scoped_pipeline(ctxs.into_iter().map(|c| move || run_stage(c)).collect());
        let t_wall = wall.elapsed_secs();

        let mut stage_outs = Vec::with_capacity(pp);
        for r in results {
            stage_outs.push(r?);
        }

        let m_count = mbs.len();
        let mut busy_max = vec![0f64; m_count];
        let mut comm_at_max = vec![0f64; m_count];
        for m in 0..m_count {
            let mut best = 0usize;
            for (si, so) in stage_outs.iter().enumerate() {
                if so.busy[m] > stage_outs[best].busy[m] {
                    best = si;
                }
            }
            busy_max[m] = stage_outs[best].busy[m];
            comm_at_max[m] = stage_outs[best].comm[m];
        }
        let mut total_busy = 0f64;
        for &b in &busy_max {
            total_busy += b;
        }
        // pp=1 has no pipeline: wall-vs-busy slack there is thread setup
        // overhead, not a bubble, and must not be subtracted by the fit
        let bubble_share = if self.pp > 1 {
            ((t_wall - total_busy) / m_count as f64).max(0.0)
        } else {
            0.0
        };

        let lora_len = self.model.lora_param_count() as usize;
        let mut out = Vec::with_capacity(m_count);
        for m in 0..m_count {
            let mut grad = vec![0f64; lora_len];
            for so in &stage_outs {
                for (g, &v) in grad.iter_mut().zip(&so.grads[m]) {
                    *g += v;
                }
            }
            let Some(parts) = stage_outs[pp - 1].parts[m].take() else {
                return Err(anyhow!("last stage produced no loss for microbatch {m}"));
            };
            let timing = MbTiming {
                seconds: busy_max[m] + bubble_share,
                comm: comm_at_max[m],
                bubble: bubble_share,
            };
            out.push((step_output(&parts, &grad), timing));
        }
        Ok(out)
    }
}

/// Execute one stage's full 1F1B schedule:
/// `F(0..w)`, then `F(m); B(m-w)` for `m in w..M`, then the cooldown
/// `B(M-w..M)`, with `w = min(M, pp-1-stage)` warmup forwards. Every
/// recv's producer is scheduled strictly earlier in dependency order, so
/// the pipeline is deadlock-free with unbounded channels.
fn run_stage(mut ctx: StageCtx<'_>) -> Result<StageOut> {
    let m_count = ctx.mbs.len();
    let (lo, hi) = layer_range_for_stage(ctx.model.n_layers(), ctx.pp, ctx.stage);
    let is_first = ctx.stage == 0;
    let is_last = ctx.stage == ctx.pp - 1;
    let lora_len = ctx.model.lora_param_count() as usize;

    let mut st = StageState {
        grads: Vec::with_capacity(m_count),
        busy: vec![0f64; m_count],
        comm: vec![0f64; m_count],
        parts: (0..m_count).map(|_| None).collect(),
        caches: (0..m_count).map(|_| None).collect(),
        head_h: (0..m_count).map(|_| None).collect(),
    };

    let w = (ctx.pp - 1 - ctx.stage).min(m_count);
    for m in 0..w {
        forward(&mut ctx, &mut st, m, lo, hi, is_first, is_last)?;
    }
    for m in w..m_count {
        forward(&mut ctx, &mut st, m, lo, hi, is_first, is_last)?;
        backward(&mut ctx, &mut st, m - w, lo, is_first, is_last, lora_len)?;
    }
    for j in (m_count - w)..m_count {
        backward(&mut ctx, &mut st, j, lo, is_first, is_last, lora_len)?;
    }

    Ok(StageOut { grads: st.grads, busy: st.busy, comm: st.comm, parts: st.parts })
}

/// Mutable per-stage pipeline state threaded through the schedule ops.
struct StageState {
    grads: Vec<Vec<f64>>,
    busy: Vec<f64>,
    comm: Vec<f64>,
    parts: Vec<Option<LossParts>>,
    /// Forward caches per in-flight microbatch (this stage's layers).
    caches: Vec<Option<Vec<LayerCache>>>,
    /// Last stage only: residual stream entering the loss head.
    head_h: Vec<Option<Vec<f64>>>,
}

fn forward(
    ctx: &mut StageCtx<'_>,
    st: &mut StageState,
    m: usize,
    lo: usize,
    hi: usize,
    is_first: bool,
    is_last: bool,
) -> Result<()> {
    let mb = &ctx.mbs[m];
    let (b, s) = (mb.shape.0 as usize, mb.shape.1 as usize);
    let h_in = if is_first {
        None
    } else {
        let Some(rx) = ctx.fwd_rx.as_ref() else {
            return Err(anyhow!("stage {} missing forward receiver", ctx.stage));
        };
        let (idx, h) = rx
            .recv()
            .map_err(|_| anyhow!("forward channel closed before microbatch {m}"))?;
        if idx != m {
            return Err(anyhow!("pipeline order violated: got mb {idx}, expected {m}"));
        }
        Some(h)
    };
    let sw = Stopwatch::start();
    let mut comm = 0f64;
    let mut h = match h_in {
        Some(h) => h,
        None => ctx.model.embed_forward(ctx.base, &mb.tokens, b, s),
    };
    let mut caches = Vec::with_capacity(hi - lo);
    for li in lo..hi {
        let (h_next, cache) = ctx.model.layer_forward(
            li,
            ctx.tp,
            ctx.base,
            ctx.lora,
            &h,
            &mb.tokens,
            &ctx.row_tasks[m],
            b,
            s,
            &mut comm,
        );
        h = h_next;
        caches.push(cache);
    }
    st.busy[m] += sw.elapsed_secs();
    st.comm[m] += comm;
    st.caches[m] = Some(caches);
    if is_last {
        st.head_h[m] = Some(h);
    } else {
        let Some(tx) = ctx.fwd_tx.as_ref() else {
            return Err(anyhow!("stage {} missing forward sender", ctx.stage));
        };
        tx.send((m, h))
            .map_err(|_| anyhow!("next stage hung up before microbatch {m}"))?;
    }
    Ok(())
}

fn backward(
    ctx: &mut StageCtx<'_>,
    st: &mut StageState,
    j: usize,
    lo: usize,
    is_first: bool,
    is_last: bool,
    lora_len: usize,
) -> Result<()> {
    let mb = &ctx.mbs[j];
    let (b, s) = (mb.shape.0 as usize, mb.shape.1 as usize);
    let mut comm = 0f64;
    let mut grad = vec![0f64; lora_len];
    let (sw, mut dh) = if is_last {
        let Some(h) = st.head_h[j].take() else {
            return Err(anyhow!("no head activation for microbatch {j}"));
        };
        let sw = Stopwatch::start();
        let (parts, dh_opt) =
            ctx.model
                .head_loss(ctx.base, &h, &mb.tokens, &mb.seg_ids, b, s, true);
        st.parts[j] = Some(parts);
        let Some(dh) = dh_opt else {
            return Err(anyhow!("head_loss produced no gradient"));
        };
        (sw, dh)
    } else {
        let Some(rx) = ctx.bwd_rx.as_ref() else {
            return Err(anyhow!("stage {} missing backward receiver", ctx.stage));
        };
        let (idx, dh) = rx
            .recv()
            .map_err(|_| anyhow!("backward channel closed before microbatch {j}"))?;
        if idx != j {
            return Err(anyhow!("pipeline order violated: got mb {idx}, expected {j}"));
        }
        (Stopwatch::start(), dh)
    };
    let Some(caches) = st.caches[j].take() else {
        return Err(anyhow!("no forward cache for microbatch {j}"));
    };
    for (off, cache) in caches.iter().enumerate().rev() {
        dh = ctx.model.layer_backward(
            lo + off,
            ctx.tp,
            ctx.base,
            ctx.lora,
            &dh,
            cache,
            &mb.tokens,
            &ctx.row_tasks[j],
            b,
            s,
            &mut grad,
            &mut comm,
        );
    }
    st.busy[j] += sw.elapsed_secs();
    st.comm[j] += comm;
    if !is_first {
        let Some(tx) = ctx.bwd_tx.as_ref() else {
            return Err(anyhow!("stage {} missing backward sender", ctx.stage));
        };
        tx.send((j, dh))
            .map_err(|_| anyhow!("previous stage hung up before microbatch {j}"))?;
    }
    st.grads.push(grad);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{NativeModel, NativeSpec};

    #[test]
    fn layer_ranges_partition_the_stack() {
        for n in 1..=8usize {
            for pp in 1..=n {
                let mut next = 0usize;
                for stage in 0..pp {
                    let (lo, hi) = layer_range_for_stage(n, pp, stage);
                    assert_eq!(lo, next, "n={n} pp={pp} stage={stage}");
                    assert!(hi > lo, "every stage needs >= 1 layer");
                    next = hi;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn new_rejects_bad_geometry() {
        let model = Arc::new(NativeModel::new(NativeSpec::micro()).unwrap());
        let (base, _) = model.init_params(1);
        let base = Arc::new(base);
        assert!(StagedEngine::new(model.clone(), base.clone(), 0, 1).is_err());
        assert!(StagedEngine::new(model.clone(), base.clone(), 1, 0).is_err());
        // micro has 4 layers: pp=5 cannot give every stage a layer
        assert!(StagedEngine::new(model.clone(), base.clone(), 1, 5).is_err());
        assert!(StagedEngine::new(model, base, 2, 4).is_ok());
    }

    #[test]
    fn empty_run_is_empty() {
        let model = Arc::new(NativeModel::new(NativeSpec::micro()).unwrap());
        let (base, lora) = model.init_params(2);
        let eng = StagedEngine::new(model, Arc::new(base), 1, 2).unwrap();
        assert!(eng.run(&lora, &[]).unwrap().is_empty());
    }
}
