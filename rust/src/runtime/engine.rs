//! The PJRT execution engine: one compiled executable per microbatch shape.

use super::manifest::Manifest;
use super::params::ParamVector;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Outputs of one train-step execution.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Mean next-token loss over non-pad targets.
    pub loss: f32,
    /// Flat LoRA gradient (same layout as the LoRA param vector).
    pub grad: Vec<f32>,
    /// Number of target tokens contributing to the loss.
    pub tokens: f32,
    /// Per-task loss sums.
    pub task_loss: Vec<f32>,
    /// Per-task token counts.
    pub task_tokens: Vec<f32>,
}

/// Compiled artifacts + a device-resident copy of the frozen base params.
///
/// The base vector is uploaded once (it never changes during FT); per step
/// only the small LoRA vector and the token batch cross the host boundary.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    train_execs: BTreeMap<(u64, u64), xla::PjRtLoadedExecutable>,
    eval_exec: Option<((u64, u64), xla::PjRtLoadedExecutable)>,
    base_buffer: Option<xla::PjRtBuffer>,
}

impl Engine {
    /// Load + compile every artifact under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut train_execs = BTreeMap::new();
        let mut eval_exec = None;
        for a in &manifest.artifacts {
            let path = manifest.artifact_path(a);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
            match a.kind.as_str() {
                "train" => {
                    train_execs.insert((a.batch, a.seq), exe);
                }
                "eval" => {
                    eval_exec = Some(((a.batch, a.seq), exe));
                }
                other => return Err(anyhow!("unknown artifact kind {other}")),
            }
        }
        if train_execs.is_empty() {
            return Err(anyhow!("no train artifacts in {:?}", manifest.dir));
        }
        Ok(Self { client, manifest, train_execs, eval_exec, base_buffer: None })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Microbatch shapes with a compiled train step, ascending by seq.
    pub fn shapes(&self) -> Vec<(u64, u64)> {
        self.manifest.train_shapes()
    }

    /// Upload the frozen base parameters once.
    pub fn set_base(&mut self, base: &ParamVector) -> Result<()> {
        if base.len() as u64 != self.manifest.base_param_count {
            return Err(anyhow!(
                "base params {} != manifest {}",
                base.len(),
                self.manifest.base_param_count
            ));
        }
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&base.data, &[base.len()], None)
            .map_err(|e| anyhow!("uploading base params: {e:?}"))?;
        self.base_buffer = Some(buf);
        Ok(())
    }

    /// Initialize fresh base/LoRA vectors from the manifest rules.
    pub fn init_params(&self, seed: u64) -> (ParamVector, ParamVector) {
        let base = ParamVector::init(
            &self.manifest.base_params,
            self.manifest.base_param_count,
            seed,
        );
        let lora = ParamVector::init(
            &self.manifest.lora_params,
            self.manifest.lora_param_count,
            seed ^ 0x5eed,
        );
        (base, lora)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        shape: (u64, u64),
        lora: &ParamVector,
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<Vec<xla::Literal>> {
        let (b, s) = shape;
        if tokens.len() as u64 != b * s {
            return Err(anyhow!("tokens len {} != {b}x{s}", tokens.len()));
        }
        if seg_ids.len() as u64 != b {
            return Err(anyhow!("seg_ids len {} != {b}", seg_ids.len()));
        }
        if !seg_ids.windows(2).all(|w| w[0] <= w[1]) {
            return Err(anyhow!("seg_ids must be sorted (kernel layout contract)"));
        }
        let base_buf = self
            .base_buffer
            .as_ref()
            .ok_or_else(|| anyhow!("set_base() not called"))?;
        let lora_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&lora.data, &[lora.len()], None)
            .map_err(|e| anyhow!("lora upload: {e:?}"))?;
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[b as usize, s as usize], None)
            .map_err(|e| anyhow!("tokens upload: {e:?}"))?;
        let seg_buf = self
            .client
            .buffer_from_host_buffer::<i32>(seg_ids, &[b as usize], None)
            .map_err(|e| anyhow!("seg upload: {e:?}"))?;
        let outs = exe
            .execute_b(&[base_buf, &lora_buf, &tok_buf, &seg_buf])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Execute one fwd+bwd microbatch of the given shape.
    ///
    /// `tokens`: row-major `[b, s]`, PAD = 0. `seg_ids`: `[b]` sorted task ids.
    pub fn train_step(
        &self,
        shape: (u64, u64),
        lora: &ParamVector,
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<StepOutput> {
        let exe = self
            .train_execs
            .get(&shape)
            .ok_or_else(|| anyhow!("no train artifact for shape {shape:?}"))?;
        let mut parts = self.run(exe, shape, lora, tokens, seg_ids)?;
        if parts.len() != 5 {
            return Err(anyhow!("expected 5 outputs, got {}", parts.len()));
        }
        let task_tokens = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let task_loss = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let tokens_out = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let grad = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(StepOutput {
            loss: loss[0],
            grad,
            tokens: tokens_out[0],
            task_loss,
            task_tokens,
        })
    }

    /// Forward-only loss at the eval artifact's shape.
    pub fn eval_loss(
        &self,
        lora: &ParamVector,
        tokens: &[i32],
        seg_ids: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let (shape, exe) = self
            .eval_exec
            .as_ref()
            .ok_or_else(|| anyhow!("no eval artifact"))?;
        let mut parts = self.run(exe, *shape, lora, tokens, seg_ids)?;
        if parts.len() != 4 {
            return Err(anyhow!("expected 4 outputs, got {}", parts.len()));
        }
        let task_tokens = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let task_loss = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let toks = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = pop_output(&mut parts)?.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss[0], toks[0], task_loss, task_tokens))
    }

    /// Eval artifact shape, if exported.
    pub fn eval_shape(&self) -> Option<(u64, u64)> {
        self.eval_exec.as_ref().map(|(s, _)| *s)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Pop the next executable output. The arity is checked before the pops,
/// so a miss means a malformed artifact — surfaced as an error with
/// context, not a panic (R4).
fn pop_output(parts: &mut Vec<xla::Literal>) -> Result<xla::Literal> {
    parts.pop().ok_or_else(|| anyhow!("executable returned fewer outputs than declared"))
}

