//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! training path — Python is never invoked here.
//!
//! `make artifacts` (build time, once) lowers the L2 JAX train/eval steps to
//! HLO *text* under `artifacts/`; this module loads the text with
//! `HloModuleProto::from_text_file`, compiles one executable per microbatch
//! shape on the PJRT CPU client, and exposes a typed `train_step` /
//! `eval_loss` interface over flat parameter vectors (see
//! `python/compile/aot.py` for the interchange contract and the reasons HLO
//! text is the format).

mod engine;
mod manifest;
pub(crate) mod native;
mod params;
mod staged;

pub use engine::{Engine, StepOutput};
pub use manifest::{ArtifactInfo, InitKind, Manifest, ParamEntry};
pub use native::{NativeModel, NativeSpec, PAD_ID};
pub use params::ParamVector;
pub use staged::{layer_range_for_stage, MbTiming, StageMb, StagedEngine};
