//! Flat parameter vectors: initialization from the manifest's per-leaf
//! rules (reproducing the Python init without running Python) and simple
//! checkpoint I/O.

use super::manifest::{InitKind, ParamEntry};
use crate::util::Rng;
use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A flat f32 parameter vector matching a manifest param table.
#[derive(Debug, Clone)]
pub struct ParamVector {
    pub data: Vec<f32>,
}

impl ParamVector {
    /// Initialize per the manifest rules (zeros / ones / normal(0, std)).
    pub fn init(table: &[ParamEntry], total: u64, seed: u64) -> Self {
        let mut data = vec![0f32; total as usize];
        let mut rng = Rng::new(seed);
        for e in table {
            let lo = e.offset as usize;
            let hi = (e.offset + e.size) as usize;
            match e.init {
                InitKind::Zeros => {}
                InitKind::Ones => data[lo..hi].fill(1.0),
                InitKind::Normal { std } => {
                    for x in &mut data[lo..hi] {
                        *x = rng.normal_ms(0.0, std) as f32;
                    }
                }
            }
        }
        Self { data }
    }

    pub fn zeros(total: u64) -> Self {
        Self { data: vec![0f32; total as usize] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// L2 norm (diagnostics / tests).
    pub fn norm(&self) -> f64 {
        // lint:allow(R5): sequential reduction over one buffer — order is fixed.
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// View one leaf's slice.
    pub fn leaf<'a>(&'a self, e: &ParamEntry) -> &'a [f32] {
        &self.data[e.offset as usize..(e.offset + e.size) as usize]
    }

    /// Save as raw little-endian f32 (LoRA checkpoints are tiny).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        let bytes: Vec<u8> = self.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Load a checkpoint; must match `expected_len`.
    pub fn load(path: impl AsRef<Path>, expected_len: usize) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() != expected_len * 4 {
            return Err(anyhow!(
                "checkpoint {:?}: {} bytes, expected {}",
                path.as_ref(),
                bytes.len(),
                expected_len * 4
            ));
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self { data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Vec<ParamEntry> {
        vec![
            ParamEntry {
                name: "['w']".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
                init: InitKind::Normal { std: 0.5 },
            },
            ParamEntry {
                name: "['g']".into(),
                shape: vec![4],
                offset: 6,
                size: 4,
                init: InitKind::Ones,
            },
            ParamEntry {
                name: "['a']".into(),
                shape: vec![2],
                offset: 10,
                size: 2,
                init: InitKind::Zeros,
            },
        ]
    }

    #[test]
    fn init_rules_apply() {
        let v = ParamVector::init(&table(), 12, 42);
        assert_eq!(v.len(), 12);
        assert!(v.data[0..6].iter().any(|&x| x != 0.0));
        assert!(v.data[6..10].iter().all(|&x| x == 1.0));
        assert!(v.data[10..12].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn init_deterministic() {
        let a = ParamVector::init(&table(), 12, 7);
        let b = ParamVector::init(&table(), 12, 7);
        assert_eq!(a.data, b.data);
        let c = ParamVector::init(&table(), 12, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let v = ParamVector::init(&table(), 12, 1);
        let dir = std::env::temp_dir().join("lobra_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.bin");
        v.save(&p).unwrap();
        let w = ParamVector::load(&p, 12).unwrap();
        assert_eq!(v.data, w.data);
        assert!(ParamVector::load(&p, 13).is_err());
    }

    #[test]
    fn leaf_views() {
        let v = ParamVector::init(&table(), 12, 3);
        let t = table();
        assert_eq!(v.leaf(&t[1]), &[1.0, 1.0, 1.0, 1.0]);
    }
}
