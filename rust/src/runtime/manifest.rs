//! `artifacts/manifest.json` — the contract between aot.py and the runtime.
//! Parsed with the in-tree JSON parser (offline build: no serde_json).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Initialization rule for one parameter leaf.
#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    Zeros,
    Ones,
    Normal { std: f64 },
}

impl InitKind {
    fn from_json(j: &Json) -> Result<Self> {
        match j.get("kind").and_then(Json::as_str) {
            Some("zeros") => Ok(InitKind::Zeros),
            Some("ones") => Ok(InitKind::Ones),
            Some("normal") => Ok(InitKind::Normal {
                std: j
                    .get("std")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("normal init missing std"))?,
            }),
            other => bail!("unknown init kind {other:?}"),
        }
    }
}

/// One flattened parameter leaf.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<u64>,
    pub offset: u64,
    pub size: u64,
    pub init: InitKind,
}

impl ParamEntry {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param missing shape"))?
                .iter()
                .map(|x| x.as_u64().ok_or_else(|| anyhow!("bad shape dim")))
                .collect::<Result<_>>()?,
            offset: j.get("offset").and_then(Json::as_u64).ok_or_else(|| anyhow!("offset"))?,
            size: j.get("size").and_then(Json::as_u64).ok_or_else(|| anyhow!("size"))?,
            init: InitKind::from_json(
                j.get("init").ok_or_else(|| anyhow!("param missing init"))?,
            )?,
        })
    }
}

/// One exported HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub kind: String,
    pub batch: u64,
    pub seq: u64,
    pub sha256: String,
}

impl ArtifactInfo {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            file: j.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("file"))?.into(),
            kind: j.get("kind").and_then(Json::as_str).ok_or_else(|| anyhow!("kind"))?.into(),
            batch: j.get("batch").and_then(Json::as_u64).ok_or_else(|| anyhow!("batch"))?,
            seq: j.get("seq").and_then(Json::as_u64).ok_or_else(|| anyhow!("seq"))?,
            sha256: j
                .get("sha256")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
        })
    }
}

/// Model metadata inside the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub d_ff: u64,
    pub n_tasks: u64,
    pub lora_rank: u64,
    pub lora_alpha: f64,
    pub block_rows: u64,
    pub pad_id: i64,
}

impl ModelMeta {
    fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<u64> {
            j.get(k).and_then(Json::as_u64).ok_or_else(|| anyhow!("model.{k}"))
        };
        Ok(Self {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            n_tasks: u("n_tasks")?,
            lora_rank: u("lora_rank")?,
            lora_alpha: j
                .get("lora_alpha")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("model.lora_alpha"))?,
            block_rows: u("block_rows")?,
            pad_id: j.get("pad_id").and_then(Json::as_i64).unwrap_or(0),
        })
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub model: ModelMeta,
    pub base_param_count: u64,
    pub lora_param_count: u64,
    pub base_params: Vec<ParamEntry>,
    pub lora_params: Vec<ParamEntry>,
    pub artifacts: Vec<ArtifactInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let params = |key: &str| -> Result<Vec<ParamEntry>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing {key}"))?
                .iter()
                .map(ParamEntry::from_json)
                .collect()
        };
        let m = Self {
            preset: j
                .get("preset")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            model: ModelMeta::from_json(
                j.get("model").ok_or_else(|| anyhow!("manifest missing model"))?,
            )?,
            base_param_count: j
                .get("base_param_count")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("base_param_count"))?,
            lora_param_count: j
                .get("lora_param_count")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("lora_param_count"))?,
            base_params: params("base_params")?,
            lora_params: params("lora_params")?,
            artifacts: j
                .get("artifacts")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifacts"))?
                .iter()
                .map(ArtifactInfo::from_json)
                .collect::<Result<_>>()?,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation: contiguous offsets, artifacts on disk.
    pub fn validate(&self) -> Result<()> {
        for (label, table, total) in [
            ("base", &self.base_params, self.base_param_count),
            ("lora", &self.lora_params, self.lora_param_count),
        ] {
            let mut off = 0u64;
            for e in table {
                if e.offset != off {
                    bail!("{label} param {} offset {} != {off}", e.name, e.offset);
                }
                let numel: u64 = e.shape.iter().product::<u64>().max(1);
                if numel != e.size {
                    bail!("{label} param {} size mismatch", e.name);
                }
                off += e.size;
            }
            if off != total {
                bail!("{label} params sum {off} != {total}");
            }
        }
        for a in &self.artifacts {
            let p = self.dir.join(&a.file);
            if !p.exists() {
                bail!("artifact missing: {p:?}");
            }
        }
        Ok(())
    }

    /// Train artifact for an exact (batch, seq) shape.
    pub fn train_artifact(&self, batch: u64, seq: u64) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "train" && a.batch == batch && a.seq == seq)
    }

    /// All train shapes, ascending by sequence length.
    pub fn train_shapes(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "train")
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort_by_key(|&(_, s)| s);
        v
    }

    /// Smallest train shape whose seq covers `len` (for padding routing).
    pub fn shape_for_len(&self, len: u64) -> Option<(u64, u64)> {
        self.train_shapes().into_iter().find(|&(_, s)| s >= len)
    }

    pub fn artifact_path(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn load_and_validate_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.base_param_count > 0);
        assert!(m.lora_param_count > 0);
        assert!(!m.train_shapes().is_empty());
        let shapes = m.train_shapes();
        for w in shapes.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let (_, s) = m.shape_for_len(10).unwrap();
        assert!(s >= 10);
        assert!(m.train_artifact(shapes[0].0, shapes[0].1).is_some());
    }

    #[test]
    fn init_kind_parses() {
        let j = Json::parse(r#"{"kind":"normal","std":0.02}"#).unwrap();
        assert_eq!(InitKind::from_json(&j).unwrap(), InitKind::Normal { std: 0.02 });
        let j2 = Json::parse(r#"{"kind":"zeros"}"#).unwrap();
        assert_eq!(InitKind::from_json(&j2).unwrap(), InitKind::Zeros);
        let j3 = Json::parse(r#"{"kind":"uniform"}"#).unwrap();
        assert!(InitKind::from_json(&j3).is_err());
    }
}
