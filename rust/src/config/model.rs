//! Model architecture descriptions for the cost model and planner.



/// Architectural shape of a transformer base model.
///
/// Only the quantities the cost/memory model needs are kept; the real
/// weights live in the HLO artifacts (for runtime-trained presets) or are
/// never materialized (for the 7B/32B/70B planning studies, exactly like the
/// paper plans from profiles rather than instantiating models on the
/// planner's machine).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDesc {
    pub name: String,
    pub n_layers: u32,
    pub d_model: u64,
    pub n_heads: u32,
    pub d_ff: u64,
    pub vocab: u64,
    /// Total parameter count (computed if 0 at construction).
    pub params: u64,
    /// LoRA rank used for the adapters.
    pub lora_rank: u32,
    /// Bytes per parameter for weights (2 = bf16).
    pub weight_bytes: u64,
}

impl ModelDesc {
    pub fn new(
        name: &str,
        n_layers: u32,
        d_model: u64,
        n_heads: u32,
        d_ff: u64,
        vocab: u64,
    ) -> Self {
        let mut m = Self {
            name: name.to_string(),
            n_layers,
            d_model,
            n_heads,
            d_ff,
            vocab,
            params: 0,
            lora_rank: 8,
            weight_bytes: 2,
        };
        m.params = m.computed_params();
        m
    }

    /// Parameter count from shape: embeddings + per-layer attention & MLP.
    pub fn computed_params(&self) -> u64 {
        let d = self.d_model;
        let per_layer = 4 * d * d           // q,k,v,o projections
            + 3 * d * self.d_ff             // gated MLP (gate/up/down)
            + 4 * d; // norms
        self.vocab * d + self.n_layers as u64 * per_layer + d
    }

    /// Per-layer parameters (used by the per-layer profiling model).
    pub fn params_per_layer(&self) -> u64 {
        (self.params - self.vocab * self.d_model) / self.n_layers as u64
    }

    /// LoRA parameter count per task (B:[in,r] + A:[r,out] on QKVO + MLP).
    pub fn lora_params_per_task(&self) -> u64 {
        let r = self.lora_rank as u64;
        let d = self.d_model;
        let per_layer = (d + 3 * d) * r      // qkv
            + (d + d) * r                    // out
            + (d + self.d_ff) * r            // up
            + (self.d_ff + d) * r; // down
        self.n_layers as u64 * per_layer
    }

    // --- paper evaluation models -------------------------------------------------

    pub fn llama2_7b() -> Self {
        Self::new("llama2-7b", 32, 4096, 32, 11008, 32000)
    }

    pub fn qwen25_32b() -> Self {
        Self::new("qwen2.5-32b", 64, 5120, 40, 27648, 152064)
    }

    pub fn llama2_70b() -> Self {
        Self::new("llama2-70b", 80, 8192, 64, 28672, 32000)
    }

    // --- CPU-scale presets matching python/compile/model.py PRESETS ---------------

    pub fn tiny() -> Self {
        Self::new("tiny", 4, 256, 8, 1024, 2048)
    }

    pub fn nano() -> Self {
        Self::new("nano", 2, 128, 4, 256, 512)
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" | "7b" => Some(Self::llama2_7b()),
            "qwen2.5-32b" | "32b" => Some(Self::qwen25_32b()),
            "llama2-70b" | "70b" => Some(Self::llama2_70b()),
            "tiny" => Some(Self::tiny()),
            "nano" => Some(Self::nano()),
            _ => None,
        }
    }

    /// Weight bytes per GPU under a (tp, pp) sharding.
    pub fn weight_bytes_per_gpu(&self, tp: u32, pp: u32) -> u64 {
        self.params * self.weight_bytes / (tp as u64 * pp as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        let b7 = ModelDesc::llama2_7b();
        assert!((6.0e9..8.5e9).contains(&(b7.params as f64)), "{}", b7.params);
        let b32 = ModelDesc::qwen25_32b();
        assert!((28.0e9..40.0e9).contains(&(b32.params as f64)), "{}", b32.params);
        let b70 = ModelDesc::llama2_70b();
        assert!((60.0e9..80.0e9).contains(&(b70.params as f64)), "{}", b70.params);
    }

    #[test]
    fn lora_params_small_fraction() {
        let m = ModelDesc::llama2_7b();
        let frac = m.lora_params_per_task() as f64 / m.params as f64;
        assert!(frac < 0.01, "LoRA fraction {frac}");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["7b", "32b", "70b", "tiny", "nano"] {
            assert!(ModelDesc::by_name(n).is_some());
        }
        assert!(ModelDesc::by_name("gpt-5").is_none());
    }

    #[test]
    fn sharding_divides_weights() {
        let m = ModelDesc::llama2_7b();
        assert_eq!(m.weight_bytes_per_gpu(1, 1), m.params * 2);
        assert_eq!(m.weight_bytes_per_gpu(2, 4), m.params * 2 / 8);
    }
}
