//! Configuration system: model descriptions, parallel configurations, and
//! FT task specifications.
//!
//! `ModelDesc` carries the architectural shape the cost model needs (layers,
//! hidden size, parameter count); presets cover the paper's three evaluation
//! models (Llama2-7B, Qwen2.5-32B, Llama2-70B) plus the CPU-scale presets the
//! real PJRT runtime trains end-to-end.

mod model;
mod parallel;
mod tasks;

pub use model::ModelDesc;
pub use parallel::ParallelConfig;
pub use tasks::{TaskMeta, TaskSet, TaskSpec};
