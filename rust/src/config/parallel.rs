//! Parallel configurations ⟨TP, PP⟩ — the unit of heterogeneity in LobRA.


use std::fmt;

/// One candidate parallel configuration `S_i = ⟨TP=α, PP=β⟩`.
///
/// `n() = tp*pp` GPUs deploy one FT replica with this configuration. The
/// paper's Table 2 notation `⟨α,β⟩×γ` is `γ` replicas of `ParallelConfig
/// { tp: α, pp: β }`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ParallelConfig {
    pub tp: u32,
    pub pp: u32,
}

impl ParallelConfig {
    pub const fn new(tp: u32, pp: u32) -> Self {
        Self { tp, pp }
    }

    /// GPUs per replica (`n_i` in the paper).
    pub const fn n(&self) -> u32 {
        self.tp * self.pp
    }

    /// All ⟨tp,pp⟩ with tp, pp powers of two, `tp <= max_tp`, `n <= max_n`.
    ///
    /// `max_tp` is typically the server size (8): TP across servers is only
    /// allowed when a single server cannot hold the model (the paper's 70B
    /// ⟨16,1⟩ case), controlled by `allow_cross_server_tp`.
    pub fn enumerate(max_n: u32, max_tp: u32, allow_cross_server_tp: bool) -> Vec<Self> {
        let mut out = Vec::new();
        let mut tp = 1;
        while tp <= max_n {
            let mut pp = 1;
            while tp * pp <= max_n {
                let ok_tp = tp <= max_tp || allow_cross_server_tp;
                if ok_tp {
                    out.push(Self::new(tp, pp));
                }
                pp *= 2;
            }
            tp *= 2;
        }
        out.sort();
        out
    }
}

impl fmt::Display for ParallelConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<{},{}>", self.tp, self.pp)
    }
}

/// Parse "⟨2,4⟩" / "<2,4>" / "2,4" into a config.
impl std::str::FromStr for ParallelConfig {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s
            .trim()
            .trim_start_matches(['⟨', '<', '('])
            .trim_end_matches(['⟩', '>', ')']);
        let (a, b) = t
            .split_once(',')
            .ok_or_else(|| format!("bad parallel config: {s}"))?;
        Ok(Self::new(
            a.trim().parse().map_err(|e| format!("{e}"))?,
            b.trim().parse().map_err(|e| format!("{e}"))?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_is_product() {
        assert_eq!(ParallelConfig::new(2, 4).n(), 8);
    }

    #[test]
    fn enumerate_respects_limits() {
        let cfgs = ParallelConfig::enumerate(16, 8, false);
        assert!(cfgs.contains(&ParallelConfig::new(1, 1)));
        assert!(cfgs.contains(&ParallelConfig::new(8, 2)));
        assert!(!cfgs.iter().any(|c| c.tp > 8));
        assert!(!cfgs.iter().any(|c| c.n() > 16));
        let cfgs2 = ParallelConfig::enumerate(16, 8, true);
        assert!(cfgs2.contains(&ParallelConfig::new(16, 1)));
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["<2,4>", "⟨2,4⟩", "2,4", " (2, 4) "] {
            let c: ParallelConfig = s.parse().unwrap();
            assert_eq!(c, ParallelConfig::new(2, 4));
        }
        assert_eq!(ParallelConfig::new(2, 4).to_string(), "<2,4>");
    }
}
