//! FT task (tenant) specifications — one per uploaded fine-tuning request.

use crate::data::{DatasetProfile, LengthDistribution};


/// Tenant-class metadata riding on a task: the priority/SLO tier drives
/// admission control in the serving runtime (`coordinator::runtime`) —
/// lower numbers are *more* important; an arrival that cannot be admitted
/// may preempt a strictly lower-priority (numerically higher) tenant, and
/// the serve report breaks time-to-admission down per tier.
///
/// Planning is tier-blind by design: tiers decide *who runs*, never *how*
/// the plan search scores a task set, so every plan-identity certificate
/// is unaffected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TaskMeta {
    /// Priority/SLO tier, 0 = highest. Default 0.
    pub tier: u8,
}

/// One fine-tuning request: a dataset (length distribution) + batch size.
///
/// Mirrors the paper's Table 4 rows: each FT dataset is one task with its
/// own per-step batch size; the joint batch fuses all tasks' batches.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    /// Sequences drawn per training step for this task.
    pub batch_size: u32,
    /// Sequence length distribution of the task's dataset.
    pub lengths: LengthDistribution,
    /// Tenant-class metadata (priority tier); defaults to tier 0.
    pub meta: TaskMeta,
}

impl TaskSpec {
    pub fn new(name: &str, batch_size: u32, lengths: LengthDistribution) -> Self {
        Self { name: name.to_string(), batch_size, lengths, meta: TaskMeta::default() }
    }

    pub fn from_profile(p: &DatasetProfile) -> Self {
        Self::new(p.name, p.batch_size, p.distribution())
    }

    /// Builder-style tier override (0 = highest priority).
    pub fn with_tier(mut self, tier: u8) -> Self {
        self.meta.tier = tier;
        self
    }
}

/// The batch of co-existing FT tasks being jointly trained.
#[derive(Debug, Clone, Default)]
pub struct TaskSet {
    pub tasks: Vec<TaskSpec>,
}

impl TaskSet {
    pub fn new(tasks: Vec<TaskSpec>) -> Self {
        Self { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Joint (fused) batch size `B = Σ_t batch_size_t`.
    pub fn joint_batch(&self) -> u32 {
        self.tasks.iter().map(|t| t.batch_size).sum()
    }

    /// All 12 paper datasets (Table 4) as tasks.
    pub fn paper_all() -> Self {
        Self::new(
            DatasetProfile::all()
                .iter()
                .map(TaskSpec::from_profile)
                .collect(),
        )
    }

    /// The 6-task subset used for the 7B / 16-GPU experiments (App. B.3).
    pub fn paper_7b_subset() -> Self {
        let names = [
            "databricks-dolly-15k",
            "Evol-Instruct",
            "XSum",
            "CommitPackFt",
            "MeetingBank",
            "python_code_instructions",
        ];
        Self::new(
            DatasetProfile::all()
                .iter()
                .filter(|p| names.contains(&p.name))
                .map(TaskSpec::from_profile)
                .collect(),
        )
    }

    /// The 4-task subset used in the scalability study (App. B.3).
    pub fn paper_scalability_subset() -> Self {
        let names = ["Evol-Instruct", "CommitPackFt", "BillSum", "PubMedQA"];
        Self::new(
            DatasetProfile::all()
                .iter()
                .filter(|p| names.contains(&p.name))
                .map(TaskSpec::from_profile)
                .collect(),
        )
    }

    /// First `n` tasks (cycling if n > 12) — used by the task-scalability bench.
    pub fn paper_first_n(n: usize) -> Self {
        let all = DatasetProfile::all();
        Self::new(
            (0..n)
                .map(|i| {
                    let p = &all[i % all.len()];
                    let mut t = TaskSpec::from_profile(p);
                    if i >= all.len() {
                        t.name = format!("{}#{}", t.name, i / all.len() + 1);
                    }
                    t
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_all_has_12_tasks() {
        let ts = TaskSet::paper_all();
        assert_eq!(ts.len(), 12);
        assert!(ts.joint_batch() > 0);
    }

    #[test]
    fn subset_selection() {
        assert_eq!(TaskSet::paper_7b_subset().len(), 6);
        assert_eq!(TaskSet::paper_scalability_subset().len(), 4);
    }

    #[test]
    fn first_n_cycles() {
        let ts = TaskSet::paper_first_n(16);
        assert_eq!(ts.len(), 16);
        assert!(ts.tasks[12].name.contains('#'));
    }
}
