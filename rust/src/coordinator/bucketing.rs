//! Dynamic bucketing (paper §4.3, Eq. 4).
//!
//! Sequences must be padded to their bucket's boundary; fixed boundaries
//! waste tokens when the sampled batch's length profile shifts. The DP
//! below starts from `U` fine-grained intervals (equal width, e.g. 256) and
//! merges them into at most `R` buckets minimizing total padding:
//!
//! ```text
//! State[i][j] = min padding bucketing the first i intervals into j buckets
//! State[i+1][j+1] = min_{i' <= i} State[i'][j] + Σ_{i''=i'+1..=i} |I_i''|·(u_{i+1} − u_{i''})
//! ```
//!
//! Complexity `O(B + R·U²)` (`B` to histogram the batch). Empty intervals
//! are skipped, which keeps `U` small in practice (paper footnote 3).

use crate::util::stats;

/// Bucketing result: `R` boundaries (ascending, last ≥ max length) and the
/// per-bucket sequence counts of the batch it was derived from.
#[derive(Debug, Clone, PartialEq)]
pub struct Buckets {
    /// Bucket upper boundaries (pad-to lengths), ascending.
    pub boundaries: Vec<u32>,
    /// Sequences per bucket for the batch used to derive the boundaries.
    pub counts: Vec<u64>,
    /// Total padding tokens incurred by this bucketing (incl. intra-interval).
    pub padding_tokens: u64,
}

impl Buckets {
    /// Index of the bucket a sequence of length `len` falls into.
    pub fn bucket_of(&self, len: u32) -> usize {
        self.boundaries
            .partition_point(|&b| b < len)
            .min(self.boundaries.len() - 1)
    }

    /// Total tokens after padding for the derivation batch.
    pub fn padded_tokens(&self) -> u64 {
        self.boundaries
            .iter()
            .zip(&self.counts)
            .map(|(&b, &c)| b as u64 * c)
            .sum()
    }
}

/// Options for [`bucketize`].
#[derive(Debug, Clone)]
pub struct BucketingOptions {
    /// Max number of buckets `R` (paper default 16).
    pub max_buckets: usize,
    /// Width of the pre-defined intervals `u_i` (paper: 256, 512, ...).
    pub interval: u32,
    /// Hard cap on interval count `U` (sequences longer than
    /// `interval×max_intervals` share the last interval).
    pub max_intervals: usize,
}

impl Default for BucketingOptions {
    fn default() -> Self {
        Self { max_buckets: 16, interval: 256, max_intervals: 128 }
    }
}

/// Fixed equal-width boundaries (the non-dynamic baseline of Figure 8).
pub fn fixed_boundaries(lengths: &[u32], opts: &BucketingOptions) -> Buckets {
    let max_len = lengths.iter().copied().max().unwrap_or(opts.interval);
    let r = opts.max_buckets as u32;
    let width = max_len.div_ceil(r).max(1);
    // round width up to a multiple of 16 for kernel alignment
    let width = width.div_ceil(16) * 16;
    let boundaries: Vec<u32> = (1..=r).map(|k| k * width).collect();
    let mut counts = vec![0u64; boundaries.len()];
    let mut padding = 0u64;
    for &l in lengths {
        let j = boundaries.partition_point(|&b| b < l).min(boundaries.len() - 1);
        counts[j] += 1;
        padding += (boundaries[j].max(l) - l) as u64;
    }
    Buckets { boundaries, counts, padding_tokens: padding }
}

/// Dynamic bucketing DP (Eq. 4): minimal-padding boundaries for `lengths`.
pub fn bucketize(lengths: &[u32], opts: &BucketingOptions) -> Buckets {
    assert!(opts.max_buckets >= 1);
    if lengths.is_empty() {
        return Buckets {
            boundaries: vec![opts.interval],
            counts: vec![0],
            padding_tokens: 0,
        };
    }
    let max_len = *lengths.iter().max().unwrap();
    // interval grid u_1..u_U covering max_len
    let mut n_intervals = (max_len.div_ceil(opts.interval) as usize).max(1);
    let mut interval = opts.interval;
    if n_intervals > opts.max_intervals {
        // widen intervals to respect the cap
        interval = max_len.div_ceil(opts.max_intervals as u32);
        interval = interval.div_ceil(16) * 16;
        n_intervals = (max_len.div_ceil(interval) as usize).max(1);
    }
    let u: Vec<u32> = (1..=n_intervals as u32).map(|k| k * interval).collect();

    // histogram per interval + intra-interval padding (constant term)
    let mut hist = vec![0u64; n_intervals];
    let mut intra_padding = 0u64;
    for &l in lengths {
        let idx = ((l.div_ceil(interval)) as usize - 1).min(n_intervals - 1);
        hist[idx] += 1;
        intra_padding += (u[idx].max(l) - l) as u64;
    }

    // Drop empty intervals (paper footnote 3) — they can never be optimal
    // boundaries except as carriers for later mass, which non-empty
    // intervals to their right dominate.
    let occupied: Vec<usize> = (0..n_intervals).filter(|&i| hist[i] > 0).collect();
    let uu: Vec<u64> = occupied.iter().map(|&i| u[i] as u64).collect();
    let hh: Vec<u64> = occupied.iter().map(|&i| hist[i]).collect();
    let n = uu.len();
    let r = opts.max_buckets.min(n);

    // State[i][j]: min inter-interval padding for first i occupied
    // intervals in j buckets. Transition per Eq. 4.
    const INF: u64 = u64::MAX / 4;
    let mut state = vec![vec![INF; r + 1]; n + 1];
    for j in 0..=r {
        state[0][j] = 0;
    }
    // choice[i][j] = i' that attained the optimum (for reconstruction)
    let mut choice = vec![vec![0usize; r + 1]; n + 1];
    // prefix sums for Σ |I_i''| and Σ |I_i''|·u_i''
    let mut pref_cnt = vec![0u64; n + 1];
    let mut pref_cu = vec![0u64; n + 1];
    for i in 0..n {
        pref_cnt[i + 1] = pref_cnt[i] + hh[i];
        pref_cu[i + 1] = pref_cu[i] + hh[i] * uu[i];
    }
    for i in 1..=n {
        for j in 1..=r {
            // bucket (i'+1 ..= i] padded to u_i
            for ip in 0..i {
                if state[ip][j - 1] >= INF {
                    continue;
                }
                let cnt = pref_cnt[i] - pref_cnt[ip];
                let cu = pref_cu[i] - pref_cu[ip];
                let pad = cnt * uu[i - 1] - cu;
                let cand = state[ip][j - 1] + pad;
                if cand < state[i][j] {
                    state[i][j] = cand;
                    choice[i][j] = ip;
                }
            }
        }
    }

    // reconstruct boundaries
    let mut bounds_rev = Vec::with_capacity(r);
    let (mut i, mut j) = (n, r);
    // the DP always uses exactly min(r, n) buckets optimally because extra
    // buckets never hurt; walk back from state[n][r]
    while i > 0 {
        bounds_rev.push(uu[i - 1] as u32);
        let ip = choice[i][j];
        i = ip;
        j -= 1;
    }
    bounds_rev.reverse();
    let boundaries = bounds_rev;

    let mut counts = vec![0u64; boundaries.len()];
    for &l in lengths {
        let idx = boundaries.partition_point(|&b| b < l).min(boundaries.len() - 1);
        counts[idx] += 1;
    }
    let inter_padding = state[n][r];
    Buckets {
        boundaries,
        counts,
        padding_tokens: inter_padding + intra_padding,
    }
}

/// Build `Buckets` for a batch against pre-existing boundaries (the fixed-
/// boundary mode of Figure 8's ablation: boundaries chosen once from a
/// calibration sample, reused every step).
pub fn buckets_from_boundaries(lengths: &[u32], boundaries: &[u32]) -> Buckets {
    let mut counts = vec![0u64; boundaries.len()];
    let mut padding = 0u64;
    for &l in lengths {
        let j = boundaries.partition_point(|&b| b < l).min(boundaries.len() - 1);
        counts[j] += 1;
        padding += (boundaries[j].max(l) - l) as u64;
    }
    Buckets { boundaries: boundaries.to_vec(), counts, padding_tokens: padding }
}

/// Padding tokens if `lengths` are padded to the given boundaries
/// (nearest boundary ≥ length; lengths above the top boundary clamp).
pub fn padding_for(lengths: &[u32], boundaries: &[u32]) -> u64 {
    let mut pad = 0u64;
    for &l in lengths {
        let j = boundaries.partition_point(|&b| b < l).min(boundaries.len() - 1);
        pad += (boundaries[j].max(l) - l) as u64;
    }
    pad
}

/// Mean padding ratio: padding / (padding + real tokens).
pub fn padding_ratio(lengths: &[u32], boundaries: &[u32]) -> f64 {
    let pad = padding_for(lengths, boundaries) as f64;
    let real: u64 = lengths.iter().map(|&l| l as u64).sum();
    if real == 0 {
        return 0.0;
    }
    pad / (pad + real as f64)
}

/// Brute-force optimal bucketing by exhaustive boundary subsets — test
/// oracle only (exponential).
#[doc(hidden)]
pub fn bucketize_bruteforce(lengths: &[u32], interval: u32, max_buckets: usize) -> u64 {
    let max_len = lengths.iter().copied().max().unwrap_or(interval);
    let n_intervals = (max_len.div_ceil(interval) as usize).max(1);
    let u: Vec<u32> = (1..=n_intervals as u32).map(|k| k * interval).collect();
    let last = n_intervals - 1;
    let mut best = u64::MAX;
    // choose subsets of boundaries that include the last interval
    let m = n_intervals - 1; // optional boundary positions
    for mask in 0..(1u64 << m) {
        if (mask.count_ones() as usize + 1) > max_buckets {
            continue;
        }
        let mut bounds: Vec<u32> = (0..m)
            .filter(|&k| mask & (1 << k) != 0)
            .map(|k| u[k])
            .collect();
        bounds.push(u[last]);
        let pad = padding_for(lengths, &bounds);
        best = best.min(pad);
    }
    best
}

/// Moment summary of a batch's lengths (diagnostics).
pub fn length_moments(lengths: &[u32]) -> stats::Moments {
    let xs: Vec<f64> = lengths.iter().map(|&l| l as f64).collect();
    stats::moments(&xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_lengths() {
        let lengths = vec![100, 300, 700, 2000, 4100];
        let b = bucketize(&lengths, &BucketingOptions::default());
        assert!(*b.boundaries.last().unwrap() >= 4100);
        assert_eq!(b.counts.iter().sum::<u64>(), 5);
    }

    #[test]
    fn dp_matches_bruteforce() {
        let opts = BucketingOptions { max_buckets: 3, interval: 100, max_intervals: 64 };
        let cases: Vec<Vec<u32>> = vec![
            vec![50, 99, 150, 380, 520, 900],
            vec![10, 20, 30, 800],
            vec![500; 10],
            vec![100, 200, 300, 400, 500, 600, 700, 800],
        ];
        for lengths in cases {
            let dp = bucketize(&lengths, &opts);
            let bf = bucketize_bruteforce(&lengths, 100, 3);
            assert_eq!(dp.padding_tokens, bf, "lengths {lengths:?}: dp {} bf {bf}", dp.padding_tokens);
        }
    }

    #[test]
    fn more_buckets_never_more_padding() {
        let mut rng = crate::util::Rng::new(5);
        let lengths: Vec<u32> =
            (0..500).map(|_| rng.range(16, 8192) as u32).collect();
        let mut prev = u64::MAX;
        for r in [2, 4, 8, 16, 32] {
            let b = bucketize(
                &lengths,
                &BucketingOptions { max_buckets: r, interval: 256, max_intervals: 128 },
            );
            assert!(b.padding_tokens <= prev, "R={r}");
            prev = b.padding_tokens;
        }
    }

    #[test]
    fn dynamic_beats_fixed() {
        // Skewed batch: dynamic boundaries should pad less than equal-width.
        let mut rng = crate::util::Rng::new(6);
        let mut lengths: Vec<u32> = (0..400)
            .map(|_| (rng.lognormal(5.5, 1.0) as u32).clamp(16, 16384))
            .collect();
        lengths.push(16384); // one huge outlier
        let opts = BucketingOptions { max_buckets: 8, interval: 256, max_intervals: 128 };
        let dynamic = bucketize(&lengths, &opts);
        let fixed = fixed_boundaries(&lengths, &opts);
        assert!(
            dynamic.padding_tokens < fixed.padding_tokens,
            "dyn {} vs fixed {}",
            dynamic.padding_tokens,
            fixed.padding_tokens
        );
    }

    #[test]
    fn single_bucket_pads_to_max() {
        let lengths = vec![100, 200, 999];
        let b = bucketize(
            &lengths,
            &BucketingOptions { max_buckets: 1, interval: 100, max_intervals: 64 },
        );
        assert_eq!(b.boundaries.len(), 1);
        assert_eq!(b.boundaries[0], 1000);
        // padding = (1000-100)+(1000-200)+(1000-999)
        assert_eq!(b.padding_tokens, 900 + 800 + 1);
    }

    #[test]
    fn empty_input() {
        let b = bucketize(&[], &BucketingOptions::default());
        assert_eq!(b.counts.iter().sum::<u64>(), 0);
        assert_eq!(b.padding_tokens, 0);
    }

    #[test]
    fn bucket_of_lookup() {
        let b = Buckets {
            boundaries: vec![256, 1024, 4096],
            counts: vec![0, 0, 0],
            padding_tokens: 0,
        };
        assert_eq!(b.bucket_of(100), 0);
        assert_eq!(b.bucket_of(256), 0);
        assert_eq!(b.bucket_of(257), 1);
        assert_eq!(b.bucket_of(9999), 2); // clamps to last
    }

    #[test]
    fn interval_cap_respected() {
        let lengths = vec![32768, 100, 50];
        let b = bucketize(
            &lengths,
            &BucketingOptions { max_buckets: 4, interval: 16, max_intervals: 8 },
        );
        assert!(*b.boundaries.last().unwrap() >= 32768);
        assert!(b.boundaries.len() <= 4);
    }

    #[test]
    fn padding_ratio_sane() {
        let r = padding_ratio(&[100, 100], &[128]);
        assert!((r - 56.0 / 256.0).abs() < 1e-9);
        assert_eq!(padding_ratio(&[], &[128]), 0.0);
    }
}
