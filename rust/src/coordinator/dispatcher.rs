//! Per-step workload-balanced data dispatching (paper §4.3, Eq. 3).
//!
//! Given the deployed heterogeneous replicas (`p*` from the planner) and
//! the current fused batch's buckets, build the min–max dispatch problem
//! with the cost model's linear coefficients and solve it. The result maps
//! every bucket's sequences onto concrete replicas, ready for execution
//! (simulated or real). Solving is sub-millisecond and overlaps with the
//! previous step's training, as in the paper (Figure 10, left).

use crate::config::ParallelConfig;
use crate::coordinator::bucketing::Buckets;
use crate::coordinator::planner::DeploymentPlan;
use crate::costmodel::{BucketLoad, CostModel, CostTable};
use crate::solver::{self, DispatchProblem, GroupSpec};

/// Dispatch policy — the ablation axis of Figure 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Every bucket to its most efficient supporting group (Fig. 4(c)).
    LengthBased,
    /// Workload-balanced min–max solve (Fig. 4(d), the LobRA default).
    Balanced,
}

/// Where each bucket's sequences go: `d[group][bucket]` plus evaluated
/// per-replica times from the *exact* (non-linearized) cost model.
#[derive(Debug, Clone)]
pub struct DispatchPlan {
    /// Deployed groups (config, replica count), aligned with `d` rows.
    pub groups: Vec<(ParallelConfig, u32)>,
    /// Bucket boundaries this dispatch was computed for.
    pub boundaries: Vec<u32>,
    /// Assignment counts per (group, bucket).
    pub d: Vec<Vec<u64>>,
    /// Exact per-replica busy times (flattened: group-major).
    pub replica_times: Vec<(ParallelConfig, f64)>,
    /// Per-replica dispatched loads (flattened group-major, aligned with
    /// `replica_times`): exactly the loads [`Dispatcher::evaluate`] timed,
    /// so executors ([`crate::exec`]) run the very assignment the predicted
    /// step time was computed from.
    pub replica_assignments: Vec<Vec<BucketLoad>>,
    /// Predicted step time (max replica time).
    pub predicted_step_time: f64,
    /// Linear-model makespan from the solver (diagnostics).
    pub solver_makespan: f64,
}

impl DispatchPlan {
    /// Per-replica loads of group `i`, as recorded by
    /// [`Dispatcher::evaluate`]'s per-sequence-cost LPT split. (This used
    /// to re-derive the split with boundary-weighted costs, which could
    /// disagree with the loads the predicted step time was evaluated on.)
    pub fn replica_loads(&self, group: usize) -> Vec<Vec<BucketLoad>> {
        let offset: usize = self.groups[..group]
            .iter()
            .map(|&(_, p)| p.max(1) as usize)
            .sum();
        let p = self.groups[group].1.max(1) as usize;
        self.replica_assignments[offset..offset + p].to_vec()
    }

    /// Total sequences dispatched.
    pub fn total_sequences(&self) -> u64 {
        self.d.iter().flatten().sum()
    }
}

/// Builds and solves per-step dispatch problems for a fixed deployment.
#[derive(Debug, Clone)]
pub struct Dispatcher<'a> {
    cost: &'a CostModel,
    plan: &'a DeploymentPlan,
    table: Option<&'a CostTable>,
}

impl<'a> Dispatcher<'a> {
    pub fn new(cost: &'a CostModel, plan: &'a DeploymentPlan) -> Self {
        Self { cost, plan, table: None }
    }

    /// Like [`Self::new`] with a prebuilt [`CostTable`]: `problem` and
    /// `evaluate` read the memoized per-sequence costs and replica times
    /// instead of re-deriving them analytically. Lookups outside the
    /// table's (config × boundary) grid fall back to the model, so results
    /// are bit-identical either way.
    pub fn with_table(
        cost: &'a CostModel,
        plan: &'a DeploymentPlan,
        table: &'a CostTable,
    ) -> Self {
        Self { cost, plan, table: Some(table) }
    }

    #[inline]
    fn per_seq_cost(&self, cfg: ParallelConfig, s: u64) -> f64 {
        match self.table {
            Some(t) => t.per_seq_cost(cfg, s),
            None => self.cost.per_seq_cost(cfg, s),
        }
    }

    #[inline]
    fn replica_time(&self, cfg: ParallelConfig, loads: &[BucketLoad]) -> f64 {
        match self.table {
            Some(t) => t.replica_time(cfg, loads),
            None => self.cost.replica_time(cfg, loads),
        }
    }

    /// Construct the solver instance for the given buckets.
    pub fn problem(&self, buckets: &Buckets) -> DispatchProblem {
        let groups = self
            .plan
            .groups
            .iter()
            .map(|&(cfg, p)| {
                let costs = buckets
                    .boundaries
                    .iter()
                    .map(|&s| self.per_seq_cost(cfg, s as u64))
                    .collect();
                GroupSpec {
                    costs,
                    replicas: p,
                    // bubble + per-step overhead enter as a fixed cost in
                    // the linear model; the exact evaluation below refines.
                    fixed: 0.01 * (cfg.pp as f64 - 1.0),
                }
            })
            .collect();
        DispatchProblem { groups, demand: buckets.counts.clone() }
    }

    /// Solve with the chosen policy and evaluate exactly.
    pub fn dispatch(
        &self,
        buckets: &Buckets,
        policy: DispatchPolicy,
    ) -> Option<DispatchPlan> {
        let problem = self.problem(buckets);
        let assignment = match policy {
            DispatchPolicy::LengthBased => solver::solve_length_based(&problem)?,
            DispatchPolicy::Balanced => solver::solve_balanced(&problem)?,
        };
        Some(self.evaluate(buckets, assignment.d, assignment.makespan))
    }

    /// Mean exact step time over the expectation batch plus robustness
    /// batches — the planner's step-5 objective, folded into the search's
    /// per-candidate evaluation. `None` if any batch is unservable by this
    /// deployment (a plan that cannot serve a *sampled* batch must never
    /// win on the expectation batch alone).
    pub fn mean_step_time(
        &self,
        expectation: &Buckets,
        eval: &[Buckets],
        policy: DispatchPolicy,
    ) -> Option<f64> {
        let solved = self.dispatch(expectation, policy)?;
        let mut total = solved.predicted_step_time;
        let mut n_eval = 1.0;
        for b in eval {
            total += self.dispatch(b, policy)?.predicted_step_time;
            n_eval += 1.0;
        }
        Some(total / n_eval)
    }

    /// Evaluate an assignment with the exact replica-time model (Eq. 10/12).
    pub fn evaluate(
        &self,
        buckets: &Buckets,
        d: Vec<Vec<u64>>,
        solver_makespan: f64,
    ) -> DispatchPlan {
        let mut replica_times = Vec::new();
        let mut replica_assignments = Vec::new();
        let mut predicted: f64 = 0.0;
        for (i, &(cfg, p)) in self.plan.groups.iter().enumerate() {
            // split this group's sequences over its replicas with the
            // cost-model's per-sequence costs driving the LPT greedy
            let costs: Vec<f64> = buckets
                .boundaries
                .iter()
                .map(|&s| {
                    let c = self.per_seq_cost(cfg, s as u64);
                    if c.is_finite() {
                        c
                    } else {
                        s as f64 // unsupported buckets never have d > 0
                    }
                })
                .collect();
            let shares = solver::split_group_lpt(&costs, &d[i], p.max(1) as usize);
            for rep in shares {
                let loads: Vec<BucketLoad> = rep
                    .iter()
                    .enumerate()
                    .filter(|&(_, &s)| s > 0)
                    .map(|(j, &s)| BucketLoad {
                        count: s,
                        padded_len: buckets.boundaries[j] as u64,
                    })
                    .collect();
                let t = self.replica_time(cfg, &loads);
                predicted = predicted.max(t);
                replica_times.push((cfg, t));
                replica_assignments.push(loads);
            }
        }
        // synchronous LoRA sync at the end of the step
        let sync = self
            .cost
            .sync_time(self.plan.n_replicas(), self.plan.n_tasks.max(1));
        DispatchPlan {
            groups: self.plan.groups.clone(),
            boundaries: buckets.boundaries.clone(),
            d,
            replica_times,
            replica_assignments,
            predicted_step_time: predicted + sync,
            solver_makespan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;
    use crate::coordinator::planner::DeploymentPlan;

    fn setup() -> (CostModel, DeploymentPlan) {
        let cost = CostModel::calibrated(
            &ModelDesc::llama2_7b(),
            &ClusterSpec::a100_40g(16),
        );
        let plan = DeploymentPlan {
            groups: vec![
                (ParallelConfig::new(1, 1), 6),
                (ParallelConfig::new(2, 1), 1),
                (ParallelConfig::new(8, 1), 1),
            ],
            n_tasks: 6,
            expected_step_time: 0.0,
        };
        (cost, plan)
    }

    fn buckets() -> Buckets {
        Buckets {
            boundaries: vec![512, 2048, 8192],
            counts: vec![200, 40, 4],
            padding_tokens: 0,
        }
    }

    #[test]
    fn balanced_dispatch_conserves_demand() {
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let b = buckets();
        let dp = disp.dispatch(&b, DispatchPolicy::Balanced).unwrap();
        assert_eq!(dp.total_sequences(), 244);
        for (j, &bj) in b.counts.iter().enumerate() {
            let sum: u64 = dp.d.iter().map(|row| row[j]).sum();
            assert_eq!(sum, bj, "bucket {j}");
        }
    }

    #[test]
    fn long_bucket_only_on_big_replicas() {
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let dp = disp.dispatch(&buckets(), DispatchPolicy::Balanced).unwrap();
        // 8K sequences cannot run on <1,1> or <2,1> (OOM on 7B/A100-40)
        assert_eq!(dp.d[0][2], 0);
        assert_eq!(dp.d[1][2], 0);
        assert_eq!(dp.d[2][2], 4);
    }

    #[test]
    fn balanced_no_worse_than_length_based() {
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let b = buckets();
        let lb = disp.dispatch(&b, DispatchPolicy::LengthBased).unwrap();
        let bal = disp.dispatch(&b, DispatchPolicy::Balanced).unwrap();
        assert!(
            bal.predicted_step_time <= lb.predicted_step_time * 1.05,
            "balanced {} vs length-based {}",
            bal.predicted_step_time,
            lb.predicted_step_time
        );
    }

    #[test]
    fn replica_loads_partition_group_load() {
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let dp = disp.dispatch(&buckets(), DispatchPolicy::Balanced).unwrap();
        for (i, _) in dp.groups.iter().enumerate() {
            let loads = dp.replica_loads(i);
            let total: u64 = loads
                .iter()
                .flatten()
                .map(|l| l.count)
                .sum();
            let expected: u64 = dp.d[i].iter().sum();
            assert_eq!(total, expected, "group {i}");
        }
    }

    #[test]
    fn memoized_dispatch_matches_uncached() {
        let (cost, plan) = setup();
        let b = buckets();
        let cfgs: Vec<ParallelConfig> = plan.groups.iter().map(|&(c, _)| c).collect();
        let table = CostTable::build(&cost, &cfgs, &b.boundaries);
        for policy in [DispatchPolicy::Balanced, DispatchPolicy::LengthBased] {
            let plain = Dispatcher::new(&cost, &plan).dispatch(&b, policy).unwrap();
            let memo = Dispatcher::with_table(&cost, &plan, &table)
                .dispatch(&b, policy)
                .unwrap();
            assert_eq!(plain.d, memo.d, "{policy:?}");
            assert_eq!(
                plain.predicted_step_time.to_bits(),
                memo.predicted_step_time.to_bits(),
                "{policy:?}"
            );
            assert_eq!(
                plain.solver_makespan.to_bits(),
                memo.solver_makespan.to_bits(),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn recorded_assignments_are_the_timed_loads() {
        // the per-replica loads recorded in the plan must be exactly the
        // loads the per-replica times were evaluated on (executors replay
        // them, so any drift would break sim/dispatch bit-identity)
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let dp = disp.dispatch(&buckets(), DispatchPolicy::Balanced).unwrap();
        assert_eq!(dp.replica_assignments.len(), dp.replica_times.len());
        for (i, (rt, loads)) in
            dp.replica_times.iter().zip(&dp.replica_assignments).enumerate()
        {
            assert_eq!(
                cost.replica_time(rt.0, loads).to_bits(),
                rt.1.to_bits(),
                "replica {i}: recorded loads don't reproduce the timed value"
            );
        }
        // replica_loads(group) slices the same recording
        let mut flat = Vec::new();
        for g in 0..dp.groups.len() {
            flat.extend(dp.replica_loads(g));
        }
        assert_eq!(flat, dp.replica_assignments);
    }

    #[test]
    fn replica_times_length_matches_replica_count() {
        let (cost, plan) = setup();
        let disp = Dispatcher::new(&cost, &plan);
        let dp = disp.dispatch(&buckets(), DispatchPolicy::Balanced).unwrap();
        assert_eq!(dp.replica_times.len(), 8);
        assert!(dp.predicted_step_time > 0.0);
    }
}
