//! Deployment planning of heterogeneous FT replicas (paper §4.2, Eq. 2).
//!
//! Solved once at joint-FT initialization (and again on task arrival/exit):
//!
//! 1. Sample `100×B` lengths, dynamic-bucketize them, and take the bucket
//!    fractions `f_j` as the expected batch composition (largest-remainder
//!    rounded so the expectation batch sums exactly to `B`).
//! 2. Propose candidate configurations (Observation 1): for every
//!    `(num_gpus, seq_len)` pair keep only the highest-throughput
//!    configuration — dominated configs can never be selected.
//! 3. Memoize the analytic costs (`per_seq_cost`, `max_seq_len`,
//!    `max_chunk_tokens`, full-chunk times) once per candidate set ×
//!    bucket boundaries in a [`CostTable`].
//! 4. *Fused streaming search*: walk the integer partitions of the GPU
//!    budget over candidates (maximal packing) with a visitor that scores
//!    each plan's Theorem-1 lower bound on the fly and discards dominated
//!    plans immediately. The planning hot path ([`Planner::search_top_k`])
//!    additionally keeps only an online top-K of the best-bound survivors
//!    per worker (replacing the old collect-then-rank-truncate step), so
//!    peak plan storage is bounded by `K`, never by the survivor count.
//!    The search runs as a parallel fold over independent DFS subtrees and
//!    merges survivors in DFS order, so it is deterministic. A
//!    [`crate::coordinator::session::PlanningSession`] can *seed* the
//!    incumbent bound from the previous replan's survivors: the visitor
//!    then prunes most plans with cheap table lookups before ever touching
//!    the expensive exact replica-time terms, without changing the result.
//! 5. Solve the inner min–max dispatch (Eq. 3 structure) for the top-K
//!    surviving plans in parallel, evaluate with the exact (memoized) cost
//!    model, and keep the best.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::cluster::ClusterSpec;
use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::{bucketize, BucketingOptions, Buckets};
use crate::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use crate::costmodel::{BucketLoad, CostModel, CostTable};
use crate::data::MultiTaskSampler;
use crate::solver::partition::{self, Plan};
use crate::util::clock::Stopwatch;
use crate::util::par::{max_threads, par_fold, par_map, CancelToken};

/// A deployed set of heterogeneous FT replicas (the paper's Table 2 rows).
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// (configuration, replica count), ascending by GPUs per replica.
    pub groups: Vec<(ParallelConfig, u32)>,
    /// Number of FT tasks this plan was computed for (sync sizing).
    pub n_tasks: u32,
    /// Planner's predicted per-step time (expectation batch).
    pub expected_step_time: f64,
}

impl DeploymentPlan {
    pub fn n_replicas(&self) -> u32 {
        self.groups.iter().map(|&(_, p)| p).sum()
    }

    pub fn gpus_used(&self) -> u32 {
        self.groups.iter().map(|&(c, p)| c.n() * p).sum()
    }

    /// Paper Table 2 notation: `<1,1>x6, <2,1>x1, <8,1>x1`.
    pub fn notation(&self) -> String {
        self.groups
            .iter()
            .map(|&(c, p)| format!("{c}x{p}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// A homogeneous plan: `count` replicas of one config.
    pub fn homogeneous(cfg: ParallelConfig, count: u32, n_tasks: u32) -> Self {
        Self { groups: vec![(cfg, count)], n_tasks, expected_step_time: 0.0 }
    }
}

/// Planning statistics (Table 5's measured quantities).
#[derive(Debug, Clone, Default)]
pub struct PlanningStats {
    pub n_candidate_configs: usize,
    pub n_plans_enumerated: usize,
    pub n_plans_after_filter: usize,
    pub solve_seconds: f64,
    pub hit_plan_cap: bool,
    /// Upper bound on plans held concurrently during the fused search (sum
    /// of per-worker buffer peaks) — the quantity the old two-phase path
    /// blew up to `max_plans` on.
    pub peak_plan_storage: usize,
}

/// Planner options (pruning toggles are the Table 5 ablation axes).
#[derive(Debug, Clone)]
pub struct PlannerOptions {
    pub bucketing: BucketingOptions,
    /// Observation-1 configuration proposal.
    pub config_proposal: bool,
    /// Theorem-1 lower-bound filtering.
    pub lower_bound_filter: bool,
    /// Keep plans within (1+threshold) of the best lower bound.
    pub lower_bound_threshold: f64,
    /// Calibration sample = `calibration_multiple × B` lengths.
    pub calibration_multiple: usize,
    /// Enumeration safety valve.
    pub max_plans: usize,
    /// Sampled batches (beyond the expectation batch) each surviving plan
    /// is evaluated on — guards against plans that are optimal for the
    /// expected bucket counts but fragile under batch randomness.
    pub eval_batches: usize,
    /// After the lower-bound filter, evaluate at most this many plans
    /// (best bounds first). Keeps large-cluster planning in minutes, as the
    /// paper's pruned solver does (Table 5).
    pub max_evaluated: usize,
    pub seed: u64,
    /// Allow TP groups spanning servers (needed when one server cannot
    /// hold the model, e.g. 70B ⟨16,1⟩).
    pub allow_cross_server_tp: bool,
    /// Dispatch policy assumed when evaluating candidate plans. The LobRA
    /// default is Balanced; the Figure 8 "+heterogeneous replicas" ablation
    /// arm plans self-consistently for LengthBased dispatch.
    pub inner_policy: DispatchPolicy,
    /// Supersession token for the async planner service: when armed, the
    /// streaming searches stop enumerating at the next visited plan and
    /// return whatever they had. A cancelled search's results are
    /// *discarded* by the caller (where the flag lands mid-walk is
    /// timing-dependent), so the deterministic sync path leaves this
    /// `None` — every determinism certificate runs with no token armed.
    pub cancel: Option<CancelToken>,
    /// Cap the GPUs the plan search may pack (a planning *shard*'s
    /// capacity slice). `None` plans against the whole cluster — the
    /// global path, bit-identical to the pre-shard behaviour. `Some(g)`
    /// is clamped to `[1, cluster.n_gpus]` by [`Self::search_gpus`].
    pub gpu_budget: Option<u32>,
}

impl PlannerOptions {
    /// The GPU capacity the plan search packs: the shard's
    /// [`Self::gpu_budget`] clamped to the cluster, or the whole cluster.
    pub fn search_gpus(&self, cluster: &ClusterSpec) -> u32 {
        self.gpu_budget.map_or(cluster.n_gpus, |g| g.min(cluster.n_gpus)).max(1)
    }
}

impl Default for PlannerOptions {
    fn default() -> Self {
        Self {
            bucketing: BucketingOptions::default(),
            config_proposal: true,
            lower_bound_filter: true,
            lower_bound_threshold: 0.15,
            calibration_multiple: 100,
            max_plans: 2_000_000,
            eval_batches: 4,
            max_evaluated: 2_000,
            seed: 0x10b7a,
            allow_cross_server_tp: true,
            inner_policy: DispatchPolicy::Balanced,
            cancel: None,
            gpu_budget: None,
        }
    }
}

/// Reusable buffers for [`Planner::lower_bound_cached`] — the bound is
/// evaluated on millions of candidate plans, so per-call allocation would
/// dominate the search.
#[derive(Debug, Default)]
pub struct LowerBoundScratch {
    per_config: Vec<Vec<BucketLoad>>,
    loads: Vec<BucketLoad>,
}

impl LowerBoundScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, n_configs: usize) {
        if self.per_config.len() < n_configs {
            self.per_config.resize_with(n_configs, Vec::new);
        }
        for v in &mut self.per_config {
            v.clear();
        }
    }
}

/// Survivors + statistics of the fused streaming plan search.
#[derive(Debug, Clone, Default)]
pub struct PlanSearch {
    /// Surviving `(plan, lower bound)` pairs in enumeration (DFS) order.
    pub survivors: Vec<(Plan, f64)>,
    pub n_enumerated: usize,
    pub hit_cap: bool,
    /// Upper bound on plans held concurrently (sum of per-worker peaks).
    pub peak_storage: usize,
}

/// Result of the fused streaming search with online top-K selection
/// ([`Planner::search_top_k`]) — the planning hot path's step 4+5 front.
#[derive(Debug, Clone, Default)]
pub struct TopKSearch {
    /// The `K = max_evaluated` best-bound survivors: sorted by
    /// `(bound, DFS order)` when the survivor set exceeded `K`, in plain
    /// DFS order otherwise — exactly the candidate list (set *and* order)
    /// the old collect-then-rank-truncate path produced.
    pub candidates: Vec<(Plan, f64)>,
    /// Exact survivor count (plans within threshold of the best bound).
    pub n_survivors: usize,
    pub n_enumerated: usize,
    pub hit_cap: bool,
    /// Sum of per-worker peak plan storage (bounded by `workers × K`).
    pub peak_storage: usize,
    /// Last enumerated count vector when `hit_cap` — the checkpoint a
    /// [`crate::coordinator::session::PlanningSession`] resumes from.
    pub resume: Option<Vec<u32>>,
    /// Minimum lower bound observed (the final cutoff is `best×(1+τ)`).
    pub best_bound: f64,
    /// Whether a warm-start seed was actually applied (a capped fresh
    /// search silently drops its seed to reproduce the cold cap prefix).
    pub seeded: bool,
}

/// Search products a [`crate::coordinator::session::PlanningSession`]
/// memoizes for the next replan: the top-K survivor plans (the warm-start
/// seed pool) plus the cap/resume state of the search that produced them.
#[derive(Debug, Clone)]
pub struct SearchCarry {
    pub candidates: Vec<(Plan, f64)>,
    pub hit_cap: bool,
    pub resume: Option<Vec<u32>>,
    pub best_bound: f64,
    /// Whether the search that produced this carry ran with its seed.
    pub seeded: bool,
}

/// Heap entry of the per-worker online top-K: ordered by
/// `(bound bits, DFS sequence)` so the max-heap's root is the *worst*
/// candidate. Non-negative f64 bit patterns order like the floats, and the
/// sequence tie-break reproduces the stable rank-truncation of the old
/// collect-then-sort path (earlier DFS position wins on equal bounds).
struct Cand {
    bits: u64,
    seq: usize,
    plan: Plan,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.bits == other.bits && self.seq == other.seq
    }
}

impl Eq for Cand {}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.bits, self.seq).cmp(&(other.bits, other.seq))
    }
}

/// Largest-remainder (Hare quota) rounding: integers proportional to
/// `counts` summing exactly to `b_total`. Ties break toward lower indices
/// for determinism. A per-bucket `ceil` would make the expectation batch
/// exceed `B` and size plans for phantom sequences.
fn largest_remainder_counts(counts: &[u64], b_total: u64) -> Vec<u64> {
    let total: u64 = counts.iter().sum();
    if total == 0 || counts.is_empty() {
        return vec![0; counts.len()];
    }
    let quotas: Vec<f64> = counts
        .iter()
        .map(|&c| c as f64 / total as f64 * b_total as f64)
        .collect();
    let mut out: Vec<u64> = quotas.iter().map(|&q| q.floor() as u64).collect();
    let assigned: u64 = out.iter().sum();
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = quotas[a] - quotas[a].floor();
        let rb = quotas[b] - quotas[b].floor();
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });
    let mut left = b_total.saturating_sub(assigned);
    let mut k = 0usize;
    while left > 0 {
        out[order[k % order.len()]] += 1;
        left -= 1;
        k += 1;
    }
    out
}

/// Calibration sample → expectation-batch buckets, shared by
/// [`Planner::plan_with_stats`], [`Planner::plan_homogeneous`] and the
/// session-aware path in [`crate::coordinator::session`]: sample
/// `calibration_multiple × B` lengths, extend with each task's distribution
/// maximum (so the plan can process every sequence the tasks may ever
/// produce — a plan sized only for the sampled max would OOM on a later
/// batch's tail draw), bucketize, and convert the bucket fractions into
/// expected per-step counts summing exactly to `B`. The returned sampler
/// continues the same deterministic stream (for robustness batches).
pub(crate) fn expectation_buckets(
    tasks: &TaskSet,
    opts: &PlannerOptions,
) -> (MultiTaskSampler, Buckets) {
    let mut sampler = MultiTaskSampler::new(tasks, opts.seed);
    let mut lengths = sampler.calibration_lengths(opts.calibration_multiple);
    for t in &tasks.tasks {
        lengths.push(t.lengths.max_len);
    }
    let calib = bucketize(&lengths, &opts.bucketing);
    let expected = largest_remainder_counts(&calib.counts, tasks.joint_batch() as u64);
    let buckets = Buckets {
        boundaries: calib.boundaries,
        counts: expected,
        padding_tokens: 0,
    };
    (sampler, buckets)
}

/// Robustness batches for step-5 evaluation: `n` real sampled fused
/// batches, bucketed with the calibration boundaries. One code path for
/// the stateless planner and the planning session, so warm-started replans
/// evaluate on exactly the batches a cold plan would.
pub(crate) fn robustness_batches(
    sampler: &mut MultiTaskSampler,
    boundaries: &[u32],
    n: usize,
) -> Vec<Buckets> {
    (0..n)
        .map(|_| {
            let batch = sampler.next_batch();
            crate::coordinator::bucketing::buckets_from_boundaries(
                &batch.lengths(),
                boundaries,
            )
        })
        .collect()
}

/// The deployment planner.
pub struct Planner<'a> {
    cost: &'a CostModel,
    cluster: &'a ClusterSpec,
}

impl<'a> Planner<'a> {
    pub fn new(cost: &'a CostModel, cluster: &'a ClusterSpec) -> Self {
        Self { cost, cluster }
    }

    pub fn cost(&self) -> &CostModel {
        self.cost
    }

    pub fn cluster(&self) -> &ClusterSpec {
        self.cluster
    }

    /// All feasible configurations on this (model, cluster).
    pub fn feasible_configs(&self, allow_cross_server_tp: bool) -> Vec<ParallelConfig> {
        ParallelConfig::enumerate(
            self.cluster.n_gpus,
            self.cluster.device.gpus_per_server,
            allow_cross_server_tp,
        )
        .into_iter()
        .filter(|&c| self.cost.feasible(c))
        .collect()
    }

    /// Observation-1 configuration proposal: for each `(num_gpus, s)` pair
    /// keep the throughput-max config; dominated configs are dropped.
    pub fn propose_configs(
        &self,
        boundaries: &[u32],
        allow_cross_server_tp: bool,
    ) -> Vec<ParallelConfig> {
        let all = self.feasible_configs(allow_cross_server_tp);
        let mut keep = std::collections::BTreeSet::new();
        let sizes: std::collections::BTreeSet<u32> = all.iter().map(|c| c.n()).collect();
        for &n in &sizes {
            for &s in boundaries {
                let mut best: Option<(f64, ParallelConfig)> = None;
                for &c in all.iter().filter(|c| c.n() == n) {
                    if self.cost.max_seq_len(c) < s as u64 {
                        continue;
                    }
                    let cap = self.cost.max_chunk_tokens(c);
                    let b = (cap / s as u64).max(1);
                    let thr = self.cost.throughput(c, b, s as u64);
                    if best.map_or(true, |(t, _)| thr > t) {
                        best = Some((thr, c));
                    }
                }
                if let Some((_, c)) = best {
                    keep.insert(c);
                }
            }
        }
        keep.into_iter().collect()
    }

    /// Theorem 1 lower bound of a plan: length-based dispatch, then
    /// `lb = Σ_i N_i·t_i / N_used`.
    ///
    /// Convenience wrapper over [`Self::lower_bound_cached`] building a
    /// one-off [`CostTable`]; the planning hot path builds the table once
    /// and reuses a [`LowerBoundScratch`] across millions of calls.
    pub fn lower_bound(
        &self,
        configs: &[ParallelConfig],
        plan: &Plan,
        buckets: &Buckets,
    ) -> Option<f64> {
        let table = CostTable::build(self.cost, configs, &buckets.boundaries);
        let mut scratch = LowerBoundScratch::new();
        self.lower_bound_cached(&table, &plan.counts, buckets, &mut scratch)
    }

    /// Memoized Theorem-1 lower bound. `table` must be built for the same
    /// config order as `counts` indexes and for `buckets.boundaries`.
    pub fn lower_bound_cached(
        &self,
        table: &CostTable,
        counts: &[u32],
        buckets: &Buckets,
        scratch: &mut LowerBoundScratch,
    ) -> Option<f64> {
        self.lower_bound_within(table, counts, buckets, scratch, f64::INFINITY)
    }

    /// Like [`Self::lower_bound_cached`] with a pruning `cutoff`: returns
    /// `None` as soon as a *cheap* lower estimate of the bound provably
    /// exceeds `cutoff`, skipping the expensive exact replica-time terms.
    /// Whenever the true bound is `<= cutoff` the returned value is exact
    /// (bit-identical to the uncut call) — the streaming search relies on
    /// this to keep survivor bounds exact while pruning the rest with a
    /// few table lookups. `cutoff = INFINITY` disables pruning entirely.
    pub fn lower_bound_within(
        &self,
        table: &CostTable,
        counts: &[u32],
        buckets: &Buckets,
        scratch: &mut LowerBoundScratch,
        cutoff: f64,
    ) -> Option<f64> {
        debug_assert!(table.covers(&buckets.boundaries));
        debug_assert_eq!(table.n_configs(), counts.len());
        let n_configs = table.n_configs();
        let configs = table.configs();
        let prune = cutoff.is_finite();
        scratch.reset(n_configs);
        // length-based: each bucket to the most efficient (per-GPU) config
        // among the plan's deployed configs that supports it. `cheap`
        // accumulates Σ_j b_j·min_i(per_seq·n_i) — a lower estimate of the
        // Theorem-1 numerator (chunked replica times only add rounding,
        // bubble and overhead on top of the per-sequence linear cost).
        let mut cheap = 0.0f64;
        for (j, (&bj, &s)) in buckets.counts.iter().zip(&buckets.boundaries).enumerate()
        {
            if bj == 0 {
                continue;
            }
            let s = s as u64;
            let mut best: Option<(f64, usize)> = None;
            for i in 0..n_configs {
                if counts[i] == 0 || table.max_seq_len_at(i) < s {
                    continue;
                }
                let eff = table.per_seq_cost_at(i, j) * configs[i].n() as f64;
                if best.map_or(true, |(e, _)| eff < e) {
                    best = Some((eff, i));
                }
            }
            let (eff, i) = best?;
            cheap += bj as f64 * eff;
            scratch.per_config[i].push(BucketLoad { count: bj, padded_len: s });
        }
        let mut n_used = 0u32;
        for i in 0..n_configs {
            if counts[i] > 0 {
                n_used += counts[i] * configs[i].n();
            }
        }
        if n_used == 0 {
            return None;
        }
        if prune && cheap / n_used as f64 > cutoff {
            return None;
        }

        // Suffix-capacity bound (strengthening of Theorem 1): sequences in
        // bucket j can only migrate to replicas that support bucket j
        // (Property 2 — supports are nested), so for every j:
        //   t̂ ≥ (Σ_{j'≥j} minimal GPU-work of bucket j') / (GPUs supporting j)
        // This removes plans that look cheap on average but choke their few
        // long-sequence-capable replicas. Evaluated *before* the exact
        // Theorem-1 numerator because it needs only table lookups, so a
        // tight cutoff (e.g. a warm-started incumbent) prunes here.
        let mut suffix = 0.0f64;
        let mut best_suffix_bound = 0.0f64;
        for j in (0..buckets.boundaries.len()).rev() {
            let s = buckets.boundaries[j] as u64;
            let bj = buckets.counts[j];
            if bj > 0 {
                // minimal GPU-seconds per bucket-j sequence over the plan
                let mut w = f64::INFINITY;
                for i in 0..n_configs {
                    if counts[i] > 0 && table.max_seq_len_at(i) >= s {
                        w = w.min(table.per_seq_cost_at(i, j) * configs[i].n() as f64);
                    }
                }
                if !w.is_finite() {
                    return None; // no deployed config supports this bucket
                }
                suffix += bj as f64 * w;
            }
            let mut supporter_gpus = 0u32;
            for i in 0..n_configs {
                if counts[i] > 0 && table.max_seq_len_at(i) >= s {
                    supporter_gpus += counts[i] * configs[i].n();
                }
            }
            if supporter_gpus > 0 && suffix > 0.0 {
                best_suffix_bound = best_suffix_bound.max(suffix / supporter_gpus as f64);
            }
        }
        if prune && best_suffix_bound > cutoff {
            return None;
        }

        // Exact Theorem-1 numerator: chunked replica times of the
        // length-based assignment, split evenly over each config's replicas.
        let mut weighted = 0.0;
        for i in 0..n_configs {
            let p = counts[i];
            if p == 0 || scratch.per_config[i].is_empty() {
                continue;
            }
            scratch.loads.clear();
            scratch.loads.extend(scratch.per_config[i].iter().map(|l| BucketLoad {
                count: l.count.div_ceil(p as u64),
                padded_len: l.padded_len,
            }));
            let t = table.replica_time_at(i, &scratch.loads);
            weighted += (configs[i].n() * p) as f64 * t;
        }
        let thm1 = weighted / n_used as f64;
        Some(thm1.max(best_suffix_bound))
    }

    /// Fused streaming plan search (steps 3–4 of Eq. 2): enumerate
    /// maximal-packing plans and filter by the Theorem-1 lower bound *on
    /// the fly*. Dominated plans are discarded as soon as they are scored,
    /// so peak storage is bounded by the survivor set (plus a ≤2×
    /// compaction slack per worker) instead of the full enumeration.
    ///
    /// The search folds independent DFS subtrees in parallel and merges
    /// survivors in DFS order: the result is the exact surviving plan set
    /// (and order) of the two-phase enumerate-then-filter path, certified
    /// by `tests/planner_streaming.rs`. When the `max_plans` cap could
    /// trip, the search runs as a single sequential DFS instead, so the
    /// capped prefix is the deterministic first-`max_plans`-in-DFS-order
    /// set (the seed semantics) rather than a thread-timing-dependent one.
    pub fn filtered_plans(
        &self,
        configs: &[ParallelConfig],
        table: &CostTable,
        buckets: &Buckets,
        opts: &PlannerOptions,
    ) -> PlanSearch {
        let longest = buckets.boundaries.last().map_or(0, |&s| s as u64);
        let supports: Vec<bool> =
            (0..configs.len()).map(|i| table.max_seq_len_at(i) >= longest).collect();
        let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
        let n_gpus = opts.search_gpus(self.cluster);
        let min_gpus = n_gpus.saturating_sub(min_n - 1);
        let threshold = 1.0 + opts.lower_bound_threshold;

        let enumerated = AtomicUsize::new(0);
        let capped = AtomicBool::new(false);
        // Global best bound: non-negative f64 bit patterns order like the
        // floats, so an integer fetch_min maintains the running minimum
        // across workers and tightens every worker's pruning cutoff.
        let best_bits = AtomicU64::new(f64::INFINITY.to_bits());

        struct Acc {
            survivors: Vec<(Plan, f64)>,
            peak: usize,
            floor: usize,
        }

        // Parallel subtrees race on the shared plan counter, so a capped
        // run would keep a scheduling-dependent subset; the partition-count
        // DP is exact and cheap, so use it to detect that case up front
        // and fall back to one sequential DFS (deterministic cap prefix).
        let may_cap =
            partition::count_plans(configs, n_gpus, min_gpus) > opts.max_plans as u64;
        let prefixes = if may_cap {
            vec![Vec::new()]
        } else {
            partition::dfs_prefixes(configs, n_gpus, max_threads() * 8)
        };

        let run_prefix = |prefix: &Vec<u32>| -> Acc {
            let mut acc = Acc { survivors: Vec::new(), peak: 0, floor: 0 };
            let mut scratch = LowerBoundScratch::new();
            partition::visit_plans_from(
                configs,
                prefix,
                n_gpus,
                min_gpus,
                None,
                &mut |counts| {
                    // supersession: an armed token ends every worker's
                    // walk at its next visit (results will be discarded)
                    if matches!(&opts.cancel, Some(c) if c.is_cancelled()) {
                        return false;
                    }
                    if enumerated.fetch_add(1, Ordering::Relaxed) >= opts.max_plans {
                        capped.store(true, Ordering::Relaxed);
                        return false;
                    }
                    // plan must deploy something able to run the longest bucket
                    if !counts.iter().zip(&supports).any(|(&c, &sup)| sup && c > 0) {
                        return true;
                    }
                    if !opts.lower_bound_filter {
                        acc.survivors.push((Plan { counts: counts.to_vec() }, 0.0));
                        acc.peak = acc.peak.max(acc.survivors.len());
                        return true;
                    }
                    // prune with the running cutoff: plans it rejects are
                    // provably above the final cutoff, so the survivor set
                    // and its bounds stay exact
                    let cut =
                        f64::from_bits(best_bits.load(Ordering::Relaxed)) * threshold;
                    let Some(lb) =
                        self.lower_bound_within(table, counts, buckets, &mut scratch, cut)
                    else {
                        return true;
                    };
                    let prev =
                        f64::from_bits(best_bits.fetch_min(lb.to_bits(), Ordering::Relaxed));
                    // pruning with a stale (higher) best only keeps extras;
                    // the final cutoff below is exact
                    if lb <= prev.min(lb) * threshold {
                        acc.survivors.push((Plan { counts: counts.to_vec() }, lb));
                        acc.peak = acc.peak.max(acc.survivors.len());
                        // lazy compaction against the tightened global bound
                        // keeps the buffer within ~2× of the true survivors
                        if acc.survivors.len() >= 1024
                            && acc.survivors.len() >= 2 * acc.floor
                        {
                            let cutoff =
                                f64::from_bits(best_bits.load(Ordering::Relaxed))
                                    * threshold;
                            acc.survivors.retain(|&(_, l)| l <= cutoff);
                            acc.floor = acc.survivors.len();
                        }
                    }
                    true
                },
            );
            acc
        };

        let merged = par_fold(prefixes, run_prefix, |mut a, mut b| {
            a.survivors.append(&mut b.survivors);
            a.peak += b.peak;
            a
        });
        let mut out = PlanSearch::default();
        let Some(merged) = merged else {
            return out;
        };
        let mut survivors = merged.survivors;
        if opts.lower_bound_filter {
            let cutoff = f64::from_bits(best_bits.load(Ordering::Relaxed)) * threshold;
            survivors.retain(|&(_, lb)| lb <= cutoff);
        }
        out.hit_cap = capped.load(Ordering::Relaxed);
        out.n_enumerated = enumerated.load(Ordering::Relaxed).min(opts.max_plans);
        out.peak_storage = merged.peak;
        out.survivors = survivors;
        out
    }

    /// Fused streaming search with *online top-K* selection: like
    /// [`Self::filtered_plans`] but each worker keeps only its `K =
    /// max_evaluated` best-bound survivors in a bounded heap (plus an 8-byte
    /// bound per survivor for exact statistics), folding the old
    /// collect-all-then-rank-truncate step into the search itself. The
    /// returned candidate list is identical — set *and* order — to
    /// truncating the full survivor list, because a per-worker top-K always
    /// contains that worker's share of the global top-K and buffered extras
    /// (plans above the final cutoff) can never evict a true survivor.
    ///
    /// `seed_bound` warm-starts the incumbent used for pruning (a valid
    /// Theorem-1 bound of *some plan in this enumeration*, e.g. the previous
    /// replan's survivors re-scored on the current buckets). Seeding only
    /// tightens the running cutoff — the final cutoff is still the exact
    /// minimum over all enumerated bounds — so the result is bit-identical
    /// to an unseeded (cold) search; it just gets there faster. When the
    /// `max_plans` cap may trip, the search runs sequentially, drops the
    /// seed (the seed plan might lie beyond the cap, which would tighten
    /// the capped prefix's cutoff beyond what a cold run sees) and records
    /// the last enumerated vector as a resume checkpoint.
    pub fn search_top_k(
        &self,
        configs: &[ParallelConfig],
        table: &CostTable,
        buckets: &Buckets,
        opts: &PlannerOptions,
        seed_bound: Option<f64>,
    ) -> TopKSearch {
        self.search_top_k_impl(configs, table, buckets, opts, seed_bound, None, opts.max_plans)
    }

    /// Resume a capped [`Self::search_top_k`] strictly after `after` (its
    /// recorded checkpoint) with a fresh enumeration budget of
    /// `extra_plans`. The seed *is* honored here: a resumed search's seed
    /// comes from the already-enumerated prefix, so the combined
    /// prefix+extension result equals a single larger-cap search.
    #[allow(clippy::too_many_arguments)]
    pub fn search_top_k_resume(
        &self,
        configs: &[ParallelConfig],
        table: &CostTable,
        buckets: &Buckets,
        opts: &PlannerOptions,
        seed_bound: Option<f64>,
        after: &[u32],
        extra_plans: usize,
    ) -> TopKSearch {
        self.search_top_k_impl(
            configs,
            table,
            buckets,
            opts,
            seed_bound,
            Some(after),
            extra_plans,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn search_top_k_impl(
        &self,
        configs: &[ParallelConfig],
        table: &CostTable,
        buckets: &Buckets,
        opts: &PlannerOptions,
        seed_bound: Option<f64>,
        resume_after: Option<&[u32]>,
        max_plans: usize,
    ) -> TopKSearch {
        let k = opts.max_evaluated.max(1);
        let longest = buckets.boundaries.last().map_or(0, |&s| s as u64);
        let supports: Vec<bool> =
            (0..configs.len()).map(|i| table.max_seq_len_at(i) >= longest).collect();
        let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
        let n_gpus = opts.search_gpus(self.cluster);
        let min_gpus = n_gpus.saturating_sub(min_n - 1);
        let threshold = 1.0 + opts.lower_bound_threshold;

        let sequential = resume_after.is_some()
            || partition::count_plans(configs, n_gpus, min_gpus) > max_plans as u64;
        let seed = if sequential && resume_after.is_none() { None } else { seed_bound };
        let seed = seed.filter(|s| s.is_finite() && *s > 0.0);
        let seeded = seed.is_some();

        let enumerated = AtomicUsize::new(0);
        let capped = AtomicBool::new(false);
        let best_bits =
            AtomicU64::new(seed.unwrap_or(f64::INFINITY).to_bits());

        enum Walk {
            Prefix(Vec<u32>),
            After(Vec<u32>),
        }

        let walks: Vec<Walk> = if let Some(after) = resume_after {
            vec![Walk::After(after.to_vec())]
        } else if sequential {
            vec![Walk::Prefix(Vec::new())]
        } else {
            partition::dfs_prefixes(configs, n_gpus, max_threads() * 8)
                .into_iter()
                .map(Walk::Prefix)
                .collect()
        };
        let track_last = sequential;

        struct Acc {
            /// Drained per-worker heap, ascending local DFS sequence.
            items: Vec<(Plan, f64, usize)>,
            /// Every pushed bound (compacted against the running cutoff) —
            /// recovers the exact survivor count after the search.
            bounds: Vec<f64>,
            peak: usize,
            last: Vec<u32>,
        }

        let run = |walk: &Walk| -> Acc {
            let mut acc =
                Acc { items: Vec::new(), bounds: Vec::new(), peak: 0, last: Vec::new() };
            let mut heap: BinaryHeap<Cand> = BinaryHeap::new();
            let mut scratch = LowerBoundScratch::new();
            let mut seq = 0usize;
            let mut floor = 0usize;
            let mut visitor = |counts: &[u32]| -> bool {
                // supersession: stop before the next visit; the caller
                // (planner service) discards a cancelled search's output
                if matches!(&opts.cancel, Some(c) if c.is_cancelled()) {
                    return false;
                }
                if enumerated.fetch_add(1, Ordering::Relaxed) >= max_plans {
                    capped.store(true, Ordering::Relaxed);
                    return false;
                }
                if track_last {
                    acc.last.clear();
                    acc.last.extend_from_slice(counts);
                }
                // plan must deploy something able to run the longest bucket
                if !counts.iter().zip(&supports).any(|(&c, &sup)| sup && c > 0) {
                    return true;
                }
                let cut = f64::from_bits(best_bits.load(Ordering::Relaxed)) * threshold;
                let Some(lb) =
                    self.lower_bound_within(table, counts, buckets, &mut scratch, cut)
                else {
                    return true;
                };
                let prev =
                    f64::from_bits(best_bits.fetch_min(lb.to_bits(), Ordering::Relaxed));
                if lb <= prev.min(lb) * threshold {
                    acc.bounds.push(lb);
                    if acc.bounds.len() >= 4096 && acc.bounds.len() >= 2 * floor {
                        let c =
                            f64::from_bits(best_bits.load(Ordering::Relaxed)) * threshold;
                        acc.bounds.retain(|&b| b <= c);
                        floor = acc.bounds.len();
                    }
                    let cand =
                        Cand { bits: lb.to_bits(), seq, plan: Plan { counts: counts.to_vec() } };
                    seq += 1;
                    if heap.len() < k {
                        heap.push(cand);
                    } else {
                        // evict the worst (max (bound, seq)) only if the new
                        // candidate beats it — extras above the final cutoff
                        // can never displace a true survivor this way
                        let beats = heap
                            .peek()
                            .map_or(false, |w| (cand.bits, cand.seq) < (w.bits, w.seq));
                        if beats {
                            heap.pop();
                            heap.push(cand);
                        }
                    }
                    acc.peak = acc.peak.max(heap.len());
                }
                true
            };
            match walk {
                Walk::Prefix(p) => {
                    partition::visit_plans_from(
                        configs, p, n_gpus, min_gpus, None, &mut visitor,
                    );
                }
                Walk::After(a) => {
                    partition::visit_plans_after(
                        configs, a, n_gpus, min_gpus, None, &mut visitor,
                    );
                }
            }
            drop(visitor);
            let mut items: Vec<(Plan, f64, usize)> = heap
                .into_iter()
                .map(|c| (c.plan, f64::from_bits(c.bits), c.seq))
                .collect();
            items.sort_unstable_by_key(|&(_, _, s)| s);
            acc.items = items;
            acc
        };

        let merged = par_fold(walks, run, |mut a, mut b| {
            // prefix order = DFS order: concatenation keeps it global
            a.items.append(&mut b.items);
            a.bounds.append(&mut b.bounds);
            a.peak += b.peak;
            a
        });
        let Some(merged) = merged else {
            return TopKSearch::default();
        };

        let best = f64::from_bits(best_bits.load(Ordering::Relaxed));
        let cutoff = best * threshold;
        let n_survivors = merged.bounds.iter().filter(|&&b| b <= cutoff).count();
        let mut candidates: Vec<(Plan, f64)> = merged
            .items
            .into_iter()
            .filter(|&(_, lb, _)| lb <= cutoff)
            .map(|(p, lb, _)| (p, lb))
            .collect();
        if n_survivors > k {
            candidates.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            candidates.truncate(k);
        }
        let was_capped = capped.load(Ordering::Relaxed);
        TopKSearch {
            candidates,
            n_survivors,
            n_enumerated: enumerated.load(Ordering::Relaxed).min(max_plans),
            hit_cap: was_capped,
            peak_storage: merged.peak,
            resume: (was_capped && !merged.last.is_empty()).then(|| merged.last.clone()),
            best_bound: best,
            seeded,
        }
    }

    /// Solve Eq. 2: the full two-stage-decomposed deployment planning.
    pub fn plan(&self, tasks: &TaskSet, opts: PlannerOptions) -> Option<DeploymentPlan> {
        self.plan_with_stats(tasks, opts).map(|(p, _)| p)
    }

    /// Like [`Self::plan`] but returns planning statistics (Table 5).
    pub fn plan_with_stats(
        &self,
        tasks: &TaskSet,
        opts: PlannerOptions,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        let start = Stopwatch::start();
        let mut stats = PlanningStats::default();
        if tasks.is_empty() {
            return None;
        }

        // 1. calibration sample → expected buckets (sums exactly to B).
        let (mut sampler, buckets) = expectation_buckets(tasks, &opts);
        // Robustness batches: real sampled fused batches, bucketed with the
        // calibration boundaries.
        let eval =
            robustness_batches(&mut sampler, &buckets.boundaries, opts.eval_batches);

        self.plan_for_buckets_robust(&buckets, &eval, tasks.len() as u32, &opts, &mut stats, start)
            .map(|p| (p, stats))
    }

    /// Plan for explicit expected buckets (used by benches & Eq. 1 solver).
    pub fn plan_for_buckets(
        &self,
        buckets: &Buckets,
        n_tasks: u32,
        opts: &PlannerOptions,
        stats: &mut PlanningStats,
        start: Stopwatch,
    ) -> Option<DeploymentPlan> {
        self.plan_for_buckets_robust(buckets, &[], n_tasks, opts, stats, start)
    }

    /// Like [`Self::plan_for_buckets`] with extra robustness batches: each
    /// surviving plan's objective is its mean exact step time over the
    /// expectation batch plus `eval` sampled batches. This is the stateless
    /// (cold) entry point: it builds a fresh [`CostTable`] and runs the
    /// pipeline unseeded. [`crate::coordinator::session::PlanningSession`]
    /// instead drives [`Self::search_top_k`] / [`Self::search_top_k_resume`]
    /// through its resumable anytime API (begin/pump/finish) with a cached
    /// table and a warm-start seed — run to completion, that path is
    /// plan-identical to this one.
    pub fn plan_for_buckets_robust(
        &self,
        buckets: &Buckets,
        eval: &[Buckets],
        n_tasks: u32,
        opts: &PlannerOptions,
        stats: &mut PlanningStats,
        start: Stopwatch,
    ) -> Option<DeploymentPlan> {
        // 2. candidate configurations
        let configs = if opts.config_proposal {
            self.propose_configs(&buckets.boundaries, opts.allow_cross_server_tp)
        } else {
            self.feasible_configs(opts.allow_cross_server_tp)
        };
        if configs.is_empty() {
            stats.n_candidate_configs = 0;
            return None;
        }
        // At least one candidate must support the longest bucket — checked
        // *before* paying for the table build (an infeasible world, e.g. a
        // sequential-baseline task too long for this cluster, exits here).
        let longest = *buckets.boundaries.last()? as u64;
        if !configs.iter().any(|&c| self.cost.max_seq_len(c) >= longest) {
            stats.n_candidate_configs = configs.len();
            return None;
        }
        // 3. memoize the analytic costs once per candidate set × boundaries
        // — every lower bound and dispatch evaluation below reads the table
        let table = CostTable::build(self.cost, &configs, &buckets.boundaries);
        self.plan_pipeline(buckets, eval, n_tasks, opts, stats, start, &table, &configs, None)
            .map(|(plan, _)| plan)
    }

    /// Steps 4–5 of Eq. 2 against prepared inputs: the fused streaming
    /// search (top-K when the lower-bound filter is on, full survivor
    /// collection for the "no filter" ablation) followed by
    /// [`Self::evaluate_candidates`]. `table` must be built for exactly
    /// `(configs, buckets.boundaries)`. `seed_bound` warm-starts the
    /// search's incumbent (see [`Self::search_top_k`]); pass `None` for a
    /// cold search. Returns the best plan plus the [`SearchCarry`] a
    /// planning session memoizes for the next replan.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_pipeline(
        &self,
        buckets: &Buckets,
        eval: &[Buckets],
        n_tasks: u32,
        opts: &PlannerOptions,
        stats: &mut PlanningStats,
        start: Stopwatch,
        table: &CostTable,
        configs: &[ParallelConfig],
        seed_bound: Option<f64>,
    ) -> Option<(DeploymentPlan, SearchCarry)> {
        stats.n_candidate_configs = configs.len();
        if configs.is_empty() {
            return None;
        }
        let longest = *buckets.boundaries.last()? as u64;
        // at least one candidate must support the longest bucket
        configs.iter().find(|c| self.cost.max_seq_len(**c) >= longest)?;

        // 4(+5 front). fused streaming enumeration + Theorem-1 filter with
        // online top-K selection of the evaluation set. The "no filter"
        // ablation (Table 5) collects everything and pays full price.
        let (candidates, carry) = if opts.lower_bound_filter {
            let search = self.search_top_k(configs, table, buckets, opts, seed_bound);
            stats.n_plans_enumerated = search.n_enumerated;
            stats.hit_plan_cap = search.hit_cap;
            stats.peak_plan_storage = search.peak_storage;
            stats.n_plans_after_filter = search.n_survivors;
            let carry = SearchCarry {
                candidates: search.candidates.clone(),
                hit_cap: search.hit_cap,
                resume: search.resume.clone(),
                best_bound: search.best_bound,
                seeded: search.seeded,
            };
            (search.candidates, carry)
        } else {
            let search = self.filtered_plans(configs, table, buckets, opts);
            stats.n_plans_enumerated = search.n_enumerated;
            stats.hit_plan_cap = search.hit_cap;
            stats.peak_plan_storage = search.peak_storage;
            stats.n_plans_after_filter = search.survivors.len();
            let carry = SearchCarry {
                candidates: Vec::new(),
                hit_cap: search.hit_cap,
                resume: None,
                best_bound: f64::INFINITY,
                seeded: false,
            };
            (search.survivors, carry)
        };

        // 5. inner dispatch solve per candidate (parallel, memoized)
        let plan =
            self.evaluate_candidates(candidates, buckets, eval, n_tasks, opts, table, configs)?;
        stats.solve_seconds = start.elapsed_secs();
        Some((plan, carry))
    }

    /// Step 5 of Eq. 2: exact dispatch evaluation of the candidate plans
    /// (augmented with the homogeneous plans) and argmin selection.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn evaluate_candidates(
        &self,
        mut candidates: Vec<(Plan, f64)>,
        buckets: &Buckets,
        eval: &[Buckets],
        n_tasks: u32,
        opts: &PlannerOptions,
        table: &CostTable,
        configs: &[ParallelConfig],
    ) -> Option<DeploymentPlan> {
        let longest = *buckets.boundaries.last()? as u64;
        // The homogeneous plans are always evaluated: pruning may never
        // leave the planner worse than the Task-Fused baseline (the bound
        // is a *relative* metric — paper Appendix A — and can misrank
        // plans whose dispatch flexibility differs a lot).
        for (i, c) in configs.iter().enumerate() {
            if self.cost.max_seq_len(*c) < longest {
                continue;
            }
            let count = opts.search_gpus(self.cluster) / c.n();
            if count == 0 {
                continue;
            }
            let mut counts = vec![0u32; configs.len()];
            counts[i] = count;
            let plan = Plan { counts };
            if !candidates.iter().any(|(p, _)| p == &plan) {
                candidates.push((plan, 0.0));
            }
        }

        let evaluated: Vec<(DeploymentPlan, f64)> = par_map(candidates, |(plan, _)| {
            let groups: Vec<(ParallelConfig, u32)> = configs
                .iter()
                .zip(&plan.counts)
                .filter(|&(_, &p)| p > 0)
                .map(|(&c, &p)| (c, p))
                .collect();
            let dp = DeploymentPlan { groups, n_tasks, expected_step_time: 0.0 };
            let dispatcher = Dispatcher::with_table(self.cost, &dp, table);
            let t = dispatcher.mean_step_time(buckets, eval, opts.inner_policy)?;
            Some((dp, t))
        })
        .into_iter()
        .flatten()
        .collect();

        let (mut best_plan, best_t) = evaluated.into_iter().min_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap()
        })?;
        best_plan.expected_step_time = best_t;
        best_plan.groups.sort_by_key(|&(c, _)| (c.n(), c.tp));
        Some(best_plan)
    }

    /// The Task-Fused baseline: best *homogeneous* deployment (tuned over
    /// candidate configs, like the paper tunes its baselines).
    pub fn plan_homogeneous(
        &self,
        tasks: &TaskSet,
        opts: &PlannerOptions,
    ) -> Option<DeploymentPlan> {
        let (_, buckets) = expectation_buckets(tasks, opts);
        let longest = *buckets.boundaries.last()? as u64;

        let candidates = self.feasible_configs(opts.allow_cross_server_tp);
        let mut best: Option<(DeploymentPlan, f64)> = None;
        for c in candidates {
            if self.cost.max_seq_len(c) < longest {
                continue; // homogeneous plan must fit the longest sequences
            }
            let count = opts.search_gpus(self.cluster) / c.n();
            if count == 0 {
                continue;
            }
            let dp = DeploymentPlan::homogeneous(c, count, tasks.len() as u32);
            let dispatcher = Dispatcher::new(self.cost, &dp);
            let Some(solved) = dispatcher.dispatch(&buckets, DispatchPolicy::Balanced)
            else {
                continue;
            };
            let t = solved.predicted_step_time;
            if best.as_ref().map_or(true, |&(_, bt)| t < bt) {
                let mut dp = dp;
                dp.expected_step_time = t;
                best = Some((dp, t));
            }
        }
        best.map(|(p, _)| p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;

    fn setup_7b16() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    #[test]
    fn config_proposal_shrinks_candidates() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let all = planner.feasible_configs(true);
        let proposed = planner.propose_configs(&[512, 2048, 8192], true);
        assert!(!proposed.is_empty());
        assert!(proposed.len() < all.len(), "{proposed:?} vs {all:?}");
        // the proposal must retain the ability to process the longest bucket
        assert!(proposed.iter().any(|&c| cost.max_seq_len(c) >= 8192));
    }

    #[test]
    fn plan_is_heterogeneous_under_skew() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        assert!(plan.gpus_used() <= 16);
        assert!(plan.gpus_used() >= 15, "maximal packing: {}", plan.gpus_used());
        // heterogeneity: more than one configuration deployed
        assert!(plan.groups.len() >= 2, "plan {}", plan.notation());
        // must include something able to run the long tail
        let longest_cap = plan
            .groups
            .iter()
            .map(|&(c, _)| cost.max_seq_len(c))
            .max()
            .unwrap();
        assert!(longest_cap >= 8192, "cap {longest_cap}");
    }

    #[test]
    fn heterogeneous_beats_homogeneous() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let hetero = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let homo = planner.plan_homogeneous(&tasks, &PlannerOptions::default()).unwrap();
        assert!(
            hetero.expected_step_time < homo.expected_step_time,
            "hetero {} vs homo {}",
            hetero.expected_step_time,
            homo.expected_step_time
        );
        assert_eq!(homo.groups.len(), 1);
    }

    #[test]
    fn pruning_preserves_solution_quality() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut opts_full = PlannerOptions::default();
        opts_full.config_proposal = false;
        opts_full.lower_bound_filter = false;
        let full = planner.plan(&tasks, opts_full).unwrap();
        let pruned = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        // paper: identical plans on 16-32 GPUs; we allow tiny tolerance
        assert!(
            pruned.expected_step_time <= full.expected_step_time * 1.02,
            "pruned {} vs full {}",
            pruned.expected_step_time,
            full.expected_step_time
        );
    }

    #[test]
    fn stats_reflect_pruning() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let (_, s_pruned) = planner
            .plan_with_stats(&tasks, PlannerOptions::default())
            .unwrap();
        let mut o = PlannerOptions::default();
        o.lower_bound_filter = false;
        let (_, s_nofilter) = planner.plan_with_stats(&tasks, o).unwrap();
        assert!(s_pruned.n_plans_after_filter <= s_nofilter.n_plans_after_filter);
        assert!(s_pruned.n_candidate_configs > 0);
        // fused search: the filtered run never holds the whole enumeration
        assert!(
            s_pruned.peak_plan_storage <= s_nofilter.n_plans_after_filter.max(1024),
            "peak {} vs enumerated {}",
            s_pruned.peak_plan_storage,
            s_nofilter.n_plans_after_filter
        );
    }

    #[test]
    fn expectation_counts_sum_to_joint_batch() {
        let tasks = TaskSet::paper_7b_subset();
        let (_, buckets) = expectation_buckets(&tasks, &PlannerOptions::default());
        assert_eq!(
            buckets.counts.iter().sum::<u64>(),
            tasks.joint_batch() as u64,
            "expectation batch must not contain phantom sequences"
        );
    }

    #[test]
    fn largest_remainder_rounding_exact() {
        assert_eq!(largest_remainder_counts(&[1, 1, 1], 2), vec![1, 1, 0]);
        assert_eq!(largest_remainder_counts(&[3, 1], 8), vec![6, 2]);
        assert_eq!(largest_remainder_counts(&[0, 0], 5), vec![0, 0]);
        let out = largest_remainder_counts(&[997, 2, 1], 100);
        assert_eq!(out, vec![100, 0, 0]);
        for (counts, b) in [
            (vec![5u64, 7, 11, 13], 64u64),
            (vec![1, 0, 0, 999], 17),
            (vec![2, 2, 2], 7),
        ] {
            let out = largest_remainder_counts(&counts, b);
            assert_eq!(out.iter().sum::<u64>(), b, "{counts:?}");
        }
    }

    #[test]
    fn notation_format() {
        let p = DeploymentPlan {
            groups: vec![
                (ParallelConfig::new(1, 1), 6),
                (ParallelConfig::new(8, 1), 1),
            ],
            n_tasks: 6,
            expected_step_time: 1.0,
        };
        assert_eq!(p.notation(), "<1,1>x6, <8,1>x1");
        assert_eq!(p.gpus_used(), 14);
        assert_eq!(p.n_replicas(), 7);
    }
}
