//! Deployment planning of heterogeneous FT replicas (paper §4.2, Eq. 2).
//!
//! Solved once at joint-FT initialization (and again on task arrival/exit):
//!
//! 1. Sample `100×B` lengths, dynamic-bucketize them, and take the bucket
//!    fractions `f_j` as the expected batch composition.
//! 2. Propose candidate configurations (Observation 1): for every
//!    `(num_gpus, seq_len)` pair keep only the highest-throughput
//!    configuration — dominated configs can never be selected.
//! 3. Enumerate deployment plans = integer partitions of the GPU budget
//!    over candidates (maximal packing: leaving a whole replica's worth of
//!    GPUs idle is dominated).
//! 4. Filter by the Theorem 1 lower bound: `lb = Σ_i N_i·t_i / N` under
//!    length-based dispatch; drop plans whose bound exceeds the best by
//!    more than the threshold (default 15%).
//! 5. Solve the inner min–max dispatch (Eq. 3 structure) for every
//!    surviving plan in parallel, evaluate with the exact cost model, and
//!    keep the best.

use crate::cluster::ClusterSpec;
use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::{bucketize, BucketingOptions, Buckets};
use crate::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use crate::costmodel::{BucketLoad, CostModel};
use crate::data::MultiTaskSampler;
use crate::solver::partition::{enumerate_plans, Plan};
use crate::util::par::par_map;

/// A deployed set of heterogeneous FT replicas (the paper's Table 2 rows).
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// (configuration, replica count), ascending by GPUs per replica.
    pub groups: Vec<(ParallelConfig, u32)>,
    /// Number of FT tasks this plan was computed for (sync sizing).
    pub n_tasks: u32,
    /// Planner's predicted per-step time (expectation batch).
    pub expected_step_time: f64,
}

impl DeploymentPlan {
    pub fn n_replicas(&self) -> u32 {
        self.groups.iter().map(|&(_, p)| p).sum()
    }

    pub fn gpus_used(&self) -> u32 {
        self.groups.iter().map(|&(c, p)| c.n() * p).sum()
    }

    /// Paper Table 2 notation: `<1,1>x6, <2,1>x1, <8,1>x1`.
    pub fn notation(&self) -> String {
        self.groups
            .iter()
            .map(|&(c, p)| format!("{c}x{p}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// A homogeneous plan: `count` replicas of one config.
    pub fn homogeneous(cfg: ParallelConfig, count: u32, n_tasks: u32) -> Self {
        Self { groups: vec![(cfg, count)], n_tasks, expected_step_time: 0.0 }
    }
}

/// Planning statistics (Table 5's measured quantities).
#[derive(Debug, Clone, Default)]
pub struct PlanningStats {
    pub n_candidate_configs: usize,
    pub n_plans_enumerated: usize,
    pub n_plans_after_filter: usize,
    pub solve_seconds: f64,
    pub hit_plan_cap: bool,
}

/// Planner options (pruning toggles are the Table 5 ablation axes).
#[derive(Debug, Clone)]
pub struct PlannerOptions {
    pub bucketing: BucketingOptions,
    /// Observation-1 configuration proposal.
    pub config_proposal: bool,
    /// Theorem-1 lower-bound filtering.
    pub lower_bound_filter: bool,
    /// Keep plans within (1+threshold) of the best lower bound.
    pub lower_bound_threshold: f64,
    /// Calibration sample = `calibration_multiple × B` lengths.
    pub calibration_multiple: usize,
    /// Enumeration safety valve.
    pub max_plans: usize,
    /// Sampled batches (beyond the expectation batch) each surviving plan
    /// is evaluated on — guards against plans that are optimal for the
    /// expected bucket counts but fragile under batch randomness.
    pub eval_batches: usize,
    /// After the lower-bound filter, evaluate at most this many plans
    /// (best bounds first). Keeps large-cluster planning in minutes, as the
    /// paper's pruned solver does (Table 5).
    pub max_evaluated: usize,
    pub seed: u64,
    /// Allow TP groups spanning servers (needed when one server cannot
    /// hold the model, e.g. 70B ⟨16,1⟩).
    pub allow_cross_server_tp: bool,
    /// Dispatch policy assumed when evaluating candidate plans. The LobRA
    /// default is Balanced; the Figure 8 "+heterogeneous replicas" ablation
    /// arm plans self-consistently for LengthBased dispatch.
    pub inner_policy: DispatchPolicy,
}

impl Default for PlannerOptions {
    fn default() -> Self {
        Self {
            bucketing: BucketingOptions::default(),
            config_proposal: true,
            lower_bound_filter: true,
            lower_bound_threshold: 0.15,
            calibration_multiple: 100,
            max_plans: 2_000_000,
            eval_batches: 4,
            max_evaluated: 2_000,
            seed: 0x10b7a,
            allow_cross_server_tp: true,
            inner_policy: DispatchPolicy::Balanced,
        }
    }
}

/// The deployment planner.
pub struct Planner<'a> {
    cost: &'a CostModel,
    cluster: &'a ClusterSpec,
}

impl<'a> Planner<'a> {
    pub fn new(cost: &'a CostModel, cluster: &'a ClusterSpec) -> Self {
        Self { cost, cluster }
    }

    /// All feasible configurations on this (model, cluster).
    pub fn feasible_configs(&self, allow_cross_server_tp: bool) -> Vec<ParallelConfig> {
        ParallelConfig::enumerate(
            self.cluster.n_gpus,
            self.cluster.gpus_per_server,
            allow_cross_server_tp,
        )
        .into_iter()
        .filter(|&c| self.cost.feasible(c))
        .collect()
    }

    /// Observation-1 configuration proposal: for each `(num_gpus, s)` pair
    /// keep the throughput-max config; dominated configs are dropped.
    pub fn propose_configs(
        &self,
        boundaries: &[u32],
        allow_cross_server_tp: bool,
    ) -> Vec<ParallelConfig> {
        let all = self.feasible_configs(allow_cross_server_tp);
        let mut keep = std::collections::BTreeSet::new();
        let sizes: std::collections::BTreeSet<u32> = all.iter().map(|c| c.n()).collect();
        for &n in &sizes {
            for &s in boundaries {
                let mut best: Option<(f64, ParallelConfig)> = None;
                for &c in all.iter().filter(|c| c.n() == n) {
                    if self.cost.max_seq_len(c) < s as u64 {
                        continue;
                    }
                    let cap = self.cost.max_chunk_tokens(c);
                    let b = (cap / s as u64).max(1);
                    let thr = self.cost.throughput(c, b, s as u64);
                    if best.map_or(true, |(t, _)| thr > t) {
                        best = Some((thr, c));
                    }
                }
                if let Some((_, c)) = best {
                    keep.insert(c);
                }
            }
        }
        keep.into_iter().collect()
    }

    /// Theorem 1 lower bound of a plan: length-based dispatch, then
    /// `lb = Σ_i N_i·t_i / N_used`.
    pub fn lower_bound(
        &self,
        configs: &[ParallelConfig],
        plan: &Plan,
        buckets: &Buckets,
    ) -> Option<f64> {
        // length-based: each bucket to the most efficient (per-GPU) config
        // among the plan's deployed configs that supports it.
        let mut per_config_loads: Vec<Vec<BucketLoad>> =
            vec![Vec::new(); configs.len()];
        for (j, (&bj, &s)) in buckets.counts.iter().zip(&buckets.boundaries).enumerate() {
            let _ = j;
            if bj == 0 {
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for (i, &c) in configs.iter().enumerate() {
                if plan.counts[i] == 0 || self.cost.max_seq_len(c) < s as u64 {
                    continue;
                }
                let eff = self.cost.per_seq_cost(c, s as u64) * c.n() as f64;
                if best.map_or(true, |(e, _)| eff < e) {
                    best = Some((eff, i));
                }
            }
            let (_, i) = best?;
            per_config_loads[i].push(BucketLoad { count: bj, padded_len: s as u64 });
        }
        let mut weighted = 0.0;
        let mut n_used = 0u32;
        for (i, &c) in configs.iter().enumerate() {
            let p = plan.counts[i];
            if p == 0 {
                continue;
            }
            n_used += p * c.n();
            if per_config_loads[i].is_empty() {
                continue;
            }
            // split the config's load evenly over its p replicas
            let loads: Vec<BucketLoad> = per_config_loads[i]
                .iter()
                .map(|l| BucketLoad {
                    count: l.count.div_ceil(p as u64),
                    padded_len: l.padded_len,
                })
                .collect();
            let t = self.cost.replica_time(c, &loads);
            weighted += (c.n() * p) as f64 * t;
        }
        if n_used == 0 {
            return None;
        }
        let thm1 = weighted / n_used as f64;

        // Suffix-capacity bound (strengthening of Theorem 1): sequences in
        // bucket j can only migrate to replicas that support bucket j
        // (Property 2 — supports are nested), so for every j:
        //   t̂ ≥ (Σ_{j'≥j} minimal GPU-work of bucket j') / (GPUs supporting j)
        // This removes plans that look cheap on average but choke their few
        // long-sequence-capable replicas.
        let mut suffix = 0.0f64;
        let mut best_suffix_bound = 0.0f64;
        for j in (0..buckets.boundaries.len()).rev() {
            let s = buckets.boundaries[j] as u64;
            let bj = buckets.counts[j];
            if bj > 0 {
                // minimal GPU-seconds per bucket-j sequence over the plan
                let w = configs
                    .iter()
                    .enumerate()
                    .filter(|&(i, c)| {
                        plan.counts[i] > 0 && self.cost.max_seq_len(*c) >= s
                    })
                    .map(|(_, c)| self.cost.per_seq_cost(*c, s) * c.n() as f64)
                    .fold(f64::INFINITY, f64::min);
                if !w.is_finite() {
                    return None; // no deployed config supports this bucket
                }
                suffix += bj as f64 * w;
            }
            let supporter_gpus: u32 = configs
                .iter()
                .enumerate()
                .filter(|&(i, c)| {
                    plan.counts[i] > 0 && self.cost.max_seq_len(*c) >= s
                })
                .map(|(i, c)| plan.counts[i] * c.n())
                .sum();
            if supporter_gpus > 0 && suffix > 0.0 {
                best_suffix_bound =
                    best_suffix_bound.max(suffix / supporter_gpus as f64);
            }
        }
        Some(thm1.max(best_suffix_bound))
    }

    /// Solve Eq. 2: the full two-stage-decomposed deployment planning.
    pub fn plan(&self, tasks: &TaskSet, opts: PlannerOptions) -> Option<DeploymentPlan> {
        self.plan_with_stats(tasks, opts).map(|(p, _)| p)
    }

    /// Like [`Self::plan`] but returns planning statistics (Table 5).
    pub fn plan_with_stats(
        &self,
        tasks: &TaskSet,
        opts: PlannerOptions,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        let start = std::time::Instant::now();
        let mut stats = PlanningStats::default();
        if tasks.is_empty() {
            return None;
        }

        // 1. calibration sample → expected buckets. The sample is extended
        // with each task's distribution maximum so the plan can process
        // every sequence the tasks may ever produce (a plan sized only for
        // the sampled max would OOM on a later batch's tail draw).
        let mut sampler = MultiTaskSampler::new(tasks, opts.seed);
        let mut lengths = sampler.calibration_lengths(opts.calibration_multiple);
        for t in &tasks.tasks {
            lengths.push(t.lengths.max_len);
        }
        let calib = bucketize(&lengths, &opts.bucketing);
        // expected per-step demand: B × f_j
        let b_total = tasks.joint_batch() as f64;
        let sample_total: u64 = calib.counts.iter().sum();
        let expected_counts: Vec<u64> = calib
            .counts
            .iter()
            .map(|&c| ((c as f64 / sample_total.max(1) as f64) * b_total).ceil() as u64)
            .collect();
        let buckets = Buckets {
            boundaries: calib.boundaries.clone(),
            counts: expected_counts,
            padding_tokens: 0,
        };
        // Robustness batches: real sampled fused batches, bucketed with the
        // calibration boundaries.
        let eval: Vec<Buckets> = (0..opts.eval_batches)
            .map(|_| {
                let batch = sampler.next_batch();
                crate::coordinator::bucketing::buckets_from_boundaries(
                    &batch.lengths(),
                    &calib.boundaries,
                )
            })
            .collect();

        self.plan_for_buckets_robust(&buckets, &eval, tasks.len() as u32, &opts, &mut stats, start)
            .map(|p| (p, stats))
    }

    /// Plan for explicit expected buckets (used by benches & Eq. 1 solver).
    pub fn plan_for_buckets(
        &self,
        buckets: &Buckets,
        n_tasks: u32,
        opts: &PlannerOptions,
        stats: &mut PlanningStats,
        start: std::time::Instant,
    ) -> Option<DeploymentPlan> {
        self.plan_for_buckets_robust(buckets, &[], n_tasks, opts, stats, start)
    }

    /// Like [`Self::plan_for_buckets`] with extra robustness batches: each
    /// surviving plan's objective is its mean exact step time over the
    /// expectation batch plus `eval` sampled batches.
    pub fn plan_for_buckets_robust(
        &self,
        buckets: &Buckets,
        eval: &[Buckets],
        n_tasks: u32,
        opts: &PlannerOptions,
        stats: &mut PlanningStats,
        start: std::time::Instant,
    ) -> Option<DeploymentPlan> {
        // 2. candidate configurations
        let configs = if opts.config_proposal {
            self.propose_configs(&buckets.boundaries, opts.allow_cross_server_tp)
        } else {
            self.feasible_configs(opts.allow_cross_server_tp)
        };
        stats.n_candidate_configs = configs.len();
        if configs.is_empty() {
            return None;
        }
        let longest = *buckets.boundaries.last()? as u64;
        // at least one candidate must support the longest bucket
        configs.iter().find(|c| self.cost.max_seq_len(**c) >= longest)?;

        // 3. enumerate maximal-packing plans
        let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
        let min_gpus = self.cluster.n_gpus.saturating_sub(min_n - 1);
        let plans = enumerate_plans(
            &configs,
            self.cluster.n_gpus,
            min_gpus,
            None,
            opts.max_plans,
        );
        stats.n_plans_enumerated = plans.len();
        stats.hit_plan_cap = plans.len() >= opts.max_plans;

        // keep only plans able to process the longest bucket
        let plans: Vec<Plan> = plans
            .into_iter()
            .filter(|p| {
                configs.iter().enumerate().any(|(i, c)| {
                    p.counts[i] > 0 && self.cost.max_seq_len(*c) >= longest
                })
            })
            .collect();

        // 4. Theorem-1 lower-bound filter
        let mut survivors: Vec<(Plan, f64)> = if opts.lower_bound_filter {
            let bounds: Vec<(Plan, f64)> = par_map(plans, |p| {
                self.lower_bound(&configs, p, buckets).map(|lb| (p.clone(), lb))
            })
            .into_iter()
            .flatten()
            .collect();
            let best_lb = bounds
                .iter()
                .map(|&(_, lb)| lb)
                .fold(f64::INFINITY, f64::min);
            bounds
                .into_iter()
                .filter(|&(_, lb)| lb <= best_lb * (1.0 + opts.lower_bound_threshold))
                .collect()
        } else {
            plans.into_iter().map(|p| (p, 0.0)).collect()
        };
        stats.n_plans_after_filter = survivors.len();
        // Rank-truncation only applies when bounds exist; the "no filter"
        // ablation (Table 5) evaluates everything and pays full price.
        if opts.lower_bound_filter && survivors.len() > opts.max_evaluated {
            survivors.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            survivors.truncate(opts.max_evaluated);
        }
        // The homogeneous plans are always evaluated: pruning may never
        // leave the planner worse than the Task-Fused baseline (the bound
        // is a *relative* metric — paper Appendix A — and can misrank
        // plans whose dispatch flexibility differs a lot).
        for (i, c) in configs.iter().enumerate() {
            if self.cost.max_seq_len(*c) < longest {
                continue;
            }
            let count = self.cluster.n_gpus / c.n();
            if count == 0 {
                continue;
            }
            let mut counts = vec![0u32; configs.len()];
            counts[i] = count;
            let plan = Plan { counts };
            if !survivors.iter().any(|(p, _)| p == &plan) {
                survivors.push((plan, 0.0));
            }
        }

        // 5. inner dispatch solve per surviving plan (parallel)
        let evaluated: Vec<(DeploymentPlan, f64)> = par_map(survivors, |(plan, _)| {
            let groups: Vec<(ParallelConfig, u32)> = configs
                .iter()
                .zip(&plan.counts)
                .filter(|&(_, &p)| p > 0)
                .map(|(&c, &p)| (c, p))
                .collect();
            let dp = DeploymentPlan { groups, n_tasks, expected_step_time: 0.0 };
            let dispatcher = Dispatcher::new(self.cost, &dp);
            let solved = dispatcher.dispatch(buckets, opts.inner_policy)?;
            let mut total = solved.predicted_step_time;
            let mut n_eval = 1.0;
            for b in eval {
                let Some(s) = dispatcher.dispatch(b, opts.inner_policy) else {
                    return None; // plan can't even serve a sampled batch
                };
                total += s.predicted_step_time;
                n_eval += 1.0;
            }
            Some((dp, total / n_eval))
        })
        .into_iter()
        .flatten()
        .collect();

        let (mut best_plan, best_t) = evaluated.into_iter().min_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap()
        })?;
        best_plan.expected_step_time = best_t;
        best_plan.groups.sort_by_key(|&(c, _)| (c.n(), c.tp));
        stats.solve_seconds = start.elapsed().as_secs_f64();
        Some(best_plan)
    }

    /// The Task-Fused baseline: best *homogeneous* deployment (tuned over
    /// candidate configs, like the paper tunes its baselines).
    pub fn plan_homogeneous(
        &self,
        tasks: &TaskSet,
        opts: &PlannerOptions,
    ) -> Option<DeploymentPlan> {
        let mut sampler = MultiTaskSampler::new(tasks, opts.seed);
        let mut lengths = sampler.calibration_lengths(opts.calibration_multiple);
        for t in &tasks.tasks {
            lengths.push(t.lengths.max_len);
        }
        let calib = bucketize(&lengths, &opts.bucketing);
        let longest = *calib.boundaries.last()? as u64;
        let b_total = tasks.joint_batch() as f64;
        let sample_total: u64 = calib.counts.iter().sum();
        let expected: Vec<u64> = calib
            .counts
            .iter()
            .map(|&c| ((c as f64 / sample_total.max(1) as f64) * b_total).ceil() as u64)
            .collect();
        let buckets = Buckets {
            boundaries: calib.boundaries.clone(),
            counts: expected,
            padding_tokens: 0,
        };

        let candidates = self.feasible_configs(opts.allow_cross_server_tp);
        let mut best: Option<(DeploymentPlan, f64)> = None;
        for c in candidates {
            if self.cost.max_seq_len(c) < longest {
                continue; // homogeneous plan must fit the longest sequences
            }
            let count = self.cluster.n_gpus / c.n();
            if count == 0 {
                continue;
            }
            let dp = DeploymentPlan::homogeneous(c, count, tasks.len() as u32);
            let dispatcher = Dispatcher::new(self.cost, &dp);
            let Some(solved) = dispatcher.dispatch(&buckets, DispatchPolicy::Balanced)
            else {
                continue;
            };
            let t = solved.predicted_step_time;
            if best.as_ref().map_or(true, |&(_, bt)| t < bt) {
                let mut dp = dp;
                dp.expected_step_time = t;
                best = Some((dp, t));
            }
        }
        best.map(|(p, _)| p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;

    fn setup_7b16() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    #[test]
    fn config_proposal_shrinks_candidates() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let all = planner.feasible_configs(true);
        let proposed = planner.propose_configs(&[512, 2048, 8192], true);
        assert!(!proposed.is_empty());
        assert!(proposed.len() < all.len(), "{proposed:?} vs {all:?}");
        // the proposal must retain the ability to process the longest bucket
        assert!(proposed.iter().any(|&c| cost.max_seq_len(c) >= 8192));
    }

    #[test]
    fn plan_is_heterogeneous_under_skew() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        assert!(plan.gpus_used() <= 16);
        assert!(plan.gpus_used() >= 15, "maximal packing: {}", plan.gpus_used());
        // heterogeneity: more than one configuration deployed
        assert!(plan.groups.len() >= 2, "plan {}", plan.notation());
        // must include something able to run the long tail
        let longest_cap = plan
            .groups
            .iter()
            .map(|&(c, _)| cost.max_seq_len(c))
            .max()
            .unwrap();
        assert!(longest_cap >= 8192, "cap {longest_cap}");
    }

    #[test]
    fn heterogeneous_beats_homogeneous() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let hetero = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let homo = planner.plan_homogeneous(&tasks, &PlannerOptions::default()).unwrap();
        assert!(
            hetero.expected_step_time < homo.expected_step_time,
            "hetero {} vs homo {}",
            hetero.expected_step_time,
            homo.expected_step_time
        );
        assert_eq!(homo.groups.len(), 1);
    }

    #[test]
    fn pruning_preserves_solution_quality() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut opts_full = PlannerOptions::default();
        opts_full.config_proposal = false;
        opts_full.lower_bound_filter = false;
        let full = planner.plan(&tasks, opts_full).unwrap();
        let pruned = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        // paper: identical plans on 16-32 GPUs; we allow tiny tolerance
        assert!(
            pruned.expected_step_time <= full.expected_step_time * 1.02,
            "pruned {} vs full {}",
            pruned.expected_step_time,
            full.expected_step_time
        );
    }

    #[test]
    fn stats_reflect_pruning() {
        let (cost, cluster) = setup_7b16();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let (_, s_pruned) = planner
            .plan_with_stats(&tasks, PlannerOptions::default())
            .unwrap();
        let mut o = PlannerOptions::default();
        o.lower_bound_filter = false;
        let (_, s_nofilter) = planner.plan_with_stats(&tasks, o).unwrap();
        assert!(s_pruned.n_plans_after_filter <= s_nofilter.n_plans_after_filter);
        assert!(s_pruned.n_candidate_configs > 0);
    }

    #[test]
    fn notation_format() {
        let p = DeploymentPlan {
            groups: vec![
                (ParallelConfig::new(1, 1), 6),
                (ParallelConfig::new(8, 1), 1),
            ],
            n_tasks: 6,
            expected_step_time: 1.0,
        };
        assert_eq!(p.notation(), "<1,1>x6, <8,1>x1");
        assert_eq!(p.gpus_used(), 14);
        assert_eq!(p.n_replicas(), 7);
    }
}
