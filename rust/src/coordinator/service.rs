//! The async planner service: off-thread anytime search with lock-free
//! plan publication.
//!
//! The sync serving path interleaves search and training on one thread —
//! each replan slice runs *between* training steps, so with no overlapping
//! deployment (cold start) the search time is exposed on the serving
//! clock. This module promotes planning to a dedicated service thread that
//! owns its own [`PlanningSession`] and pumps
//! [`PlanningSession::pump_anytime_cancellable`] continuously, so search
//! overlaps training even when nothing is deployed:
//!
//! ```text
//!  event thread (ServeRuntime)              planner service thread
//!  ───────────────────────────              ──────────────────────
//!  Event ─────► apply_event                  recv ──► drain to newest
//!      │            (window opens)             │
//!      ├─ cancel in-flight token ──────────►  CancelToken observed
//!      └─ submit(epoch+1, tasks) ──────────►  inside PlanCursor slice:
//!                                             discard slice, new search
//!  train_step ... train_step                 pump ─ pump ─ pump ─ done
//!      │                                       │
//!      ▼         ┌───────────────┐             ▼
//!  poll() ◄──────┤  EpochCell    │◄── publish(epoch, final plan)
//!      │         │ (lock-free)   │
//!      ▼         └───────────────┘
//!  epoch match? ──► finish_replan_with(plan) at the step boundary
//! ```
//!
//! **Supersession** is epoch-counted: every [`PlannerService::submit`]
//! cancels the previous request's [`CancelToken`] and bumps the epoch. The
//! token is checked inside `PlanCursor` enumeration slices (every plan),
//! so a superseding event interrupts the search mid-slice instead of
//! waiting for cooperative slice exhaustion; the interrupted slice's
//! partial results are discarded wholesale (see
//! [`PlanningSession::pump_anytime_cancellable`]). The [`EpochCell`]
//! rejects publishes at stale epochs, so a search superseded between
//! computing and publishing its plan can never overwrite its successor's.
//!
//! **Determinism.** The service publishes only *terminal* results — the
//! search ran to enumeration completion (`done`) or its budget expired
//! (`exhausted`, plan = best-so-far) — exactly the two adoption points of
//! the sync path. A completed (`done`) search is built from the same
//! certified-cold-identical machinery as the sync path (same
//! `begin/pump/finish` calls on a `PlanningSession`), so its plan is
//! bit-identical to a cold `Planner::plan` for the same task set — that is
//! what `tests/async_planner.rs` certifies across thread counts, the same
//! way warm == cold is certified today. Budget *accounting* is the one
//! best-effort divergence: a superseding request carries the open window's
//! remaining budget like the sync path, but if an event lands in the gap
//! after the service finished and before the runtime adopted, the
//! successor restarts with a full budget (the sync path, which adopts at
//! the same tick it detects completion, has no such gap). Under the
//! unlimited-budget certification setup this is moot; under the wall
//! meter, budgets are timing-dependent by definition.
//!
//! Raw thread spawning here is sanctioned by detlint rule R6 (confined to
//! `util::par` and this module).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::cluster::ClusterSpec;
use crate::config::TaskSet;
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::runtime::BudgetMeter;
use crate::coordinator::session::PlanningSession;
use crate::costmodel::{CostModel, CostTables};
use crate::util::par::{with_max_threads, CancelToken, EpochCell};

/// A terminal search result published by the service. Every update is
/// final for its epoch: the service publishes nothing mid-search (the
/// expensive candidate evaluation runs once, at adoption time, exactly
/// like the sync path — this is what keeps async == sync plan identity).
#[derive(Debug, Clone)]
pub struct PlanUpdate {
    /// The request epoch this result answers (compare against the epoch
    /// returned by [`PlannerService::submit`] before adopting).
    pub epoch: u64,
    /// The planning shard this result belongs to (0 for the unsharded
    /// path — see [`PlannerService::submit_shard`]).
    pub shard: usize,
    /// The plan to adopt; `None` means the world is infeasible for the
    /// requested task set (the deployment drains).
    pub plan: Option<DeploymentPlan>,
    /// The enumeration ran to completion: `plan` is certified
    /// cold-identical.
    pub done: bool,
    /// The budget expired mid-search: `plan` is the feasible best-so-far.
    pub exhausted: bool,
    /// Plans enumerated across the whole search.
    pub n_enumerated: usize,
    /// Slices the search took.
    pub slices: u32,
    /// Service-side wall-clock spent searching (for the runtime's
    /// overlapped-vs-unoverlapped split; budget charging uses the
    /// [`BudgetMeter`], which may be the sim clock instead).
    pub search_seconds: f64,
}

/// One search request: plan for `tasks`, reporting at `epoch`.
struct PlanRequest {
    epoch: u64,
    /// Planning shard the request targets — each shard has its own
    /// publication cell, cancel token, session and window budget, so an
    /// event on one shard never cancels another's in-flight search.
    shard: usize,
    tasks: TaskSet,
    /// Replan budget for a fresh window; `None` = unlimited.
    budget: Option<f64>,
    /// This request opens a new replan window (don't carry the previous
    /// window's remaining budget).
    fresh: bool,
    /// GPU capacity slice the shard's search packs (`None`: whole
    /// cluster — the unsharded path).
    gpu_budget: Option<u32>,
    cancel: CancelToken,
}

enum Cmd {
    Plan(Box<PlanRequest>),
    Shutdown,
}

/// Handle to the planner service thread. Owned by the serving runtime;
/// dropping it shuts the thread down (cancelling any in-flight search).
///
/// Sharded operation ([`Self::spawn_sharded`]) gives every planning shard
/// its own publication cell and cancel token under one global epoch
/// counter: submitting for shard A cancels only A's in-flight search —
/// shard B's may be *delayed* (the worker is one thread) but is never
/// discarded.
pub struct PlannerService {
    tx: mpsc::Sender<Cmd>,
    cells: Vec<Arc<EpochCell<PlanUpdate>>>,
    handle: Option<JoinHandle<()>>,
    epoch: u64,
    cancels: Vec<Option<CancelToken>>,
}

impl PlannerService {
    /// Spawn the service thread for the unsharded (single planning shard)
    /// path. It owns a clone of the world (cost model + cluster) and its
    /// own [`PlanningSession`]; session warm-starts are certified
    /// plan-identical to cold searches, so the separate memo chain changes
    /// no published plan. `threads` bounds the slice parallelism *of the
    /// service thread only* (via [`with_max_threads`]); the event loop's
    /// own parallelism is untouched.
    pub fn spawn(
        cost: CostModel,
        cluster: ClusterSpec,
        opts: PlannerOptions,
        meter: BudgetMeter,
        slice_plans: usize,
        threads: usize,
    ) -> Self {
        Self::spawn_sharded(cost, cluster, opts, meter, slice_plans, threads, 1)
    }

    /// Spawn the service thread with `n_shards` independent planning
    /// shards over one homogeneous world. Each shard gets its own
    /// [`PlanningSession`] (lazily, over one shared cost-table LRU),
    /// publication cell, cancel token and replan-window budget.
    pub fn spawn_sharded(
        cost: CostModel,
        cluster: ClusterSpec,
        opts: PlannerOptions,
        meter: BudgetMeter,
        slice_plans: usize,
        threads: usize,
        n_shards: usize,
    ) -> Self {
        let n_shards = n_shards.max(1);
        let worlds = vec![(cost, cluster); n_shards];
        Self::spawn_fleet(worlds, opts, meter, slice_plans, threads)
    }

    /// Spawn the service thread with one planning shard per `(cost model,
    /// cluster pool)` world — the async path of a mixed-generation fleet.
    /// Shard `i` searches exclusively against world `i`, so every pool's
    /// plans come from its own device-typed cost tables.
    pub fn spawn_fleet(
        worlds: Vec<(CostModel, ClusterSpec)>,
        opts: PlannerOptions,
        meter: BudgetMeter,
        slice_plans: usize,
        threads: usize,
    ) -> Self {
        assert!(!worlds.is_empty(), "PlannerService needs at least one world");
        let n_shards = worlds.len();
        let (tx, rx) = mpsc::channel();
        let cells: Vec<Arc<EpochCell<PlanUpdate>>> =
            (0..n_shards).map(|_| Arc::new(EpochCell::new())).collect();
        let worker_cells = cells.clone();
        let handle = std::thread::spawn(move || {
            let worker = Worker {
                worlds,
                opts,
                tables: CostTables::default(),
                sessions: BTreeMap::new(),
                meter,
                slice_plans,
                cells: worker_cells,
                window_left: BTreeMap::new(),
            };
            with_max_threads(threads, || worker.run(&rx));
        });
        Self {
            tx,
            cells,
            handle: Some(handle),
            epoch: 0,
            cancels: vec![None; n_shards],
        }
    }

    /// Shards this service was spawned with.
    pub fn n_shards(&self) -> usize {
        self.cells.len()
    }

    /// Request a plan for `tasks`, superseding any in-flight search (its
    /// token is cancelled before the new request is sent, so the service
    /// observes the cancellation no later than the request). Returns the
    /// request epoch: adopt a polled [`PlanUpdate`] only when its epoch
    /// matches. `fresh` marks the start of a new replan window (full
    /// `budget`); a non-fresh request carries the open window's remaining
    /// budget. Shard-0 shorthand for [`Self::submit_shard`].
    pub fn submit(&mut self, tasks: TaskSet, budget: Option<f64>, fresh: bool) -> u64 {
        self.submit_shard(0, tasks, budget, fresh, None)
    }

    /// Request a plan for one planning shard, superseding only *that
    /// shard's* in-flight search. `gpu_budget` caps the capacity the
    /// shard's search packs (its slice of the cluster).
    pub fn submit_shard(
        &mut self,
        shard: usize,
        tasks: TaskSet,
        budget: Option<f64>,
        fresh: bool,
        gpu_budget: Option<u32>,
    ) -> u64 {
        let shard = shard.min(self.cells.len() - 1);
        if let Some(c) = self.cancels[shard].take() {
            c.cancel();
        }
        let cancel = CancelToken::new();
        self.cancels[shard] = Some(cancel.clone());
        self.epoch += 1;
        let _ = self.tx.send(Cmd::Plan(Box::new(PlanRequest {
            epoch: self.epoch,
            shard,
            tasks,
            budget,
            fresh,
            gpu_budget,
            cancel,
        })));
        self.epoch
    }

    /// Cancel every in-flight search without submitting a new one — a
    /// fleet drain has no successor task set to search for.
    pub fn cancel_current(&mut self) {
        for c in &mut self.cancels {
            if let Some(c) = c.take() {
                c.cancel();
            }
        }
    }

    /// Wait-free snapshot of the newest published result (the cell epoch
    /// and the update it tags). `None` until the first publish. Shard-0
    /// shorthand for [`Self::poll_shard`].
    pub fn poll(&self) -> Option<(u64, Arc<PlanUpdate>)> {
        self.poll_shard(0)
    }

    /// Wait-free snapshot of one shard's newest published result.
    pub fn poll_shard(&self, shard: usize) -> Option<(u64, Arc<PlanUpdate>)> {
        self.cells.get(shard).and_then(|c| c.read())
    }

    /// The epoch of the most recent submission on any shard (0 before
    /// any).
    pub fn submitted_epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for PlannerService {
    fn drop(&mut self) {
        self.cancel_current();
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Service-thread state: the cloned per-shard worlds plus per-shard
/// planning sessions (lazily created over one shared cost-table LRU) and
/// per-shard replan-window budget bookkeeping.
struct Worker {
    /// Shard → its `(cost model, cluster pool)` world. A homogeneous
    /// sharded service replicates one world; a fleet service has one
    /// entry per device pool.
    worlds: Vec<(CostModel, ClusterSpec)>,
    opts: PlannerOptions,
    /// One cost-table LRU across every shard's session.
    tables: CostTables,
    sessions: BTreeMap<usize, PlanningSession>,
    meter: BudgetMeter,
    slice_plans: usize,
    cells: Vec<Arc<EpochCell<PlanUpdate>>>,
    /// Shard → remaining budget of its open replan window (`None` value =
    /// unlimited). Absent key = no window open on that shard; a
    /// superseding (non-fresh) request carries the stored remainder
    /// instead of a full budget.
    window_left: BTreeMap<usize, Option<f64>>,
}

impl Worker {
    fn run(mut self, rx: &mpsc::Receiver<Cmd>) {
        loop {
            let first = match rx.recv() {
                Ok(c) => c,
                // sender dropped without Shutdown (runtime panicked)
                Err(_) => return,
            };
            // Drain to the newest request *per shard*: an intermediate
            // request for a shard was superseded (its token is already
            // cancelled) before we ever started it, but requests for
            // *other* shards are independent work and must all run.
            let mut pending: BTreeMap<usize, PlanRequest> = BTreeMap::new();
            let mut shutdown = false;
            match first {
                Cmd::Shutdown => return,
                Cmd::Plan(r) => {
                    pending.insert(r.shard, *r);
                }
            }
            while let Ok(newer) = rx.try_recv() {
                match newer {
                    Cmd::Shutdown => {
                        shutdown = true;
                        break;
                    }
                    Cmd::Plan(r) => {
                        pending.insert(r.shard, *r);
                    }
                }
            }
            for (_, req) in pending {
                self.plan(req);
            }
            if shutdown {
                return;
            }
        }
    }

    /// Run one shard's search to a terminal state (done / exhausted /
    /// cancelled), publishing the terminal result unless cancelled.
    fn plan(&mut self, req: PlanRequest) {
        let PlanRequest { epoch, shard, tasks, budget, fresh, gpu_budget, cancel } = req;
        // Budget carry across supersession, mirroring the sync runtime's
        // replan window: a fresh window starts with the full budget, a
        // superseding request inherits what the superseded search left.
        let mut left = match (fresh, self.window_left.get(&shard)) {
            (false, Some(prev)) => *prev,
            _ => budget,
        };
        self.window_left.insert(shard, left);

        let session = self.sessions.entry(shard).or_insert_with(|| {
            PlanningSession::with_tables(self.opts.clone(), self.tables.clone())
        });
        session.set_gpu_budget(gpu_budget);
        let cell = &self.cells[shard.min(self.cells.len() - 1)];
        let (cost, cluster) = &self.worlds[shard.min(self.worlds.len() - 1)];
        let planner = Planner::new(cost, cluster);
        let Some(mut search) = session.begin_anytime(&planner, &tasks) else {
            // Infeasible world (e.g. no candidate config supports the
            // longest bucket): terminal "no plan" verdict, window closed.
            self.window_left.remove(&shard);
            cell.publish(
                epoch,
                Arc::new(PlanUpdate {
                    epoch,
                    shard,
                    plan: None,
                    done: true,
                    exhausted: false,
                    n_enumerated: 0,
                    slices: 0,
                    search_seconds: 0.0,
                }),
            );
            return;
        };
        let mut search_seconds = 0.0;
        loop {
            let report = session.pump_anytime_cancellable(
                &planner,
                &mut search,
                self.slice_plans,
                Some(&cancel),
            );
            search_seconds += report.wall_seconds;
            if report.cancelled {
                // Superseded: leave the window open carrying the remaining
                // budget, and drop the search unfinished — the sync path's
                // supersession likewise drops the pending search without
                // adopting it. Nothing is published (and the EpochCell
                // would reject this epoch anyway once the successor
                // publishes).
                self.window_left.insert(shard, left);
                return;
            }
            let charge = self.meter.charge(report.wall_seconds, report.n_enumerated);
            let mut exhausted = false;
            if let Some(b) = left.as_mut() {
                *b -= charge;
                exhausted = *b <= 0.0;
            }
            if report.done || exhausted {
                // capture counters before finish_anytime consumes the
                // search
                let n_enumerated = search.n_enumerated();
                let slices = search.slices();
                let plan = session.finish_anytime(&planner, search).map(|(p, _)| p);
                self.window_left.remove(&shard);
                cell.publish(
                    epoch,
                    Arc::new(PlanUpdate {
                        epoch,
                        shard,
                        plan,
                        done: report.done,
                        exhausted: exhausted && !report.done,
                        n_enumerated,
                        slices,
                        search_seconds,
                    }),
                );
                return;
            }
        }
    }
}
