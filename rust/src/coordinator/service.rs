//! The async planner service: off-thread anytime search with lock-free
//! plan publication.
//!
//! The sync serving path interleaves search and training on one thread —
//! each replan slice runs *between* training steps, so with no overlapping
//! deployment (cold start) the search time is exposed on the serving
//! clock. This module promotes planning to a dedicated service thread that
//! owns its own [`PlanningSession`] and pumps
//! [`PlanningSession::pump_anytime_cancellable`] continuously, so search
//! overlaps training even when nothing is deployed:
//!
//! ```text
//!  event thread (ServeRuntime)              planner service thread
//!  ───────────────────────────              ──────────────────────
//!  TaskEvent ──► apply_event                 recv ──► drain to newest
//!      │            (window opens)             │
//!      ├─ cancel in-flight token ──────────►  CancelToken observed
//!      └─ submit(epoch+1, tasks) ──────────►  inside PlanCursor slice:
//!                                             discard slice, new search
//!  train_step ... train_step                 pump ─ pump ─ pump ─ done
//!      │                                       │
//!      ▼         ┌───────────────┐             ▼
//!  poll() ◄──────┤  EpochCell    │◄── publish(epoch, final plan)
//!      │         │ (lock-free)   │
//!      ▼         └───────────────┘
//!  epoch match? ──► finish_replan_with(plan) at the step boundary
//! ```
//!
//! **Supersession** is epoch-counted: every [`PlannerService::submit`]
//! cancels the previous request's [`CancelToken`] and bumps the epoch. The
//! token is checked inside `PlanCursor` enumeration slices (every plan),
//! so a superseding event interrupts the search mid-slice instead of
//! waiting for cooperative slice exhaustion; the interrupted slice's
//! partial results are discarded wholesale (see
//! [`PlanningSession::pump_anytime_cancellable`]). The [`EpochCell`]
//! rejects publishes at stale epochs, so a search superseded between
//! computing and publishing its plan can never overwrite its successor's.
//!
//! **Determinism.** The service publishes only *terminal* results — the
//! search ran to enumeration completion (`done`) or its budget expired
//! (`exhausted`, plan = best-so-far) — exactly the two adoption points of
//! the sync path. A completed (`done`) search is built from the same
//! certified-cold-identical machinery as the sync path (same
//! `begin/pump/finish` calls on a `PlanningSession`), so its plan is
//! bit-identical to a cold `Planner::plan` for the same task set — that is
//! what `tests/async_planner.rs` certifies across thread counts, the same
//! way warm == cold is certified today. Budget *accounting* is the one
//! best-effort divergence: a superseding request carries the open window's
//! remaining budget like the sync path, but if an event lands in the gap
//! after the service finished and before the runtime adopted, the
//! successor restarts with a full budget (the sync path, which adopts at
//! the same tick it detects completion, has no such gap). Under the
//! unlimited-budget certification setup this is moot; under the wall
//! meter, budgets are timing-dependent by definition.
//!
//! Raw thread spawning here is sanctioned by detlint rule R6 (confined to
//! `util::par` and this module).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::cluster::ClusterSpec;
use crate::config::TaskSet;
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::runtime::BudgetMeter;
use crate::coordinator::session::PlanningSession;
use crate::costmodel::CostModel;
use crate::util::par::{with_max_threads, CancelToken, EpochCell};

/// A terminal search result published by the service. Every update is
/// final for its epoch: the service publishes nothing mid-search (the
/// expensive candidate evaluation runs once, at adoption time, exactly
/// like the sync path — this is what keeps async == sync plan identity).
#[derive(Debug, Clone)]
pub struct PlanUpdate {
    /// The request epoch this result answers (compare against the epoch
    /// returned by [`PlannerService::submit`] before adopting).
    pub epoch: u64,
    /// The plan to adopt; `None` means the world is infeasible for the
    /// requested task set (the deployment drains).
    pub plan: Option<DeploymentPlan>,
    /// The enumeration ran to completion: `plan` is certified
    /// cold-identical.
    pub done: bool,
    /// The budget expired mid-search: `plan` is the feasible best-so-far.
    pub exhausted: bool,
    /// Plans enumerated across the whole search.
    pub n_enumerated: usize,
    /// Slices the search took.
    pub slices: u32,
    /// Service-side wall-clock spent searching (for the runtime's
    /// overlapped-vs-unoverlapped split; budget charging uses the
    /// [`BudgetMeter`], which may be the sim clock instead).
    pub search_seconds: f64,
}

/// One search request: plan for `tasks`, reporting at `epoch`.
struct PlanRequest {
    epoch: u64,
    tasks: TaskSet,
    /// Replan budget for a fresh window; `None` = unlimited.
    budget: Option<f64>,
    /// This request opens a new replan window (don't carry the previous
    /// window's remaining budget).
    fresh: bool,
    cancel: CancelToken,
}

enum Cmd {
    Plan(Box<PlanRequest>),
    Shutdown,
}

/// Handle to the planner service thread. Owned by the serving runtime;
/// dropping it shuts the thread down (cancelling any in-flight search).
pub struct PlannerService {
    tx: mpsc::Sender<Cmd>,
    cell: Arc<EpochCell<PlanUpdate>>,
    handle: Option<JoinHandle<()>>,
    epoch: u64,
    current_cancel: Option<CancelToken>,
}

impl PlannerService {
    /// Spawn the service thread. It owns a clone of the world (cost model
    /// + cluster) and its own [`PlanningSession`]; session warm-starts are
    /// certified plan-identical to cold searches, so the separate memo
    /// chain changes no published plan. `threads` bounds the slice
    /// parallelism *of the service thread only* (via
    /// [`with_max_threads`]); the event loop's own parallelism is
    /// untouched.
    pub fn spawn(
        cost: CostModel,
        cluster: ClusterSpec,
        opts: PlannerOptions,
        meter: BudgetMeter,
        slice_plans: usize,
        threads: usize,
    ) -> Self {
        let (tx, rx) = mpsc::channel();
        let cell = Arc::new(EpochCell::new());
        let worker_cell = Arc::clone(&cell);
        let handle = std::thread::spawn(move || {
            let worker = Worker {
                cost,
                cluster,
                session: PlanningSession::new(opts),
                meter,
                slice_plans,
                cell: worker_cell,
                window_open: false,
                window_left: None,
            };
            with_max_threads(threads, || worker.run(&rx));
        });
        Self {
            tx,
            cell,
            handle: Some(handle),
            epoch: 0,
            current_cancel: None,
        }
    }

    /// Request a plan for `tasks`, superseding any in-flight search (its
    /// token is cancelled before the new request is sent, so the service
    /// observes the cancellation no later than the request). Returns the
    /// request epoch: adopt a polled [`PlanUpdate`] only when its epoch
    /// matches. `fresh` marks the start of a new replan window (full
    /// `budget`); a non-fresh request carries the open window's remaining
    /// budget.
    pub fn submit(&mut self, tasks: TaskSet, budget: Option<f64>, fresh: bool) -> u64 {
        self.cancel_current();
        let cancel = CancelToken::new();
        self.current_cancel = Some(cancel.clone());
        self.epoch += 1;
        let _ = self.tx.send(Cmd::Plan(Box::new(PlanRequest {
            epoch: self.epoch,
            tasks,
            budget,
            fresh,
            cancel,
        })));
        self.epoch
    }

    /// Cancel the in-flight search (if any) without submitting a new one —
    /// a drain event has no successor task set to search for.
    pub fn cancel_current(&mut self) {
        if let Some(c) = self.current_cancel.take() {
            c.cancel();
        }
    }

    /// Wait-free snapshot of the newest published result (the cell epoch
    /// and the update it tags). `None` until the first publish.
    pub fn poll(&self) -> Option<(u64, Arc<PlanUpdate>)> {
        self.cell.read()
    }

    /// The epoch of the most recent [`Self::submit`] (0 before any).
    pub fn submitted_epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for PlannerService {
    fn drop(&mut self) {
        self.cancel_current();
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Service-thread state: the cloned world plus its own planning session
/// and replan-window budget bookkeeping.
struct Worker {
    cost: CostModel,
    cluster: ClusterSpec,
    session: PlanningSession,
    meter: BudgetMeter,
    slice_plans: usize,
    cell: Arc<EpochCell<PlanUpdate>>,
    /// A replan window is open: a superseding (non-fresh) request carries
    /// [`Self::window_left`] instead of a full budget.
    window_open: bool,
    /// Remaining budget of the open window; `None` = unlimited.
    window_left: Option<f64>,
}

impl Worker {
    fn run(mut self, rx: &mpsc::Receiver<Cmd>) {
        loop {
            let mut cmd = match rx.recv() {
                Ok(c) => c,
                // sender dropped without Shutdown (runtime panicked)
                Err(_) => return,
            };
            // Drain to the newest request: every intermediate one was
            // superseded (its token is already cancelled) before we ever
            // started it, so searching for it would be pure waste.
            while let Ok(newer) = rx.try_recv() {
                cmd = newer;
            }
            match cmd {
                Cmd::Shutdown => return,
                Cmd::Plan(req) => self.plan(*req),
            }
        }
    }

    /// Run one search to a terminal state (done / exhausted / cancelled),
    /// publishing the terminal result unless cancelled.
    fn plan(&mut self, req: PlanRequest) {
        let PlanRequest { epoch, tasks, budget, fresh, cancel } = req;
        // Budget carry across supersession, mirroring the sync runtime's
        // replan window: a fresh window starts with the full budget, a
        // superseding request inherits what the superseded search left.
        let mut left = if fresh || !self.window_open { budget } else { self.window_left };
        self.window_open = true;

        let planner = Planner::new(&self.cost, &self.cluster);
        let Some(mut search) = self.session.begin_anytime(&planner, &tasks) else {
            // Infeasible world (e.g. no candidate config supports the
            // longest bucket): terminal "no plan" verdict, window closed.
            self.window_open = false;
            self.window_left = None;
            self.cell.publish(
                epoch,
                Arc::new(PlanUpdate {
                    epoch,
                    plan: None,
                    done: true,
                    exhausted: false,
                    n_enumerated: 0,
                    slices: 0,
                    search_seconds: 0.0,
                }),
            );
            return;
        };
        let mut search_seconds = 0.0;
        loop {
            let report = self.session.pump_anytime_cancellable(
                &planner,
                &mut search,
                self.slice_plans,
                Some(&cancel),
            );
            search_seconds += report.wall_seconds;
            if report.cancelled {
                // Superseded: leave the window open carrying the remaining
                // budget, and drop the search unfinished — the sync path's
                // supersession likewise drops the pending search without
                // adopting it. Nothing is published (and the EpochCell
                // would reject this epoch anyway once the successor
                // publishes).
                self.window_left = left;
                return;
            }
            let charge = self.meter.charge(report.wall_seconds, report.n_enumerated);
            let mut exhausted = false;
            if let Some(b) = left.as_mut() {
                *b -= charge;
                exhausted = *b <= 0.0;
            }
            if report.done || exhausted {
                // capture counters before finish_anytime consumes the
                // search
                let n_enumerated = search.n_enumerated();
                let slices = search.slices();
                let plan = self.session.finish_anytime(&planner, search).map(|(p, _)| p);
                self.window_open = false;
                self.window_left = None;
                self.cell.publish(
                    epoch,
                    Arc::new(PlanUpdate {
                        epoch,
                        plan,
                        done: report.done,
                        exhausted: exhausted && !report.done,
                        n_enumerated,
                        slices,
                        search_seconds,
                    }),
                );
                return;
            }
        }
    }
}
