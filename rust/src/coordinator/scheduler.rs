//! The joint-FT step loop (simulation-clock execution).
//!
//! Each training step: draw the fused batch → dynamic-bucketize → solve the
//! balanced dispatch → build the [`crate::exec::ExecutionPlan`] → execute
//! it on a [`crate::exec::SimExecutor`] (exact cost-model times) →
//! synchronous LoRA sync → account GPU seconds. This is the engine behind
//! the end-to-end (Fig. 7), ablation (Fig. 8), case-study (Fig. 9) and
//! scalability (Fig. 11) benches; the *real* PJRT-backed training loop in
//! [`crate::train`] routes through the same dispatch → `ExecutionPlan` →
//! executor pipeline with the PJRT backend, so both report GPU-seconds
//! from the same dispatch code.

use std::sync::Arc;

use crate::cluster::GpuLedger;
use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::{
    bucketize, buckets_from_boundaries, padding_ratio, BucketingOptions, Buckets,
};
use crate::coordinator::dispatcher::{DispatchPlan, DispatchPolicy};
use crate::coordinator::planner::DeploymentPlan;
use crate::costmodel::{CostModel, CostTable, CostTables};
use crate::data::MultiTaskSampler;
use crate::exec::{ExecutionPlan, ReplicaExecutor, SimExecutor};
use crate::metrics::JointFtReport;
use crate::util::clock::Stopwatch;

/// Scheduler knobs — the Figure 8 ablation axes.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    pub bucketing: BucketingOptions,
    pub policy: DispatchPolicy,
    /// Dynamic (per-batch DP) vs fixed equal-width boundaries.
    pub dynamic_bucketing: bool,
    pub seed: u64,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        Self {
            bucketing: BucketingOptions::default(),
            policy: DispatchPolicy::Balanced,
            dynamic_bucketing: true,
            seed: 7,
        }
    }
}

/// Outcome of one simulated step.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub step: u64,
    pub step_time: f64,
    pub gpu_seconds: f64,
    pub utilization: f64,
    pub padding_ratio: f64,
    /// Dispatch-solver wall-clock (the overlappable per-step planning cost).
    pub solve_seconds: f64,
    pub dispatch: DispatchPlan,
}

/// Joint-FT scheduler over a fixed deployment plan.
pub struct Scheduler<'a> {
    cost: &'a CostModel,
    plan: &'a DeploymentPlan,
    sampler: MultiTaskSampler,
    opts: SchedulerOptions,
    ledger: GpuLedger,
    reports: Vec<StepReport>,
    /// Boundaries fixed at init (used when `dynamic_bucketing = false`):
    /// derived once from a calibration sample, like the paper's fixed-
    /// boundary ablation arm.
    fixed: Vec<u32>,
    /// Shared cost-table LRU: per-step tables are drawn from here, so a
    /// boundary vector the dynamic-bucketing DP revisits — even after
    /// intervening steps landed elsewhere — reuses its table instead of
    /// rebuilding (the old single-slot memo only survived *consecutive*
    /// repeats). The handle may be shared with a planning session.
    tables: CostTables,
    /// The step's current table (skips the cache lock while consecutive
    /// batches land on the same boundaries — the common case).
    table: Option<Arc<CostTable>>,
    /// Execution backend: the scheduler is a thin loop over it.
    exec: SimExecutor<'a>,
}

impl<'a> Scheduler<'a> {
    pub fn new(
        cost: &'a CostModel,
        plan: &'a DeploymentPlan,
        tasks: &TaskSet,
        opts: SchedulerOptions,
    ) -> Self {
        Self::with_tables(cost, plan, tasks, opts, CostTables::default())
    }

    /// Like [`Self::new`] but drawing cost tables from a shared cache
    /// (e.g. [`crate::coordinator::tasks::TaskManager::tables`]), so the
    /// scheduler and the planning session reuse each other's builds.
    pub fn with_tables(
        cost: &'a CostModel,
        plan: &'a DeploymentPlan,
        tasks: &TaskSet,
        opts: SchedulerOptions,
        tables: CostTables,
    ) -> Self {
        let mut calib_sampler = MultiTaskSampler::new(tasks, opts.seed ^ 0xCA11B);
        let calib = calib_sampler.calibration_lengths(20);
        let fixed = bucketize(&calib, &opts.bucketing).boundaries;
        Self {
            cost,
            plan,
            sampler: MultiTaskSampler::new(tasks, opts.seed),
            opts,
            ledger: GpuLedger::new(),
            reports: Vec::new(),
            fixed,
            tables,
            table: None,
            exec: SimExecutor::new(cost),
        }
    }

    /// Cloneable handle to the scheduler's cost-table cache.
    pub fn tables(&self) -> CostTables {
        self.tables.clone()
    }

    pub fn plan(&self) -> &DeploymentPlan {
        self.plan
    }

    /// Bucketize one batch of lengths per the configured policy.
    pub fn buckets_for(&self, lengths: &[u32]) -> Buckets {
        if self.opts.dynamic_bucketing {
            bucketize(lengths, &self.opts.bucketing)
        } else {
            // fixed boundaries may not cover an extreme sample: *append* a
            // batch-max boundary so the original buckets keep their
            // coverage — overwriting the last boundary would silently pad
            // every sequence in the top buckets to the batch max.
            let max_len = lengths.iter().copied().max().unwrap_or(0);
            if max_len > *self.fixed.last().unwrap_or(&0) {
                let mut b = self.fixed.clone();
                b.push(max_len);
                buckets_from_boundaries(lengths, &b)
            } else {
                buckets_from_boundaries(lengths, &self.fixed)
            }
        }
    }

    /// Run one step; returns its report.
    ///
    /// The step is a thin pipeline: sample → bucketize → build the
    /// [`ExecutionPlan`] (MINMAX dispatch solve + concrete sequence
    /// assignment) → hand it to the [`SimExecutor`]. All step-time
    /// arithmetic lives in the executor; `tests/exec_identity.rs` certifies
    /// it is bit-identical to the pre-exec inline computation.
    pub fn step(&mut self) -> Option<StepReport> {
        let batch = self.sampler.next_batch();
        let lengths = batch.lengths();
        let buckets = self.buckets_for(&lengths);

        let t0 = Stopwatch::start();
        if self.table.as_ref().map_or(true, |t| !t.covers(&buckets.boundaries)) {
            let cfgs: Vec<ParallelConfig> =
                self.plan.groups.iter().map(|&(c, _)| c).collect();
            self.table =
                Some(self.tables.get_or_build(self.cost, &cfgs, &buckets.boundaries));
        }
        let table_seconds = t0.elapsed_secs();
        let eplan = ExecutionPlan::build(
            self.cost,
            self.plan,
            self.table.clone(),
            batch,
            buckets,
            self.opts.policy,
        )?;
        // solve cost = table (re)build + the dispatch solve itself; the
        // concrete-sequence deal-out inside `build` is execution setup, not
        // planning, and must not inflate the overlappable-solve metric
        let solve_seconds = table_seconds + eplan.solve_seconds;

        let exec = self.exec.execute_step(&eplan).ok()?;
        let acc = self.ledger.record_step(&exec.replica_seconds);
        let report = StepReport {
            step: self.ledger.steps,
            step_time: exec.step_time,
            gpu_seconds: self.plan.gpus_used() as f64 * exec.step_time,
            utilization: acc.utilization,
            padding_ratio: padding_ratio(&lengths, &eplan.buckets.boundaries),
            solve_seconds,
            dispatch: eplan.dispatch,
        };
        self.reports.push(report.clone());
        Some(report)
    }

    /// Run `n` steps and summarize.
    pub fn run_steps(&mut self, n: usize) -> JointFtReport {
        for _ in 0..n {
            if self.step().is_none() {
                break;
            }
        }
        self.report()
    }

    /// Aggregate report over all executed steps.
    pub fn report(&self) -> JointFtReport {
        JointFtReport::from_steps(
            &self.plan.notation(),
            self.plan.gpus_used(),
            self.reports.iter().map(|r| (r.step_time, r.gpu_seconds, r.utilization, r.padding_ratio, r.solve_seconds)),
        )
    }

    pub fn steps(&self) -> &[StepReport] {
        &self.reports
    }
}

/// Result of [`sequential_gpu_seconds`].
#[derive(Debug, Clone, Default)]
pub struct SequentialRuns {
    /// Sum of per-task GPU seconds per step (the baseline's total).
    pub total_gpu_seconds: f64,
    pub per_task: Vec<(String, f64)>,
    /// Tasks the single-task planner could not place. They contribute
    /// nothing to `total_gpu_seconds`, so any baseline comparison must
    /// surface them — silently dropping a task would under-count the
    /// baseline and overstate LobRA's reduction.
    pub skipped: Vec<String>,
}

/// GPU seconds for running the tasks **sequentially** (Task-Sequential /
/// LobRA-Sequential baselines): each task is planned and run on its own,
/// and the totals are summed (paper Figure 4(a) accounting). Unplannable
/// tasks are reported in [`SequentialRuns::skipped`], never silently
/// dropped.
pub fn sequential_gpu_seconds(
    cost: &CostModel,
    cluster: &crate::cluster::ClusterSpec,
    tasks: &TaskSet,
    heterogeneous: bool,
    steps: usize,
    opts: &SchedulerOptions,
) -> SequentialRuns {
    use crate::coordinator::planner::{Planner, PlannerOptions};
    let planner = Planner::new(cost, cluster);
    let mut runs = SequentialRuns::default();
    for t in &tasks.tasks {
        let single = TaskSet::new(vec![t.clone()]);
        let plan = if heterogeneous {
            planner.plan(&single, PlannerOptions::default())
        } else {
            planner.plan_homogeneous(&single, &PlannerOptions::default())
        };
        let Some(plan) = plan else {
            runs.skipped.push(t.name.clone());
            continue;
        };
        let mut sched = Scheduler::new(cost, &plan, &single, opts.clone());
        let rep = sched.run_steps(steps);
        runs.total_gpu_seconds += rep.gpu_seconds_per_step;
        runs.per_task.push((t.name.clone(), rep.gpu_seconds_per_step));
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;
    use crate::coordinator::planner::{Planner, PlannerOptions};

    fn world() -> (CostModel, ClusterSpec, TaskSet) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        (cost, cluster, tasks)
    }

    #[test]
    fn steps_execute_and_account() {
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
        let rep = sched.run_steps(10);
        assert_eq!(rep.steps, 10);
        assert!(rep.gpu_seconds_per_step > 0.0);
        assert!(rep.mean_step_time > 0.0);
        assert!(rep.utilization > 0.3 && rep.utilization <= 1.0);
    }

    #[test]
    fn balanced_beats_length_based_end_to_end() {
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut o_lb = SchedulerOptions::default();
        o_lb.policy = DispatchPolicy::LengthBased;
        let lb = Scheduler::new(&cost, &plan, &tasks, o_lb).run_steps(20);
        let bal = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default())
            .run_steps(20);
        assert!(
            bal.gpu_seconds_per_step < lb.gpu_seconds_per_step,
            "balanced {} vs length-based {}",
            bal.gpu_seconds_per_step,
            lb.gpu_seconds_per_step
        );
    }

    #[test]
    fn dynamic_bucketing_reduces_padding() {
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut o_fixed = SchedulerOptions::default();
        o_fixed.dynamic_bucketing = false;
        let fixed = Scheduler::new(&cost, &plan, &tasks, o_fixed).run_steps(15);
        let dynamic =
            Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default()).run_steps(15);
        assert!(
            dynamic.mean_padding_ratio < fixed.mean_padding_ratio,
            "dyn {} vs fixed {}",
            dynamic.mean_padding_ratio,
            fixed.mean_padding_ratio
        );
    }

    #[test]
    fn fixed_bucketing_appends_overflow_boundary() {
        // regression: a batch max beyond the last fixed boundary used to
        // *overwrite* that boundary, silently padding the whole top bucket
        // to the batch max — it must be appended as a new bucket instead
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut o = SchedulerOptions::default();
        o.dynamic_bucketing = false;
        let sched = Scheduler::new(&cost, &plan, &tasks, o);
        let covered = sched.buckets_for(&[100, 500]);
        let top = *covered.boundaries.last().unwrap();
        let huge = top + 4096;
        let b = sched.buckets_for(&[100, 500, huge]);
        assert_eq!(b.boundaries.len(), covered.boundaries.len() + 1);
        assert_eq!(
            &b.boundaries[..covered.boundaries.len()],
            &covered.boundaries[..],
            "original boundaries must keep their coverage"
        );
        assert_eq!(*b.boundaries.last().unwrap(), huge);
        // only the overflow sequence lands in the new top bucket
        assert_eq!(b.counts.last().copied(), Some(1));
        assert_eq!(b.counts.iter().sum::<u64>(), 3);
    }

    #[test]
    fn cached_tables_keep_dispatch_bit_identical() {
        // ROADMAP "CostTable reuse across steps": two schedulers over the
        // same deployment, one sharing a pre-warmed LRU (every step is a
        // cache hit) and one building fresh tables, must produce
        // bit-identical dispatch results step for step.
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let shared = crate::costmodel::CostTables::with_capacity(16);

        let mut warmup =
            Scheduler::with_tables(&cost, &plan, &tasks, SchedulerOptions::default(), shared.clone());
        warmup.run_steps(12);
        let (_, misses_after_warmup) = shared.stats();

        let mut cached =
            Scheduler::with_tables(&cost, &plan, &tasks, SchedulerOptions::default(), shared.clone());
        let mut fresh = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
        for step in 0..12 {
            let a = cached.step().unwrap();
            let b = fresh.step().unwrap();
            assert_eq!(a.dispatch.d, b.dispatch.d, "step {step}");
            assert_eq!(
                a.step_time.to_bits(),
                b.step_time.to_bits(),
                "step {step}: cache hit changed the dispatch result"
            );
            assert_eq!(a.gpu_seconds.to_bits(), b.gpu_seconds.to_bits(), "step {step}");
        }
        let (hits, misses) = shared.stats();
        assert_eq!(
            misses, misses_after_warmup,
            "identical batch stream must be served entirely from the cache"
        );
        assert!(hits > 0);
    }

    #[test]
    fn revisited_boundaries_hit_the_lru() {
        // the old single-slot memo rebuilt on every boundary *change*; the
        // LRU must serve A→B→A without a third build
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
        let cfgs: Vec<ParallelConfig> = plan.groups.iter().map(|&(c, _)| c).collect();
        let tables = sched.tables();
        let a = vec![512u32, 2048, 8192];
        let b = vec![256u32, 1024, 4096, 16384];
        tables.get_or_build(&cost, &cfgs, &a);
        tables.get_or_build(&cost, &cfgs, &b);
        tables.get_or_build(&cost, &cfgs, &a);
        tables.get_or_build(&cost, &cfgs, &b);
        assert_eq!(tables.stats(), (2, 2), "A→B→A→B must build exactly twice");
    }

    #[test]
    fn sequential_reports_skipped_tasks() {
        // 70B on 16×A100-40G: no configuration can hold MeetingBank's 16K
        // sequences, so that task must be *reported* skipped, not silently
        // dropped from the baseline total
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_70b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let runs = sequential_gpu_seconds(
            &cost,
            &cluster,
            &tasks,
            false,
            2,
            &SchedulerOptions::default(),
        );
        assert_eq!(runs.per_task.len() + runs.skipped.len(), tasks.len());
        assert!(
            runs.skipped.iter().any(|n| n == "MeetingBank"),
            "16K task cannot fit 70B on A100-40G: {:?}",
            runs.skipped
        );
        assert!(!runs.per_task.iter().any(|(n, _)| n == "MeetingBank"));
        // the plannable world reports no skips
        let (cost7, cluster7, tasks7) = world();
        let ok = sequential_gpu_seconds(
            &cost7,
            &cluster7,
            &tasks7,
            false,
            2,
            &SchedulerOptions::default(),
        );
        assert!(ok.skipped.is_empty());
        assert_eq!(ok.per_task.len(), tasks7.len());
        assert!(ok.total_gpu_seconds > 0.0);
    }

    #[test]
    fn solve_time_overlappable() {
        // Paper Fig. 10: the per-step dispatch solve must be much cheaper
        // than the step itself (so it overlaps with training).
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
        let rep = sched.run_steps(10);
        assert!(
            rep.mean_solve_seconds < rep.mean_step_time,
            "solve {} vs step {}",
            rep.mean_solve_seconds,
            rep.mean_step_time
        );
    }
}
