//! Persistent planning sessions: warm-start incremental replanning with a
//! shared cost-table cache (paper §5.1 dynamics, ROADMAP "incremental
//! replanning" / "CostTable reuse across steps").
//!
//! The stateless [`Planner`] re-derives everything from scratch on every
//! task arrival/exit, which is what dominates the paper's "< 3 minutes"
//! adjustment budget. A [`PlanningSession`] owns the long-lived search
//! state between replans:
//!
//! * the previous replan's **survivor set** (the top-K candidates of the
//!   streaming search). On the next replan the survivors are re-scored
//!   against the *new* expectation buckets and their best bound seeds the
//!   search incumbent — every survivor is still a member of the new
//!   enumeration (the cluster did not change), so its Theorem-1 bound is an
//!   upper bound on the new optimum, and the seeded search prunes most
//!   plans with cheap table lookups before touching the exact replica-time
//!   terms. Seeding never changes the result: warm-started replans are
//!   plan-identical (same groups, bit-identical `expected_step_time`) to a
//!   cold [`Planner::plan`] on the same task set, certified by
//!   `tests/session_replan.rs`.
//! * a **[`CostTables`] LRU** keyed by (candidate-config set, bucket
//!   boundaries): recurring contexts — churn traces cycling through task
//!   sets, schedulers whose dynamic-bucketing DP revisits boundary vectors
//!   — reuse the built table instead of re-deriving the analytic model.
//!   The handle is cloneable; [`crate::coordinator::scheduler::Scheduler`]
//!   draws its per-step tables from the same cache.
//! * the **resume checkpoint** of a capped search: when the enumeration
//!   tripped `max_plans`, [`PlanningSession::extend_capped_search`]
//!   continues strictly after the recorded count vector (via
//!   [`crate::solver::partition::visit_plans_after`]) instead of
//!   re-walking the prefix, so the adjustment budget can be spent
//!   incrementally.
//! * the **anytime search** ([`AnytimeReplan`]): the same resumability,
//!   inverted into a begin/pump/finish API so a serving runtime can spend
//!   a wall-clock replan budget in slices *between training steps* — the
//!   search always holds a feasible best-so-far plan, and a fully-pumped
//!   search is plan-identical to a cold `Planner::plan`. The blocking
//!   [`PlanningSession::plan`] is now literally the unlimited-budget
//!   anytime path (one slice of the whole `max_plans` budget).
//!
//! The candidate-config set is recomputed every replan (it depends on the
//! bucket boundaries); warm-starting applies only when it matches the
//! memoized one *shape-and-content* — otherwise the survivor count vectors
//! would index different configurations and the session falls back to a
//! cold search.

use std::sync::Arc;

use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::Buckets;
use crate::coordinator::planner::{
    expectation_buckets, robustness_batches, DeploymentPlan, LowerBoundScratch, Planner,
    PlannerOptions, PlanningStats, SearchCarry,
};
use crate::costmodel::{cost_fingerprint, fnv1a, CostTable, CostTables};
use crate::solver::partition::{Plan, PlanCursor};
use crate::util::clock::Stopwatch;
use crate::util::par::CancelToken;

/// Counters of how the session's replans were served.
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Total successful replans through this session.
    pub plans: u64,
    /// Replans whose search was seeded from the previous survivor set.
    pub warm_starts: u64,
    /// Replans that ran unseeded (first plan, candidate-set change, or a
    /// capped fresh search, which must reproduce the cold capped prefix).
    pub cold_starts: u64,
    /// Capped searches continued via [`PlanningSession::extend_capped_search`].
    pub extensions: u64,
}

/// What the previous replan left behind.
#[derive(Debug, Clone)]
struct SearchMemo {
    /// Fingerprint of the task set the memo was computed for (used to gate
    /// [`PlanningSession::extend_capped_search`], which only makes sense
    /// while the task set is unchanged).
    fingerprint: u64,
    /// [`cost_fingerprint`] of the cost model the memo was searched under.
    /// Recalibration (a new profile generation) changes it, and survivors
    /// scored under the old `t(b,s)` must not seed — or extend — a search
    /// over the new one.
    cost_fp: u64,
    configs: Vec<ParallelConfig>,
    boundaries: Vec<u32>,
    /// Top-K survivors (plan, bound-in-memo-context) of the last search.
    candidates: Vec<(Plan, f64)>,
    hit_cap: bool,
    resume: Option<Vec<u32>>,
    best_bound: f64,
}

/// Cheap order-sensitive fingerprint of a task set (names, batch sizes and
/// the full length-distribution parameters) — detects "the task set
/// changed" between a capped search and its extension. The distribution
/// parameters matter: a task whose lengths were refit (same name, same
/// max) yields different buckets, and resuming against a stale checkpoint
/// would break the extension's exactness guarantee. Built on the same
/// FNV-1a step as [`crate::costmodel::structural_hash`].
fn task_fingerprint(tasks: &TaskSet) -> u64 {
    let mut h = fnv1a(0xcbf29ce484222325, tasks.tasks.len() as u64);
    for t in &tasks.tasks {
        for b in t.name.as_bytes() {
            h = fnv1a(h, *b as u64);
        }
        h = fnv1a(h, 0xFF);
        h = fnv1a(h, t.batch_size as u64);
        let d = &t.lengths;
        for v in [d.mu, d.sigma, d.tail_weight, d.tail_mu, d.tail_sigma] {
            h = fnv1a(h, v.to_bits());
        }
        h = fnv1a(h, d.min_len as u64);
        h = fnv1a(h, d.max_len as u64);
    }
    h
}

/// Merge already-held survivors with a resumed slice's candidates under
/// the combined `cutoff`, truncating to the best-bound `k` (stable sort,
/// so equal bounds keep DFS order) only when the merged set exceeds it —
/// the exact rank-truncation a single search applies. Prefix candidates
/// must come first (they precede the checkpoint in DFS order). One shared
/// implementation for [`PlanningSession::pump_anytime`] and
/// [`PlanningSession::extend_capped_search`]: the plan-identity rules
/// live in one place.
fn merge_survivors(
    prefix: Vec<(Plan, f64)>,
    extension: Vec<(Plan, f64)>,
    cutoff: f64,
    k: usize,
) -> Vec<(Plan, f64)> {
    let mut merged: Vec<(Plan, f64)> = prefix
        .into_iter()
        .filter(|(_, lb)| *lb <= cutoff)
        .chain(extension.into_iter().filter(|(_, lb)| *lb <= cutoff))
        .collect();
    if merged.len() > k {
        merged.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        merged.truncate(k);
    }
    merged
}

/// A resumable **anytime** replan: the planning context (buckets,
/// robustness batches, candidate configs, cost table, warm-start seed) is
/// frozen by [`PlanningSession::begin_anytime`], after which the
/// enumeration budget is spent slice by slice
/// ([`PlanningSession::pump_anytime`]) while the search always holds a
/// merged best-so-far survivor set — [`PlanningSession::anytime_best`]
/// yields a valid feasible deployment at *any* point, and
/// [`PlanningSession::finish_anytime`] adopts the result. A fully-pumped
/// search is plan-identical to a cold blocking `Planner::plan`; a
/// budget-exhausted one memoizes its resume checkpoint like a capped
/// search. This is the mechanism behind the serving runtime's overlapped
/// replanning ([`crate::coordinator::runtime`]) and the ROADMAP's
/// "adaptive replan budgeting" item.
#[derive(Debug)]
pub struct AnytimeReplan {
    /// Task-set fingerprint the context was frozen for.
    fingerprint: u64,
    cost_fp: u64,
    buckets: Buckets,
    eval: Vec<Buckets>,
    configs: Vec<ParallelConfig>,
    table: Arc<CostTable>,
    n_tasks: u32,
    /// Warm-start seed for the first slice (re-scored previous survivors).
    seed: Option<f64>,
    /// Enumeration position between slices.
    cursor: PlanCursor,
    /// Merged best-so-far survivors (≤ `max_evaluated` after truncation).
    candidates: Vec<(Plan, f64)>,
    best_bound: f64,
    seeded: bool,
    hit_cap: bool,
    n_enumerated: usize,
    n_survivors: usize,
    peak_storage: usize,
    slices: u32,
    /// Host wall-clock spent across begin + every pumped slice.
    spent_seconds: f64,
}

impl AnytimeReplan {
    /// Whether the enumeration has been fully walked (further pumping is a
    /// no-op; the finished plan is certified cold-identical).
    pub fn enumeration_done(&self) -> bool {
        self.cursor.is_exhausted()
    }

    /// Plans enumerated so far, across all slices.
    pub fn n_enumerated(&self) -> usize {
        self.n_enumerated
    }

    /// Slices pumped so far.
    pub fn slices(&self) -> u32 {
        self.slices
    }

    /// Host wall-clock spent in begin + slices so far.
    pub fn spent_seconds(&self) -> f64 {
        self.spent_seconds
    }
}

/// What one [`PlanningSession::pump_anytime`] slice did.
#[derive(Debug, Clone, Copy)]
pub struct SliceReport {
    /// Plans enumerated by this slice.
    pub n_enumerated: usize,
    /// Host wall-clock of this slice.
    pub wall_seconds: f64,
    /// The enumeration is complete (no further slices needed).
    pub done: bool,
    /// A supersession token interrupted the slice: its partial results
    /// were discarded and the search state was left exactly as it was
    /// before the slice ran (see
    /// [`PlanningSession::pump_anytime_cancellable`]).
    pub cancelled: bool,
}

/// A long-lived planning session. Construct once per (cost model, cluster)
/// pair and feed it every replan of that world; feeding it planners built
/// over a *different* world invalidates the warm-start reasoning (the memo
/// plans would no longer be members of the search space), so don't.
#[derive(Debug)]
pub struct PlanningSession {
    opts: PlannerOptions,
    tables: CostTables,
    memo: Option<SearchMemo>,
    pub stats: SessionStats,
}

impl PlanningSession {
    pub fn new(opts: PlannerOptions) -> Self {
        Self::with_tables(opts, CostTables::default())
    }

    /// Share an existing cost-table cache (e.g. with a running scheduler).
    pub fn with_tables(opts: PlannerOptions, tables: CostTables) -> Self {
        Self { opts, tables, memo: None, stats: SessionStats::default() }
    }

    pub fn options(&self) -> &PlannerOptions {
        &self.opts
    }

    /// Cloneable handle to the session's cost-table LRU.
    pub fn tables(&self) -> CostTables {
        self.tables.clone()
    }

    /// Whether the next replan can warm-start (a memo exists).
    pub fn has_memo(&self) -> bool {
        self.memo.is_some()
    }

    /// Drop the memoized search state (the next replan runs cold).
    pub fn invalidate(&mut self) {
        self.memo = None;
    }

    /// Re-slice the GPU capacity this session's searches may pack (a
    /// planning shard's slice; `None` = the whole cluster). A changed
    /// budget invalidates the memo wholesale: survivors, resume
    /// checkpoints and `hit_cap` all describe an enumeration over the old
    /// capacity, so resuming against them would break the plan-identity
    /// guarantees. A no-op (same budget) keeps the memo.
    pub fn set_gpu_budget(&mut self, budget: Option<u32>) {
        if self.opts.gpu_budget != budget {
            self.opts.gpu_budget = budget;
            self.memo = None;
        }
    }

    /// Session-aware [`Planner::plan`].
    pub fn plan(&mut self, planner: &Planner, tasks: &TaskSet) -> Option<DeploymentPlan> {
        self.plan_with_stats(planner, tasks).map(|(p, _)| p)
    }

    /// Session-aware [`Planner::plan_with_stats`]: identical output (same
    /// groups, bit-identical `expected_step_time`), but the search is
    /// seeded from the previous survivor set when the candidate-config set
    /// still matches, and the cost table comes from the shared LRU.
    ///
    /// Since the anytime refactor this is a thin wrapper over the resumable
    /// search: [`Self::begin_anytime`] freezes the planning context, one
    /// [`Self::pump_anytime`] slice of the full `max_plans` budget runs the
    /// search (parallel and seeded, exactly as before), and
    /// [`Self::finish_anytime`] evaluates and memoizes. The blocking path
    /// is literally the unlimited-budget anytime path — bit-identical
    /// results, inverted control flow.
    pub fn plan_with_stats(
        &mut self,
        planner: &Planner,
        tasks: &TaskSet,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        let budget = self.opts.max_plans;
        let mut search = self.begin_anytime(planner, tasks)?;
        self.pump_anytime(planner, &mut search, budget);
        self.finish_anytime(planner, search)
    }

    /// Freeze the planning context for a resumable **anytime** replan:
    /// expectation buckets, robustness batches, candidate configurations,
    /// the shared-LRU cost table and the warm-start seed are computed
    /// exactly as the blocking path would, but no enumeration runs yet.
    /// Spend the search budget with [`Self::pump_anytime`] and adopt the
    /// result with [`Self::finish_anytime`] (which is valid — feasible
    /// best-so-far — after *any* number of slices, including zero).
    ///
    /// Returns `None` (clearing the memo) when no plan can exist: empty
    /// task set, no candidate configurations, or no candidate supports the
    /// longest bucket.
    pub fn begin_anytime(
        &mut self,
        planner: &Planner,
        tasks: &TaskSet,
    ) -> Option<AnytimeReplan> {
        let start = Stopwatch::start();
        if tasks.is_empty() {
            self.memo = None;
            return None;
        }
        // The memo only describes the cost world it was searched under;
        // a swapped cost model (e.g. recalibration bumping the profile
        // generation) invalidates it wholesale — the next replan is cold.
        let cost_fp = cost_fingerprint(planner.cost());
        if self.memo.as_ref().is_some_and(|m| m.cost_fp != cost_fp) {
            self.memo = None;
        }
        let opts = self.opts.clone();

        // 1. calibration sample → expectation buckets + robustness batches
        // (the exact code path of the stateless planner, so anytime and
        // cold replans see the same batches).
        let (mut sampler, buckets) = expectation_buckets(tasks, &opts);
        let eval =
            robustness_batches(&mut sampler, &buckets.boundaries, opts.eval_batches);

        // 2. candidate configurations (depend on the boundaries, so they
        // are recomputed — warm-starting is gated on them matching).
        let configs = if opts.config_proposal {
            planner.propose_configs(&buckets.boundaries, opts.allow_cross_server_tp)
        } else {
            planner.feasible_configs(opts.allow_cross_server_tp)
        };
        if configs.is_empty() {
            self.memo = None;
            return None;
        }
        // Infeasible worlds (no candidate supports the longest bucket) must
        // not pollute the shared LRU with a dead table — bail before the
        // fetch, mirroring the stateless planner.
        let longest = *buckets.boundaries.last()? as u64;
        if !configs.iter().any(|&c| planner.cost().max_seq_len(c) >= longest) {
            self.memo = None;
            return None;
        }

        // 3. cost table from the shared LRU (bit-identical to a fresh
        // build). Exactly one fetch per begun replan, preserving the
        // "one table fetch per replan" accounting invariant.
        let table = self.tables.get_or_build(planner.cost(), &configs, &buckets.boundaries);

        // 4. seed for the search incumbent from the previous survivors.
        let seed = self.seed_bound(planner, &table, &buckets, &configs);

        Some(AnytimeReplan {
            fingerprint: task_fingerprint(tasks),
            cost_fp,
            buckets,
            eval,
            configs,
            table,
            n_tasks: tasks.len() as u32,
            seed,
            cursor: PlanCursor::new(),
            candidates: Vec::new(),
            best_bound: f64::INFINITY,
            seeded: false,
            hit_cap: false,
            n_enumerated: 0,
            n_survivors: 0,
            peak_storage: 0,
            slices: 0,
            spent_seconds: start.elapsed_secs(),
        })
    }

    /// Spend one enumeration slice of up to `slice_plans` plans on a
    /// resumable replan. The first slice runs the (parallel, warm-seeded)
    /// streaming search capped at the slice budget; later slices resume
    /// strictly after the recorded checkpoint and merge their survivors
    /// under the combined cutoff, exactly like
    /// [`Self::extend_capped_search`] — so the fully-pumped search is
    /// plan-identical to a single uncapped one. A slice that trips its cap
    /// leaves the cursor resumable; a slice that completes the enumeration
    /// marks the search done.
    pub fn pump_anytime(
        &self,
        planner: &Planner,
        search: &mut AnytimeReplan,
        slice_plans: usize,
    ) -> SliceReport {
        self.pump_anytime_cancellable(planner, search, slice_plans, None)
    }

    /// [`Self::pump_anytime`] with a supersession token. When `cancel` is
    /// armed (before or during the slice), the slice's partial results are
    /// **discarded wholesale** — candidates, bounds, cursor and counters
    /// stay exactly as they were before the slice ran — and the report
    /// comes back `cancelled` (never `done`). Discarding is what keeps
    /// determinism certifiable: where the flag lands mid-enumeration is
    /// timing-dependent, so the only deterministic states are "slice never
    /// happened" and "slice ran in full". A cancelled search is normally
    /// dropped by its owner (the planner service starts a fresh search for
    /// the superseding task set); if resumed instead, the next slice
    /// re-runs from the same checkpoint as if the cancelled one had never
    /// been attempted.
    pub fn pump_anytime_cancellable(
        &self,
        planner: &Planner,
        search: &mut AnytimeReplan,
        slice_plans: usize,
        cancel: Option<&CancelToken>,
    ) -> SliceReport {
        let armed = |c: Option<&CancelToken>| matches!(c, Some(t) if t.is_cancelled());
        if armed(cancel) {
            return SliceReport {
                n_enumerated: 0,
                wall_seconds: 0.0,
                done: false,
                cancelled: true,
            };
        }
        if search.cursor.is_exhausted() || slice_plans == 0 {
            return SliceReport {
                n_enumerated: 0,
                wall_seconds: 0.0,
                done: search.cursor.is_exhausted(),
                cancelled: false,
            };
        }
        let start = Stopwatch::start();
        let mut opts = self.opts.clone();
        opts.max_plans = slice_plans;
        opts.cancel = cancel.cloned();

        if !opts.lower_bound_filter {
            // The "no filter" ablation has no bounds to merge across
            // slices: run it as one capped slice, like the blocking path.
            let found = planner.filtered_plans(&search.configs, &search.table, &search.buckets, &opts);
            if armed(cancel) {
                // interrupted mid-walk: the visited set is timing-dependent
                // — throw it away, leave the search untouched
                return SliceReport {
                    n_enumerated: 0,
                    wall_seconds: start.elapsed_secs(),
                    done: false,
                    cancelled: true,
                };
            }
            search.n_enumerated += found.n_enumerated;
            search.n_survivors = found.survivors.len();
            search.peak_storage = search.peak_storage.max(found.peak_storage);
            search.hit_cap = found.hit_cap;
            search.candidates = found.survivors;
            search.seeded = false;
            search.cursor.finish();
            search.slices += 1;
            let wall = start.elapsed_secs();
            search.spent_seconds += wall;
            return SliceReport {
                n_enumerated: search.n_enumerated,
                wall_seconds: wall,
                done: true,
                cancelled: false,
            };
        }

        let first = search.slices == 0;
        let ext = match search.cursor.checkpoint() {
            None => planner.search_top_k(
                &search.configs,
                &search.table,
                &search.buckets,
                &opts,
                search.seed,
            ),
            Some(after) => {
                let seed =
                    Some(search.best_bound).filter(|b| b.is_finite() && *b > 0.0);
                planner.search_top_k_resume(
                    &search.configs,
                    &search.table,
                    &search.buckets,
                    &opts,
                    seed,
                    after,
                    slice_plans,
                )
            }
        };
        if armed(cancel) {
            // Interrupted mid-enumeration: which plans the slice visited
            // depends on when the flag landed, so none of its products
            // (candidates, bounds, checkpoint, counters) may leak into the
            // resumable state.
            return SliceReport {
                n_enumerated: 0,
                wall_seconds: start.elapsed_secs(),
                done: false,
                cancelled: true,
            };
        }

        let threshold = 1.0 + self.opts.lower_bound_threshold;
        let best = search.best_bound.min(ext.best_bound);
        let cutoff = best * threshold;
        let k = self.opts.max_evaluated.max(1);
        if first {
            search.candidates = ext.candidates;
            search.n_survivors = ext.n_survivors;
            search.seeded = ext.seeded;
        } else {
            let merged = merge_survivors(
                std::mem::take(&mut search.candidates),
                ext.candidates,
                cutoff,
                k,
            );
            search.n_survivors = merged.len();
            search.candidates = merged;
        }
        search.best_bound = best;
        search.n_enumerated += ext.n_enumerated;
        search.peak_storage = search.peak_storage.max(ext.peak_storage);
        search.hit_cap = ext.hit_cap;
        match (ext.hit_cap, ext.resume) {
            (true, Some(cp)) => search.cursor.set_checkpoint(cp),
            // capped with no checkpoint can only mean an empty slice — the
            // enumeration has nothing more to offer
            (true, None) => search.cursor.finish(),
            (false, _) => search.cursor.finish(),
        }
        search.slices += 1;
        let wall = start.elapsed_secs();
        search.spent_seconds += wall;
        SliceReport {
            n_enumerated: ext.n_enumerated,
            wall_seconds: wall,
            done: search.cursor.is_exhausted(),
            cancelled: false,
        }
    }

    /// Evaluate the current best-so-far plan of an in-flight anytime
    /// search *without* consuming it: the merged survivors (plus the
    /// always-evaluated homogeneous fallbacks) go through the exact step-5
    /// dispatch evaluation. Never `None` for a search that
    /// [`Self::begin_anytime`] admitted — even with zero slices pumped, a
    /// homogeneous plan covering the longest bucket exists. This is what
    /// the serving runtime deploys when the replan budget expires
    /// mid-search, and what the budget-sweep bench samples per slice.
    pub fn anytime_best(
        &self,
        planner: &Planner,
        search: &AnytimeReplan,
    ) -> Option<DeploymentPlan> {
        planner.evaluate_candidates(
            search.candidates.clone(),
            &search.buckets,
            &search.eval,
            search.n_tasks,
            &self.opts,
            &search.table,
            &search.configs,
        )
    }

    /// Adopt an anytime replan: run the final evaluation over the merged
    /// survivor set, memoize the search products for the next replan (a
    /// budget-exhausted search memoizes capped, so
    /// [`Self::extend_capped_search`] can continue it), and account the
    /// replan in the session stats. When the enumeration ran to
    /// completion, the result is plan-identical — same groups,
    /// bit-identical `expected_step_time` — to a cold [`Planner::plan`]
    /// (certified by `tests/session_replan.rs`).
    pub fn finish_anytime(
        &mut self,
        planner: &Planner,
        search: AnytimeReplan,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        let start = Stopwatch::start();
        let plan = planner.evaluate_candidates(
            search.candidates.clone(),
            &search.buckets,
            &search.eval,
            search.n_tasks,
            &self.opts,
            &search.table,
            &search.configs,
        );
        match plan {
            Some(plan) => {
                let stats = PlanningStats {
                    n_candidate_configs: search.configs.len(),
                    n_plans_enumerated: search.n_enumerated,
                    n_plans_after_filter: search.n_survivors,
                    solve_seconds: search.spent_seconds + start.elapsed_secs(),
                    hit_plan_cap: search.hit_cap,
                    peak_plan_storage: search.peak_storage,
                };
                self.stats.plans += 1;
                // `search.seeded` (not `seed.is_some()`): a capped fresh
                // search drops its seed to reproduce the cold cap prefix
                // and must count as a cold start.
                if search.seeded {
                    self.stats.warm_starts += 1;
                } else {
                    self.stats.cold_starts += 1;
                }
                let resume = search.cursor.checkpoint().map(|c| c.to_vec());
                self.memo = Some(SearchMemo {
                    fingerprint: search.fingerprint,
                    cost_fp: search.cost_fp,
                    configs: search.configs,
                    boundaries: search.buckets.boundaries,
                    candidates: search.candidates,
                    hit_cap: search.hit_cap,
                    resume,
                    best_bound: search.best_bound,
                });
                Some((plan, stats))
            }
            None => {
                self.memo = None;
                None
            }
        }
    }

    /// Continue a replan whose search tripped the `max_plans` cap, with a
    /// fresh enumeration budget of `extra_plans`. The extension resumes
    /// strictly after the recorded checkpoint, merges its survivors with
    /// the memoized ones and re-runs the step-5 evaluation; the combined
    /// result equals a single search with the summed budget. Returns
    /// `None` when there is nothing to extend (no capped memo, task set or
    /// bucketing changed since, or the lower-bound filter is off).
    ///
    /// Two caveats versus a literal single larger-cap search:
    /// * the returned [`PlanningStats`] cover the *extension slice* only
    ///   (`n_plans_enumerated` excludes the already-walked prefix, and
    ///   `n_plans_after_filter` is the merged post-truncation candidate
    ///   count, not the cumulative survivor count);
    /// * when the capped prefix search truncated to `K`, the memoized
    ///   candidates are bound-sorted rather than DFS-ordered, so if two
    ///   candidate plans evaluate to *bit-identical* mean step times the
    ///   argmin tie could break toward a different (equally optimal) plan
    ///   than the single search's — distinct plans producing bit-equal
    ///   mean dispatch times do not occur in practice.
    pub fn extend_capped_search(
        &mut self,
        planner: &Planner,
        tasks: &TaskSet,
        extra_plans: usize,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        if !self.opts.lower_bound_filter || extra_plans == 0 {
            return None;
        }
        let memo = self.memo.as_ref()?;
        if !memo.hit_cap || memo.fingerprint != task_fingerprint(tasks) {
            return None;
        }
        let cost_fp = cost_fingerprint(planner.cost());
        if memo.cost_fp != cost_fp {
            return None; // cost world changed (e.g. recalibration): checkpoint is stale
        }
        let resume = memo.resume.clone()?;
        let start = Stopwatch::start();
        let mut stats = PlanningStats::default();
        let opts = self.opts.clone();

        let (mut sampler, buckets) = expectation_buckets(tasks, &opts);
        if buckets.boundaries != memo.boundaries {
            return None; // bucketing drifted: the checkpoint is stale
        }
        let eval =
            robustness_batches(&mut sampler, &buckets.boundaries, opts.eval_batches);
        let configs = memo.configs.clone();
        let table = self.tables.get_or_build(planner.cost(), &configs, &buckets.boundaries);
        stats.n_candidate_configs = configs.len();

        let seed = Some(memo.best_bound).filter(|b| b.is_finite() && *b > 0.0);
        let ext = planner.search_top_k_resume(
            &configs, &table, &buckets, &opts, seed, &resume, extra_plans,
        );
        stats.n_plans_enumerated = ext.n_enumerated;
        stats.hit_plan_cap = ext.hit_cap;
        stats.peak_plan_storage = ext.peak_storage;

        // Merge prefix + extension survivors under the combined cutoff
        // (shared rank-truncation rules: see `merge_survivors`).
        let threshold = 1.0 + opts.lower_bound_threshold;
        let best = memo.best_bound.min(ext.best_bound);
        let cutoff = best * threshold;
        let k = opts.max_evaluated.max(1);
        let merged =
            merge_survivors(memo.candidates.clone(), ext.candidates, cutoff, k);
        stats.n_plans_after_filter = merged.len();

        let plan = planner.evaluate_candidates(
            merged.clone(),
            &buckets,
            &eval,
            tasks.len() as u32,
            &opts,
            &table,
            &configs,
        )?;
        stats.solve_seconds = start.elapsed_secs();

        self.stats.extensions += 1;
        let carry = SearchCarry {
            candidates: merged,
            hit_cap: ext.hit_cap,
            resume: ext.resume,
            best_bound: best,
            seeded: ext.seeded,
        };
        self.remember(tasks, cost_fp, configs, buckets.boundaries.clone(), carry);
        Some((plan, stats))
    }

    /// Best re-scored bound of the memoized survivors against the *new*
    /// planning context — the warm-start seed. `None` when no compatible
    /// memo exists (cold start).
    fn seed_bound(
        &self,
        planner: &Planner,
        table: &CostTable,
        buckets: &Buckets,
        configs: &[ParallelConfig],
    ) -> Option<f64> {
        if !self.opts.lower_bound_filter {
            return None;
        }
        let memo = self.memo.as_ref()?;
        if memo.configs != configs {
            return None; // survivor count vectors index different configs
        }
        let n_gpus = self.opts.search_gpus(planner.cluster());
        let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
        let min_gpus = n_gpus.saturating_sub(min_n - 1);
        // The search only admits plans deploying a config that supports the
        // longest *boundary* (even when that bucket's expected count rounds
        // to 0, in which case `lower_bound_cached` would happily score a
        // short-only plan). A seed from a plan the cold search never admits
        // could undercut the cold incumbent and break warm==cold identity,
        // so mirror the visitor's support filter here.
        let longest = buckets.boundaries.last().map_or(0, |&s| s as u64);
        let supports: Vec<bool> =
            (0..configs.len()).map(|i| table.max_seq_len_at(i) >= longest).collect();
        let mut scratch = LowerBoundScratch::new();
        let mut best: Option<f64> = None;
        for (plan, _) in &memo.candidates {
            // only members of the current enumeration may seed the cutoff
            let used = plan.gpus_used(configs);
            if used < min_gpus || used > n_gpus {
                continue;
            }
            if !plan.counts.iter().zip(&supports).any(|(&c, &sup)| sup && c > 0) {
                continue;
            }
            let Some(lb) = planner.lower_bound_cached(table, &plan.counts, buckets, &mut scratch)
            else {
                continue;
            };
            if lb > 0.0 && best.map_or(true, |b| lb < b) {
                best = Some(lb);
            }
        }
        best.filter(|b| b.is_finite())
    }

    fn remember(
        &mut self,
        tasks: &TaskSet,
        cost_fp: u64,
        configs: Vec<ParallelConfig>,
        boundaries: Vec<u32>,
        carry: SearchCarry,
    ) {
        self.memo = Some(SearchMemo {
            fingerprint: task_fingerprint(tasks),
            cost_fp,
            configs,
            boundaries,
            candidates: carry.candidates,
            hit_cap: carry.hit_cap,
            resume: carry.resume,
            best_bound: carry.best_bound,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;
    use crate::costmodel::CostModel;

    fn world() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    #[test]
    fn session_plan_matches_stateless_planner() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        let cold = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let s1 = session.plan(&planner, &tasks).unwrap();
        assert_eq!(s1.groups, cold.groups);
        assert_eq!(s1.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
        assert_eq!(session.stats.cold_starts, 1);
        // replanning the same task set warm-starts and returns the same plan
        let s2 = session.plan(&planner, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 1);
        assert_eq!(s2.groups, cold.groups);
        assert_eq!(s2.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
    }

    #[test]
    fn table_cache_hits_on_recurring_context() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &tasks).unwrap();
        let (h0, m0) = session.tables().stats();
        assert_eq!((h0, m0), (0, 1));
        session.plan(&planner, &tasks).unwrap();
        let (h1, m1) = session.tables().stats();
        assert_eq!(m1, m0, "identical context must not rebuild the table");
        assert!(h1 > h0);
    }

    #[test]
    fn fingerprint_detects_task_changes() {
        let a = TaskSet::paper_7b_subset();
        let mut b = a.clone();
        assert_eq!(task_fingerprint(&a), task_fingerprint(&b));
        b.tasks[0].batch_size += 1;
        assert_ne!(task_fingerprint(&a), task_fingerprint(&b));
        let mut c = a.clone();
        c.tasks.swap(0, 1);
        assert_ne!(task_fingerprint(&a), task_fingerprint(&c), "order-sensitive");
    }

    #[test]
    fn recalibration_invalidates_warm_start_memo() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &tasks).unwrap();
        session.plan(&planner, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 1);
        // recalibrate: a measured profile changes the cost fingerprint, so
        // survivors scored under analytic t(b,s) must not seed the search
        let c = ParallelConfig::new(1, 1);
        let mut store = crate::costmodel::CalibrationStore::new(&cost);
        for &(b, s) in &[(16u64, 512u64), (4, 2048), (1, 8192), (8, 512), (2, 2048)] {
            store.record(c, b, s, 1.5 * cost.t_microbatch(c, b, s));
        }
        let profiled =
            CostModel::from_profile(&cost.model, &cluster, store.profile()).unwrap();
        assert_ne!(cost_fingerprint(&cost), cost_fingerprint(&profiled));
        let planner2 = Planner::new(&profiled, &cluster);
        session.plan(&planner2, &tasks).unwrap();
        assert_eq!(
            session.stats.cold_starts, 2,
            "recalibrated world must cold-start, not reuse stale survivors"
        );
        // the recalibrated world warm-starts against itself thereafter
        session.plan(&planner2, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 2);
    }

    #[test]
    fn empty_task_set_clears_memo() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &TaskSet::paper_7b_subset()).unwrap();
        assert!(session.has_memo());
        assert!(session.plan(&planner, &TaskSet::default()).is_none());
        assert!(!session.has_memo());
    }
}
