//! Persistent planning sessions: warm-start incremental replanning with a
//! shared cost-table cache (paper §5.1 dynamics, ROADMAP "incremental
//! replanning" / "CostTable reuse across steps").
//!
//! The stateless [`Planner`] re-derives everything from scratch on every
//! task arrival/exit, which is what dominates the paper's "< 3 minutes"
//! adjustment budget. A [`PlanningSession`] owns the long-lived search
//! state between replans:
//!
//! * the previous replan's **survivor set** (the top-K candidates of the
//!   streaming search). On the next replan the survivors are re-scored
//!   against the *new* expectation buckets and their best bound seeds the
//!   search incumbent — every survivor is still a member of the new
//!   enumeration (the cluster did not change), so its Theorem-1 bound is an
//!   upper bound on the new optimum, and the seeded search prunes most
//!   plans with cheap table lookups before touching the exact replica-time
//!   terms. Seeding never changes the result: warm-started replans are
//!   plan-identical (same groups, bit-identical `expected_step_time`) to a
//!   cold [`Planner::plan`] on the same task set, certified by
//!   `tests/session_replan.rs`.
//! * a **[`CostTables`] LRU** keyed by (candidate-config set, bucket
//!   boundaries): recurring contexts — churn traces cycling through task
//!   sets, schedulers whose dynamic-bucketing DP revisits boundary vectors
//!   — reuse the built table instead of re-deriving the analytic model.
//!   The handle is cloneable; [`crate::coordinator::scheduler::Scheduler`]
//!   draws its per-step tables from the same cache.
//! * the **resume checkpoint** of a capped search: when the enumeration
//!   tripped `max_plans`, [`PlanningSession::extend_capped_search`]
//!   continues strictly after the recorded count vector (via
//!   [`crate::solver::partition::visit_plans_after`]) instead of
//!   re-walking the prefix, so the adjustment budget can be spent
//!   incrementally.
//!
//! The candidate-config set is recomputed every replan (it depends on the
//! bucket boundaries); warm-starting applies only when it matches the
//! memoized one *shape-and-content* — otherwise the survivor count vectors
//! would index different configurations and the session falls back to a
//! cold search.

use std::time::Instant;

use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::Buckets;
use crate::coordinator::planner::{
    expectation_buckets, robustness_batches, DeploymentPlan, LowerBoundScratch, Planner,
    PlannerOptions, PlanningStats, SearchCarry,
};
use crate::costmodel::{cost_fingerprint, fnv1a, CostTable, CostTables};
use crate::solver::partition::Plan;

/// Counters of how the session's replans were served.
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Total successful replans through this session.
    pub plans: u64,
    /// Replans whose search was seeded from the previous survivor set.
    pub warm_starts: u64,
    /// Replans that ran unseeded (first plan, candidate-set change, or a
    /// capped fresh search, which must reproduce the cold capped prefix).
    pub cold_starts: u64,
    /// Capped searches continued via [`PlanningSession::extend_capped_search`].
    pub extensions: u64,
}

/// What the previous replan left behind.
#[derive(Debug, Clone)]
struct SearchMemo {
    /// Fingerprint of the task set the memo was computed for (used to gate
    /// [`PlanningSession::extend_capped_search`], which only makes sense
    /// while the task set is unchanged).
    fingerprint: u64,
    /// [`cost_fingerprint`] of the cost model the memo was searched under.
    /// Recalibration (a new profile generation) changes it, and survivors
    /// scored under the old `t(b,s)` must not seed — or extend — a search
    /// over the new one.
    cost_fp: u64,
    configs: Vec<ParallelConfig>,
    boundaries: Vec<u32>,
    /// Top-K survivors (plan, bound-in-memo-context) of the last search.
    candidates: Vec<(Plan, f64)>,
    hit_cap: bool,
    resume: Option<Vec<u32>>,
    best_bound: f64,
}

/// Cheap order-sensitive fingerprint of a task set (names, batch sizes and
/// the full length-distribution parameters) — detects "the task set
/// changed" between a capped search and its extension. The distribution
/// parameters matter: a task whose lengths were refit (same name, same
/// max) yields different buckets, and resuming against a stale checkpoint
/// would break the extension's exactness guarantee. Built on the same
/// FNV-1a step as [`crate::costmodel::structural_hash`].
fn task_fingerprint(tasks: &TaskSet) -> u64 {
    let mut h = fnv1a(0xcbf29ce484222325, tasks.tasks.len() as u64);
    for t in &tasks.tasks {
        for b in t.name.as_bytes() {
            h = fnv1a(h, *b as u64);
        }
        h = fnv1a(h, 0xFF);
        h = fnv1a(h, t.batch_size as u64);
        let d = &t.lengths;
        for v in [d.mu, d.sigma, d.tail_weight, d.tail_mu, d.tail_sigma] {
            h = fnv1a(h, v.to_bits());
        }
        h = fnv1a(h, d.min_len as u64);
        h = fnv1a(h, d.max_len as u64);
    }
    h
}

/// A long-lived planning session. Construct once per (cost model, cluster)
/// pair and feed it every replan of that world; feeding it planners built
/// over a *different* world invalidates the warm-start reasoning (the memo
/// plans would no longer be members of the search space), so don't.
#[derive(Debug)]
pub struct PlanningSession {
    opts: PlannerOptions,
    tables: CostTables,
    memo: Option<SearchMemo>,
    pub stats: SessionStats,
}

impl PlanningSession {
    pub fn new(opts: PlannerOptions) -> Self {
        Self::with_tables(opts, CostTables::default())
    }

    /// Share an existing cost-table cache (e.g. with a running scheduler).
    pub fn with_tables(opts: PlannerOptions, tables: CostTables) -> Self {
        Self { opts, tables, memo: None, stats: SessionStats::default() }
    }

    pub fn options(&self) -> &PlannerOptions {
        &self.opts
    }

    /// Cloneable handle to the session's cost-table LRU.
    pub fn tables(&self) -> CostTables {
        self.tables.clone()
    }

    /// Whether the next replan can warm-start (a memo exists).
    pub fn has_memo(&self) -> bool {
        self.memo.is_some()
    }

    /// Drop the memoized search state (the next replan runs cold).
    pub fn invalidate(&mut self) {
        self.memo = None;
    }

    /// Session-aware [`Planner::plan`].
    pub fn plan(&mut self, planner: &Planner, tasks: &TaskSet) -> Option<DeploymentPlan> {
        self.plan_with_stats(planner, tasks).map(|(p, _)| p)
    }

    /// Session-aware [`Planner::plan_with_stats`]: identical output (same
    /// groups, bit-identical `expected_step_time`), but the search is
    /// seeded from the previous survivor set when the candidate-config set
    /// still matches, and the cost table comes from the shared LRU.
    pub fn plan_with_stats(
        &mut self,
        planner: &Planner,
        tasks: &TaskSet,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        let start = Instant::now();
        let mut stats = PlanningStats::default();
        if tasks.is_empty() {
            self.memo = None;
            return None;
        }
        // The memo only describes the cost world it was searched under;
        // a swapped cost model (e.g. recalibration bumping the profile
        // generation) invalidates it wholesale — the next replan is cold.
        let cost_fp = cost_fingerprint(planner.cost());
        if self.memo.as_ref().is_some_and(|m| m.cost_fp != cost_fp) {
            self.memo = None;
        }
        let opts = self.opts.clone();

        // 1. calibration sample → expectation buckets + robustness batches
        // (the exact code path of the stateless planner, so warm and cold
        // replans see the same batches).
        let (mut sampler, buckets) = expectation_buckets(tasks, &opts);
        let eval =
            robustness_batches(&mut sampler, &buckets.boundaries, opts.eval_batches);

        // 2. candidate configurations (depend on the boundaries, so they
        // are recomputed — warm-starting is gated on them matching).
        let configs = if opts.config_proposal {
            planner.propose_configs(&buckets.boundaries, opts.allow_cross_server_tp)
        } else {
            planner.feasible_configs(opts.allow_cross_server_tp)
        };
        if configs.is_empty() {
            self.memo = None;
            return None;
        }
        // Infeasible worlds (no candidate supports the longest bucket) must
        // not pollute the shared LRU with a dead table — bail before the
        // fetch, mirroring the stateless planner.
        let longest = *buckets.boundaries.last()? as u64;
        if !configs.iter().any(|&c| planner.cost().max_seq_len(c) >= longest) {
            self.memo = None;
            return None;
        }

        // 3. cost table from the shared LRU (bit-identical to a fresh build).
        let table = self.tables.get_or_build(planner.cost(), &configs, &buckets.boundaries);

        // 4. seed the incumbent from the previous survivors, if compatible.
        let seed = self.seed_bound(planner, &table, &buckets, &configs);

        let out = planner.plan_pipeline(
            &buckets,
            &eval,
            tasks.len() as u32,
            &opts,
            &mut stats,
            start,
            &table,
            &configs,
            seed,
        );
        match out {
            Some((plan, carry)) => {
                self.stats.plans += 1;
                // `carry.seeded` (not `seed.is_some()`): a capped fresh
                // search drops its seed to reproduce the cold cap prefix
                // and must count as a cold start.
                if carry.seeded {
                    self.stats.warm_starts += 1;
                } else {
                    self.stats.cold_starts += 1;
                }
                self.remember(tasks, cost_fp, configs, buckets.boundaries.clone(), carry);
                Some((plan, stats))
            }
            None => {
                self.memo = None;
                None
            }
        }
    }

    /// Continue a replan whose search tripped the `max_plans` cap, with a
    /// fresh enumeration budget of `extra_plans`. The extension resumes
    /// strictly after the recorded checkpoint, merges its survivors with
    /// the memoized ones and re-runs the step-5 evaluation; the combined
    /// result equals a single search with the summed budget. Returns
    /// `None` when there is nothing to extend (no capped memo, task set or
    /// bucketing changed since, or the lower-bound filter is off).
    ///
    /// Two caveats versus a literal single larger-cap search:
    /// * the returned [`PlanningStats`] cover the *extension slice* only
    ///   (`n_plans_enumerated` excludes the already-walked prefix, and
    ///   `n_plans_after_filter` is the merged post-truncation candidate
    ///   count, not the cumulative survivor count);
    /// * when the capped prefix search truncated to `K`, the memoized
    ///   candidates are bound-sorted rather than DFS-ordered, so if two
    ///   candidate plans evaluate to *bit-identical* mean step times the
    ///   argmin tie could break toward a different (equally optimal) plan
    ///   than the single search's — distinct plans producing bit-equal
    ///   mean dispatch times do not occur in practice.
    pub fn extend_capped_search(
        &mut self,
        planner: &Planner,
        tasks: &TaskSet,
        extra_plans: usize,
    ) -> Option<(DeploymentPlan, PlanningStats)> {
        if !self.opts.lower_bound_filter || extra_plans == 0 {
            return None;
        }
        let memo = self.memo.as_ref()?;
        if !memo.hit_cap || memo.fingerprint != task_fingerprint(tasks) {
            return None;
        }
        let cost_fp = cost_fingerprint(planner.cost());
        if memo.cost_fp != cost_fp {
            return None; // cost world changed (e.g. recalibration): checkpoint is stale
        }
        let resume = memo.resume.clone()?;
        let start = Instant::now();
        let mut stats = PlanningStats::default();
        let opts = self.opts.clone();

        let (mut sampler, buckets) = expectation_buckets(tasks, &opts);
        if buckets.boundaries != memo.boundaries {
            return None; // bucketing drifted: the checkpoint is stale
        }
        let eval =
            robustness_batches(&mut sampler, &buckets.boundaries, opts.eval_batches);
        let configs = memo.configs.clone();
        let table = self.tables.get_or_build(planner.cost(), &configs, &buckets.boundaries);
        stats.n_candidate_configs = configs.len();

        let seed = Some(memo.best_bound).filter(|b| b.is_finite() && *b > 0.0);
        let ext = planner.search_top_k_resume(
            &configs, &table, &buckets, &opts, seed, &resume, extra_plans,
        );
        stats.n_plans_enumerated = ext.n_enumerated;
        stats.hit_plan_cap = ext.hit_cap;
        stats.peak_plan_storage = ext.peak_storage;

        // Merge prefix + extension survivors under the combined cutoff.
        // Prefix candidates come first (they precede the checkpoint in DFS
        // order); a re-sort only happens when the merged set exceeds K,
        // mirroring the single-search rank-truncation.
        let threshold = 1.0 + opts.lower_bound_threshold;
        let best = memo.best_bound.min(ext.best_bound);
        let cutoff = best * threshold;
        let k = opts.max_evaluated.max(1);
        let mut merged: Vec<(Plan, f64)> = memo
            .candidates
            .iter()
            .filter(|(_, lb)| *lb <= cutoff)
            .cloned()
            .chain(ext.candidates.into_iter().filter(|(_, lb)| *lb <= cutoff))
            .collect();
        if merged.len() > k {
            merged.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            merged.truncate(k);
        }
        stats.n_plans_after_filter = merged.len();

        let plan = planner.evaluate_candidates(
            merged.clone(),
            &buckets,
            &eval,
            tasks.len() as u32,
            &opts,
            &table,
            &configs,
        )?;
        stats.solve_seconds = start.elapsed().as_secs_f64();

        self.stats.extensions += 1;
        let carry = SearchCarry {
            candidates: merged,
            hit_cap: ext.hit_cap,
            resume: ext.resume,
            best_bound: best,
            seeded: ext.seeded,
        };
        self.remember(tasks, cost_fp, configs, buckets.boundaries.clone(), carry);
        Some((plan, stats))
    }

    /// Best re-scored bound of the memoized survivors against the *new*
    /// planning context — the warm-start seed. `None` when no compatible
    /// memo exists (cold start).
    fn seed_bound(
        &self,
        planner: &Planner,
        table: &CostTable,
        buckets: &Buckets,
        configs: &[ParallelConfig],
    ) -> Option<f64> {
        if !self.opts.lower_bound_filter {
            return None;
        }
        let memo = self.memo.as_ref()?;
        if memo.configs != configs {
            return None; // survivor count vectors index different configs
        }
        let n_gpus = planner.cluster().n_gpus;
        let min_n = configs.iter().map(|c| c.n()).min().unwrap_or(1);
        let min_gpus = n_gpus.saturating_sub(min_n - 1);
        // The search only admits plans deploying a config that supports the
        // longest *boundary* (even when that bucket's expected count rounds
        // to 0, in which case `lower_bound_cached` would happily score a
        // short-only plan). A seed from a plan the cold search never admits
        // could undercut the cold incumbent and break warm==cold identity,
        // so mirror the visitor's support filter here.
        let longest = buckets.boundaries.last().map_or(0, |&s| s as u64);
        let supports: Vec<bool> =
            (0..configs.len()).map(|i| table.max_seq_len_at(i) >= longest).collect();
        let mut scratch = LowerBoundScratch::new();
        let mut best: Option<f64> = None;
        for (plan, _) in &memo.candidates {
            // only members of the current enumeration may seed the cutoff
            let used = plan.gpus_used(configs);
            if used < min_gpus || used > n_gpus {
                continue;
            }
            if !plan.counts.iter().zip(&supports).any(|(&c, &sup)| sup && c > 0) {
                continue;
            }
            let Some(lb) = planner.lower_bound_cached(table, &plan.counts, buckets, &mut scratch)
            else {
                continue;
            };
            if lb > 0.0 && best.map_or(true, |b| lb < b) {
                best = Some(lb);
            }
        }
        best.filter(|b| b.is_finite())
    }

    fn remember(
        &mut self,
        tasks: &TaskSet,
        cost_fp: u64,
        configs: Vec<ParallelConfig>,
        boundaries: Vec<u32>,
        carry: SearchCarry,
    ) {
        self.memo = Some(SearchMemo {
            fingerprint: task_fingerprint(tasks),
            cost_fp,
            configs,
            boundaries,
            candidates: carry.candidates,
            hit_cap: carry.hit_cap,
            resume: carry.resume,
            best_bound: carry.best_bound,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;
    use crate::costmodel::CostModel;

    fn world() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    #[test]
    fn session_plan_matches_stateless_planner() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        let cold = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let s1 = session.plan(&planner, &tasks).unwrap();
        assert_eq!(s1.groups, cold.groups);
        assert_eq!(s1.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
        assert_eq!(session.stats.cold_starts, 1);
        // replanning the same task set warm-starts and returns the same plan
        let s2 = session.plan(&planner, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 1);
        assert_eq!(s2.groups, cold.groups);
        assert_eq!(s2.expected_step_time.to_bits(), cold.expected_step_time.to_bits());
    }

    #[test]
    fn table_cache_hits_on_recurring_context() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &tasks).unwrap();
        let (h0, m0) = session.tables().stats();
        assert_eq!((h0, m0), (0, 1));
        session.plan(&planner, &tasks).unwrap();
        let (h1, m1) = session.tables().stats();
        assert_eq!(m1, m0, "identical context must not rebuild the table");
        assert!(h1 > h0);
    }

    #[test]
    fn fingerprint_detects_task_changes() {
        let a = TaskSet::paper_7b_subset();
        let mut b = a.clone();
        assert_eq!(task_fingerprint(&a), task_fingerprint(&b));
        b.tasks[0].batch_size += 1;
        assert_ne!(task_fingerprint(&a), task_fingerprint(&b));
        let mut c = a.clone();
        c.tasks.swap(0, 1);
        assert_ne!(task_fingerprint(&a), task_fingerprint(&c), "order-sensitive");
    }

    #[test]
    fn recalibration_invalidates_warm_start_memo() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &tasks).unwrap();
        session.plan(&planner, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 1);
        // recalibrate: a measured profile changes the cost fingerprint, so
        // survivors scored under analytic t(b,s) must not seed the search
        let c = ParallelConfig::new(1, 1);
        let mut store = crate::costmodel::CalibrationStore::new(&cost);
        for &(b, s) in &[(16u64, 512u64), (4, 2048), (1, 8192), (8, 512), (2, 2048)] {
            store.record(c, b, s, 1.5 * cost.t_microbatch(c, b, s));
        }
        let profiled =
            CostModel::from_profile(&cost.model, &cluster, store.profile()).unwrap();
        assert_ne!(cost_fingerprint(&cost), cost_fingerprint(&profiled));
        let planner2 = Planner::new(&profiled, &cluster);
        session.plan(&planner2, &tasks).unwrap();
        assert_eq!(
            session.stats.cold_starts, 2,
            "recalibrated world must cold-start, not reuse stale survivors"
        );
        // the recalibrated world warm-starts against itself thereafter
        session.plan(&planner2, &tasks).unwrap();
        assert_eq!(session.stats.warm_starts, 2);
    }

    #[test]
    fn empty_task_set_clears_memo() {
        let (cost, cluster) = world();
        let planner = Planner::new(&cost, &cluster);
        let mut session = PlanningSession::new(PlannerOptions::default());
        session.plan(&planner, &TaskSet::paper_7b_subset()).unwrap();
        assert!(session.has_memo());
        assert!(session.plan(&planner, &TaskSet::default()).is_none());
        assert!(!session.has_memo());
    }
}
