//! The event-driven **serving runtime**: training overlapped with
//! budgeted, anytime replanning.
//!
//! The paper's multi-tenant story (§5.1) replans on every task arrival or
//! exit. The blocking [`crate::coordinator::tasks::TaskManager::handle`]
//! runs the full plan search inside the event — on large clusters that
//! stalls every live tenant's training for the whole search. This runtime
//! inverts the control flow:
//!
//! ```text
//!   churn trace ──► Event ──► TaskManager::apply_event (non-blocking)
//!                                          │ opens AnytimeReplan
//!          ┌───────────────────────────────▼───────────────────────────┐
//!          │  event loop (sim clock)                                   │
//!          │    ┌── training step (SimTrainLoop, current plan) ──┐     │
//!          │    │                                                │     │
//!          │    └── pump one search slice (budget-metered) ◄─────┘     │
//!          │            │ done / budget exhausted                      │
//!          │            ▼                                              │
//!          │    swap at step boundary: finish_replan →                 │
//!          │    charge checkpoint+restart for CHANGED groups only      │
//!          └───────────────────────────────────────────────────────────┘
//! ```
//!
//! * Training keeps stepping under the **current** deployment while the
//!   search runs: every live replica makes progress through a replan
//!   window (no stop-the-world) — the [`ServeReport`] records the minimum
//!   steps observed in any window as proof.
//! * The replan spends its budget in **slices** between steps. With an
//!   overlapping deployment the search time hides under training; with no
//!   deployment (cold start) the slices are exposed on the serving clock.
//! * Budget charging is pluggable ([`BudgetMeter`]): real wall-clock for
//!   production, a deterministic per-enumerated-plan sim clock for tests
//!   and benches.
//! * On exhaustion the **best-so-far** plan deploys (always feasible); on
//!   completion the plan is the certified cold-identical result, optionally
//!   re-verified against a cold `Planner::plan`
//!   ([`ServeOptions::certify_identity`]).
//! * Tenant-observed metrics: time-to-admission, steps trained (incl.
//!   during replan windows), and GPU-seconds lost to redeploys — charged
//!   only for replica groups that actually changed.
//! * With [`ServeOptions::planner_threads`] > 0 the search leaves the
//!   event loop entirely: a [`crate::coordinator::service::PlannerService`]
//!   thread pumps it continuously and publishes the terminal plan through
//!   a lock-free epoch cell; the loop polls at step boundaries and adopts
//!   via `TaskManager::finish_replan_with`. Search then overlaps training
//!   even on cold starts — the report's
//!   [`ServeReport::search_seconds_unoverlapped`] split collapses to the
//!   residual polling wait instead of the full search time.
//! * With [`ServeOptions::shards`] > 1 the runtime fronts a
//!   [`ShardManager`]: tenants partition into planning shards by
//!   sequence-length profile, an event replans only its own shard against
//!   that shard's GPU capacity slice (per-shard service submissions never
//!   cancel another shard's in-flight search), infeasible-now arrivals
//!   queue per priority tier (preempting the lowest tier when a higher
//!   one cannot fit), and [`ServeOptions::rebalance_every`] periodically
//!   re-slices capacity across shards. [`ServeReport`] adds the fairness
//!   evidence: per-tier time-to-admission and Jain's index over
//!   per-tenant GPU-seconds.
//! * **Cluster churn** rides the same event stream (trace grammar v2):
//!   `NodeLeave` / `Preempt` shrink the fleet's [`FleetAvailability`], the
//!   interrupted step's work on the vanished GPUs is charged as
//!   [`ServeReport::gpu_seconds_lost_preempt`], and the surviving capacity
//!   becomes planner budgets via [`ShardManager::apply_capacity`] — the
//!   shrink replan is diff-charged like any other redeploy, and training
//!   state survives it (same checkpoint-swap path; `Trainer::redeploy`
//!   carries the optimizer trajectory on the real-training side).
//!   `NodeJoin` restores capacity; a restore to *full* clears every GPU
//!   budget, so the next adopted plan is certified bit-identical to the
//!   never-shrunk cold plan and the degraded episode's time-to-recover
//!   lands in [`ServeReport::recoveries`].
//! * **Mixed-generation fleets** ([`ServeRuntime::new_fleet`]) run one
//!   planning shard and one training loop per device pool (the fleet step
//!   is the slowest pool's — LoRA gradients sync at the fleet step
//!   boundary); cluster churn maps to per-pool capacity.


use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::{ClusterSpec, FleetAvailability, VirtualCluster};
use crate::config::{TaskSet, TaskSpec};
use crate::coordinator::planner::{Planner, PlannerOptions};
use crate::coordinator::service::PlannerService;
use crate::coordinator::shard::ShardManager;
use crate::coordinator::tasks::{Event, Outcome};
use crate::costmodel::CostModel;
use crate::exec::SimTrainLoop;
use crate::util::clock::Stopwatch;

/// How a replan slice's search work is charged against the budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetMeter {
    /// Host wall-clock of each slice (production serving).
    Wall,
    /// Deterministic sim clock: `seconds × plans enumerated` per slice —
    /// host-speed-independent, so tests and benches reproduce exactly.
    SimPerPlan(f64),
}

impl BudgetMeter {
    /// Seconds to charge one search slice against the replan budget.
    /// `wall_seconds` comes from a [`crate::util::clock::Stopwatch`] (the
    /// runtime's only wall-clock consumer — rule R1 confines the raw reads
    /// to `util::clock`); `Wall` charges it directly, `SimPerPlan` ignores
    /// it in favor of the deterministic enumeration count, the `SimClock`
    /// analogue for search work.
    pub fn charge(&self, wall_seconds: f64, plans_enumerated: usize) -> f64 {
        match self {
            BudgetMeter::Wall => wall_seconds,
            BudgetMeter::SimPerPlan(per_plan) => per_plan * plans_enumerated as f64,
        }
    }
}

/// Serving-runtime knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Replan budget in seconds per window; `None` = unlimited (the swap
    /// waits for the full search, certified plan-identical to cold). A
    /// superseding event re-targets the open window but does **not**
    /// restart its budget clock, so sustained churn cannot push the swap
    /// out indefinitely — the oldest waiting tenant is admitted (to the
    /// best-so-far plan at worst) within one budget.
    pub replan_budget: Option<f64>,
    /// Enumeration budget per background slice (one slice runs between
    /// consecutive training steps).
    pub slice_plans: usize,
    pub meter: BudgetMeter,
    pub planner: PlannerOptions,
    pub seed: u64,
    /// Per-replica checkpoint+restart seconds charged on redeploy.
    pub restart_seconds_per_replica: f64,
    /// After a completed (not budget-exhausted) replan, re-verify the
    /// deployed plan against a cold `Planner::plan` — expensive, used by
    /// tests and the churn bench to certify anytime identity end to end.
    pub certify_identity: bool,
    /// Training steps to run after the last event settles (lets tenants
    /// admitted by the final replan register progress).
    pub tail_steps: u64,
    /// Worker threads for the async planner service; 0 (default) keeps
    /// the deterministic single-threaded sync path, which doubles as the
    /// sim/test double. With N > 0 the search runs on a dedicated service
    /// thread whose slice parallelism is scoped to N
    /// ([`crate::util::par::with_max_threads`]), and the event loop only
    /// polls for published plans at step boundaries.
    pub planner_threads: usize,
    /// Planning shards ([`ShardManager`]). 1 (default) is the bit-exact
    /// global path; with N > 1 tenants partition by sequence-length
    /// profile, each shard searches only its own GPU capacity slice, and
    /// an event replans only its shard — O(change), not O(fleet).
    pub shards: usize,
    /// Rebalance shard capacity slices every K training steps (0 = off).
    /// Runs only between replan windows; shards whose slice changed reopen
    /// their (diff-charged) replans.
    pub rebalance_every: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            // paper §5.1: adjustments stay under 3 minutes
            replan_budget: Some(180.0),
            slice_plans: 4096,
            meter: BudgetMeter::SimPerPlan(1e-4),
            planner: PlannerOptions::default(),
            seed: 7,
            restart_seconds_per_replica: 15.0,
            certify_identity: false,
            tail_steps: 4,
            planner_threads: 0,
            shards: 1,
            rebalance_every: 0,
        }
    }
}

/// One churn-trace record: at sim time `at`, a tenant arrives or exits —
/// or, since trace grammar v2, a cluster event lands (server join/leave,
/// GPU-range preemption).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub at: f64,
    pub event: Event,
}

/// Per-tenant observed service metrics.
#[derive(Debug, Clone)]
pub struct TenantRecord {
    pub name: String,
    /// Priority tier at arrival (0 = highest).
    pub tier: u8,
    /// Sim time the arrival was requested (trace timestamp).
    pub arrived_at: f64,
    /// Sim time the tenant's task first trained under a deployed plan.
    pub admitted_at: Option<f64>,
    /// Sim time the exit was requested.
    pub exited_at: Option<f64>,
    /// Training steps this tenant's task participated in.
    pub steps_trained: u64,
    /// GPU-seconds of training attributed to this tenant (each step's
    /// GPU-seconds split equally among deployed tenants) — the fairness
    /// metric's allocation variable.
    pub gpu_seconds: f64,
}

impl TenantRecord {
    /// Seconds from arrival request to first training step coverage.
    pub fn time_to_admission(&self) -> Option<f64> {
        self.admitted_at.map(|t| t - self.arrived_at)
    }
}

/// Aggregate outcome of a served churn trace.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub tenants: Vec<TenantRecord>,
    pub sim_seconds: f64,
    pub steps_total: u64,
    /// Steps executed while a replan window was open (overlap proof).
    pub steps_during_replan: u64,
    /// Replan windows opened — one per plan-changing event (a superseding
    /// event re-targets the open window and counts again).
    pub replan_windows: u32,
    /// Minimum training steps observed in any replan window that had a
    /// live deployment to overlap (`None`: no such window occurred).
    pub min_steps_in_replan_window: Option<u64>,
    pub redeploys: u32,
    /// Swaps whose plan was identical (charged zero adjustment).
    pub plan_swaps_identical: u32,
    /// Windows closed by budget exhaustion (best-so-far plan deployed).
    pub budget_exhausted: u32,
    pub rejected_arrivals: u32,
    pub gpu_seconds_trained: f64,
    /// GPU-seconds idled by redeploys (changed replica groups only).
    pub gpu_seconds_lost_redeploy: f64,
    /// Completed replans re-verified against a cold plan / mismatches.
    pub identity_checks: u32,
    pub identity_failures: u32,
    /// Total search time charged by the meter (sync) or reported by the
    /// planner service (async), seconds.
    pub search_seconds_total: f64,
    /// The share of search time *exposed on the serving clock* because no
    /// deployment was training to hide it under (cold starts). Sync: the
    /// full charge of every unoverlapped slice. Async: only the residual
    /// polling wait — the search itself runs off-thread, so this collapses
    /// toward zero under the wall meter.
    pub search_seconds_unoverlapped: f64,
    /// Arrivals held in the admission queue instead of rejected.
    pub queued_admissions: u32,
    /// Tenants preempted to admit a higher-priority arrival.
    pub preemptions: u32,
    /// Capacity rebalances across planning shards that changed a slice.
    pub rebalances: u32,
    /// Search slices pumped (sync) or reported by the service (async) —
    /// with [`ServeReport::replan_windows`] this is the per-event replan
    /// search cost the sharding is meant to flatten.
    pub replan_slices_total: u64,
    /// Plans enumerated across all replan searches.
    pub plans_enumerated_total: u64,
    /// `Preempt` events delivered (GPU-range reclaims).
    pub preempt_events: u32,
    /// `NodeLeave` events delivered (whole-server departures).
    pub leave_events: u32,
    /// `NodeJoin` events delivered (server restorations).
    pub join_events: u32,
    /// GPU-seconds of in-flight step work lost to capacity reclaims: each
    /// vanished GPU forfeits up to one step time of progress (the step it
    /// was interrupted in), on top of the redeploy charge the shrink pays.
    pub gpu_seconds_lost_preempt: f64,
    /// Time-to-recover of each degraded episode: seconds from the first
    /// capacity-loss event until a plan is adopted with the fleet back at
    /// full capacity (every GPU budget cleared).
    pub recoveries: Vec<f64>,
}

impl ServeReport {
    /// Mean time-to-admission over admitted tenants.
    pub fn mean_time_to_admission(&self) -> Option<f64> {
        let ttas: Vec<f64> =
            self.tenants.iter().filter_map(TenantRecord::time_to_admission).collect();
        if ttas.is_empty() {
            return None;
        }
        // lint:allow(R5): sequential mean over a Vec in event order, not a parallel reduce.
        Some(ttas.iter().sum::<f64>() / ttas.len() as f64)
    }

    /// Mean time-to-admission per priority tier (ascending tier; tiers
    /// with no admitted tenant are omitted). The SLO evidence: lower tiers
    /// should see lower TTA under contention.
    pub fn tta_by_tier(&self) -> Vec<(u8, f64)> {
        let mut by: BTreeMap<u8, (f64, u32)> = BTreeMap::new();
        for t in &self.tenants {
            if let Some(tta) = t.time_to_admission() {
                let e = by.entry(t.tier).or_insert((0.0, 0));
                e.0 += tta;
                e.1 += 1;
            }
        }
        by.into_iter().map(|(tier, (sum, n))| (tier, sum / n as f64)).collect()
    }

    /// Jain's fairness index over per-tenant GPU-seconds:
    /// `(Σx)² / (n · Σx²)` — 1.0 is a perfectly even split, `1/n` is one
    /// tenant holding everything. `None` when no tenant trained.
    pub fn jain_fairness(&self) -> Option<f64> {
        let (mut n, mut sum, mut sumsq) = (0u32, 0.0f64, 0.0f64);
        for t in &self.tenants {
            if t.gpu_seconds > 0.0 {
                n += 1;
                sum += t.gpu_seconds;
                sumsq += t.gpu_seconds * t.gpu_seconds;
            }
        }
        if n == 0 || sumsq == 0.0 {
            return None;
        }
        Some(sum * sum / (n as f64 * sumsq))
    }
}

/// Budget bookkeeping of one open replan window.
#[derive(Debug)]
struct ReplanWindow {
    budget_left: Option<f64>,
    steps_in_window: u64,
    /// A deployment existed to overlap the search with.
    had_deployment: bool,
}

/// One device pool's training loop plus the tenant-record index of each
/// of its deployed tasks (rebuilt at every swap). A homogeneous fleet has
/// exactly one entry, driven by the composed plan — bit-identical to the
/// pre-fleet single-loop runtime.
struct PoolLoop<'a> {
    pool: usize,
    tl: SimTrainLoop<'a>,
    /// Task index (in this pool's task set) → tenant-record index.
    tenants: Vec<usize>,
}

/// The serving runtime: owns the non-blocking [`ShardManager`], the
/// swappable per-pool training loops, the fleet availability ledger and
/// the sim clock, and replays a churn trace.
pub struct ServeRuntime<'a> {
    cost: &'a CostModel,
    cluster: &'a ClusterSpec,
    /// Per-pool worlds; a homogeneous fleet has exactly one.
    worlds: Vec<(&'a CostModel, &'a ClusterSpec)>,
    /// Owned fleet geometry (server spans) for resolving cluster events.
    fleet: VirtualCluster,
    /// Which GPUs are currently up, under join/leave/preempt churn.
    avail: FleetAvailability,
    mgr: ShardManager<'a>,
    /// One training loop per pool with a live plan (empty = idle fleet).
    train: Vec<PoolLoop<'a>>,
    opts: ServeOptions,
    now: f64,
    window: Option<ReplanWindow>,
    epoch: u64,
    tenants: Vec<TenantRecord>,
    report: ServeReport,
    /// The async planner service (`planner_threads` > 0), or `None` for
    /// the deterministic sync path.
    service: Option<PlannerService>,
    /// Per-shard epoch of the service request the open window awaits
    /// (stale published epochs are ignored). Distinct from `epoch`, which
    /// seeds training across redeploys.
    submitted_epochs: BTreeMap<usize, u64>,
    /// Shards the open window still awaits a published result from.
    awaiting: BTreeSet<usize>,
    /// Training steps since the last shard-capacity rebalance.
    steps_since_rebalance: u64,
    /// Sim time of the first capacity-loss event of the current degraded
    /// episode (`None`: fleet at full capacity, or recovery already
    /// recorded).
    degraded_since: Option<f64>,
    /// Duration of the most recent fleet training step — the exposure
    /// bound for interrupted-step loss accounting.
    last_step_time: f64,
}

impl<'a> ServeRuntime<'a> {
    pub fn new(cost: &'a CostModel, cluster: &'a ClusterSpec, opts: ServeOptions) -> Self {
        Self::new_fleet(vec![(cost, cluster)], opts)
    }

    /// A serving runtime over a mixed-generation fleet: one planning shard
    /// and one training loop per `(cost model, cluster pool)` world. With
    /// a single world this is exactly [`ServeRuntime::new`]; with several,
    /// `opts.shards` is ignored (device pools *are* the shards).
    pub fn new_fleet(
        worlds: Vec<(&'a CostModel, &'a ClusterSpec)>,
        opts: ServeOptions,
    ) -> Self {
        assert!(!worlds.is_empty(), "ServeRuntime needs at least one world");
        let (cost, cluster) = worlds[0];
        let mixed = worlds.len() > 1;
        let fleet = if mixed {
            VirtualCluster::mixed(worlds.iter().map(|&(_, cl)| cl.clone()).collect())
        } else {
            VirtualCluster::homogeneous(cluster.clone())
        };
        let avail = FleetAvailability::full(&fleet);
        let mut mgr = if mixed {
            ShardManager::new_fleet(worlds.clone(), TaskSet::default(), opts.planner.clone())
        } else {
            ShardManager::new(
                cost,
                cluster,
                TaskSet::default(),
                opts.planner.clone(),
                opts.shards,
            )
        };
        mgr.set_restart_seconds(opts.restart_seconds_per_replica);
        let service = (opts.planner_threads > 0).then(|| {
            if mixed {
                PlannerService::spawn_fleet(
                    worlds.iter().map(|&(c, cl)| (c.clone(), cl.clone())).collect(),
                    opts.planner.clone(),
                    opts.meter,
                    opts.slice_plans,
                    opts.planner_threads,
                )
            } else {
                PlannerService::spawn_sharded(
                    cost.clone(),
                    cluster.clone(),
                    opts.planner.clone(),
                    opts.meter,
                    opts.slice_plans,
                    opts.planner_threads,
                    opts.shards,
                )
            }
        });
        Self {
            cost,
            cluster,
            worlds,
            fleet,
            avail,
            mgr,
            train: Vec::new(),
            opts,
            now: 0.0,
            window: None,
            epoch: 0,
            tenants: Vec::new(),
            report: ServeReport::default(),
            service,
            submitted_epochs: BTreeMap::new(),
            awaiting: BTreeSet::new(),
            steps_since_rebalance: 0,
            degraded_since: None,
            last_step_time: 0.0,
        }
    }

    /// The fleet manager (composed plan, per-shard sessions and counters).
    pub fn manager(&self) -> &ShardManager<'a> {
        &self.mgr
    }

    /// Current sim time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Replay a churn trace to completion and report tenant-observed
    /// metrics. Events are delivered in timestamp order at step
    /// granularity; each delivery opens (or re-targets) a replan window
    /// that is pumped between training steps until it completes or its
    /// budget runs out, and the plan swaps at the next step boundary.
    pub fn run_trace(&mut self, trace: &[TraceEvent]) -> ServeReport {
        let mut events: Vec<TraceEvent> = trace.to_vec();
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        let mut idx = 0usize;
        // hard iteration guard: the loop always either advances the sim
        // clock, consumes an event, or closes a window — this bound only
        // trips on a logic bug, keeping CI from hanging
        let mut guard = 0u64;
        let max_ticks = 10_000_000u64;
        loop {
            guard += 1;
            if guard > max_ticks {
                debug_assert!(false, "serve runtime exceeded its tick guard");
                break;
            }
            // 1. deliver every event that is due
            while idx < events.len() && events[idx].at <= self.now {
                self.deliver(&events[idx]);
                idx += 1;
            }
            // 2. an open replan window: overlap one step with one slice
            if self.window.is_some() {
                self.replan_tick();
                continue;
            }
            // 2b. between windows: periodic capacity rebalance across the
            // planning shards (drains the admission queue into any freed
            // slice; shards whose budget changed reopen their replans)
            if self.opts.rebalance_every > 0
                && self.steps_since_rebalance >= self.opts.rebalance_every
            {
                self.steps_since_rebalance = 0;
                let opened = self.mgr.rebalance();
                if !opened.is_empty() {
                    self.open_replan_window(&opened);
                    continue;
                }
            }
            // 3. steady state: train toward the next event, or finish
            if idx < events.len() {
                let next_at = events[idx].at;
                if !self.train.is_empty() {
                    if !self.train_step(false) {
                        // deployment cannot serve its batch — skip ahead
                        self.now = next_at;
                    }
                } else {
                    // idle serving process: jump to the next arrival
                    self.now = next_at;
                }
                continue;
            }
            break;
        }
        // tail: let tenants admitted by the last swap register progress
        for _ in 0..self.opts.tail_steps {
            if self.train.is_empty() || !self.train_step(false) {
                break;
            }
        }
        self.report.sim_seconds = self.now;
        self.report.queued_admissions = self.mgr.queued_admissions;
        self.report.preemptions = self.mgr.preemptions;
        self.report.rebalances = self.mgr.rebalances;
        self.report.tenants = self.tenants.clone();
        self.report.clone()
    }

    /// Deliver one trace event: update tenant records, apply it to the
    /// fleet manager, and open / re-target the replan window. Cluster
    /// events resolve against the fleet geometry into planner capacity
    /// instead of going through the task managers.
    fn deliver(&mut self, ev: &TraceEvent) {
        if ev.event.is_cluster() {
            self.deliver_cluster(ev);
            return;
        }
        let (name, tier) = match &ev.event {
            Event::Arrive(spec) => (spec.name.clone(), spec.meta.tier),
            Event::Exit { name } => (name.clone(), 0),
            _ => return,
        };
        let arriving = matches!(&ev.event, Event::Arrive(_));
        match self.mgr.apply_event(ev.event.clone()) {
            Outcome::Rejected => {
                self.report.rejected_arrivals += 1;
            }
            Outcome::Unchanged => {
                // a queued tenant withdrawing is Unchanged but has a
                // record; an unknown exit has none and this is a no-op
                if !arriving {
                    if let Some(t) = self
                        .tenants
                        .iter_mut()
                        .rev()
                        .find(|t| t.name == name && t.exited_at.is_none())
                    {
                        t.exited_at = Some(ev.at);
                    }
                }
            }
            Outcome::Queued => {
                // held for capacity, not rejected: time-to-admission is
                // measured from the *request*, so the record opens now and
                // admission happens at a later queue drain
                self.tenants.push(TenantRecord {
                    name,
                    tier,
                    arrived_at: ev.at,
                    admitted_at: None,
                    exited_at: None,
                    steps_trained: 0,
                    gpu_seconds: 0.0,
                });
            }
            Outcome::Drained => {
                // no tasks left: the deployment tears down immediately,
                // and any in-flight service search has no successor target
                if let Some(svc) = &mut self.service {
                    svc.cancel_current();
                }
                self.window = None;
                self.awaiting.clear();
                self.submitted_epochs.clear();
                self.train.clear();
                if let Some(t) = self
                    .tenants
                    .iter_mut()
                    .rev()
                    .find(|t| t.name == name && t.exited_at.is_none())
                {
                    t.exited_at = Some(ev.at);
                }
            }
            Outcome::Planning { opened } => {
                if arriving {
                    self.tenants.push(TenantRecord {
                        name,
                        tier,
                        arrived_at: ev.at,
                        admitted_at: None,
                        exited_at: None,
                        steps_trained: 0,
                        gpu_seconds: 0.0,
                    });
                } else if let Some(t) = self
                    .tenants
                    .iter_mut()
                    .rev()
                    .find(|t| t.name == name && t.exited_at.is_none())
                {
                    t.exited_at = Some(ev.at);
                }
                self.open_replan_window(&opened);
            }
        }
    }

    /// Deliver one cluster event: update the availability ledger, charge
    /// interrupted-step losses for reclaimed GPUs, fold the surviving
    /// capacity into the planners' GPU budgets
    /// ([`ShardManager::apply_capacity`]) and open / re-target the replan
    /// window for the shards whose budget changed. Training keeps stepping
    /// under the stale plan on the survivors until the shrink (or grow)
    /// plan is adopted at a step boundary — the same overlap model as
    /// tenant churn.
    fn deliver_cluster(&mut self, ev: &TraceEvent) {
        let resolved = match &ev.event {
            Event::NodeJoin { server } => {
                self.report.join_events += 1;
                self.avail.node_join(&self.fleet, *server)
            }
            Event::NodeLeave { server } => {
                self.report.leave_events += 1;
                self.avail.node_leave(&self.fleet, *server)
            }
            Event::Preempt { gpu_range } => {
                self.report.preempt_events += 1;
                self.avail.preempt(&self.fleet, *gpu_range)
            }
            _ => return,
        };
        // `parse_trace_for` rejects geometry violations up front; a
        // violation surviving to delivery (hand-built trace) is dropped
        // rather than corrupting the ledger
        let Ok(gpus_changed) = resolved else {
            return;
        };
        let lost = matches!(
            &ev.event,
            Event::NodeLeave { .. } | Event::Preempt { .. }
        );
        if lost {
            // the reclaimed GPUs were partway through the in-flight step:
            // that work is forfeit (checkpoints land at step boundaries).
            // Exposure is bounded by one step — the event lands mid-step
            // and the survivors checkpoint at its boundary.
            let exposure = (self.now - ev.at).clamp(0.0, self.last_step_time);
            self.report.gpu_seconds_lost_preempt += gpus_changed as f64 * exposure;
            if self.degraded_since.is_none() {
                self.degraded_since = Some(ev.at);
            }
        }
        let caps = self.avail.available();
        let opened = self.mgr.apply_capacity(&caps);
        if !opened.is_empty() {
            self.open_replan_window(&opened);
        } else if self.avail.is_full() && !self.mgr.replan_pending() {
            // capacity restored with nothing to replan (no live tasks):
            // the episode still closes
            if let Some(since) = self.degraded_since.take() {
                self.report.recoveries.push(self.now - since);
            }
        }
    }

    /// Open (or re-target) the replan window and, on the async path,
    /// submit each opened shard's search to the planner service. A
    /// superseding event KEEPS the open window's remaining budget —
    /// resetting it would let sustained churn defer every swap
    /// indefinitely; carrying it bounds the oldest waiting tenant's
    /// admission by one budget, after which the best-so-far plan deploys.
    fn open_replan_window(&mut self, opened: &[usize]) {
        let fresh = self.window.is_none();
        let (steps_so_far, budget_left) = match self.window.take() {
            Some(w) => (w.steps_in_window, w.budget_left),
            None => (0, self.opts.replan_budget),
        };
        self.report.replan_windows += 1;
        self.window = Some(ReplanWindow {
            budget_left,
            steps_in_window: steps_so_far,
            had_deployment: !self.train.is_empty(),
        });
        // async: hand each opened shard's search to the service —
        // submit_shard cancels only that shard's superseded token, so a
        // localized event never discards another shard's progress. An
        // empty `opened` (drained-shard recompose) leaves nothing to
        // await; the async tick finishes the window synchronously.
        if let Some(svc) = &mut self.service {
            for &s in opened {
                let e = svc.submit_shard(
                    s,
                    self.mgr.shard_tasks(s).clone(),
                    self.opts.replan_budget,
                    fresh,
                    self.mgr.gpu_budget(s),
                );
                self.submitted_epochs.insert(s, e);
                self.awaiting.insert(s);
            }
        }
    }

    /// One tick of an open replan window. Sync: a training step under the
    /// current plan (the overlap), then one budget-metered search slice;
    /// when the search completes or the budget runs out, swap at this
    /// step boundary. Async: a training step, then a wait-free poll of the
    /// service's publication cell.
    fn replan_tick(&mut self) {
        if self.service.is_some() {
            self.replan_tick_async();
        } else {
            self.replan_tick_sync();
        }
    }

    fn replan_tick_sync(&mut self) {
        let stepped = self.train_step(true);
        let t0 = Stopwatch::start();
        let slice = self.mgr.pump_replan(self.opts.slice_plans);
        let wall = t0.elapsed_secs();
        let (done, enumerated) = match slice {
            Some(s) => {
                self.report.replan_slices_total += 1;
                (s.done, s.n_enumerated)
            }
            // no search to pump (infeasible context): adopt immediately
            None => (true, 0),
        };
        self.report.plans_enumerated_total += enumerated as u64;
        let charge = self.opts.meter.charge(wall, enumerated);
        self.report.search_seconds_total += charge;
        if !stepped {
            // nothing overlapped the search: its cost is exposed on the
            // serving clock (cold starts pay for planning, live tenants
            // hide it under training)
            self.now += charge;
            self.report.search_seconds_unoverlapped += charge;
        }
        let exhausted = match self.window.as_mut() {
            // replan_tick is only entered with an open window; if it is
            // somehow gone, close out rather than spinning
            None => true,
            Some(w) => match &mut w.budget_left {
                None => false,
                Some(left) => {
                    *left -= charge;
                    *left <= 0.0
                }
            },
        };
        if done || exhausted {
            if exhausted && !done {
                self.report.budget_exhausted += 1;
            }
            self.swap(done);
        }
    }

    /// Async window tick: the searches run on the service thread, so the
    /// loop just trains and polls each awaited shard. A published update
    /// is adopted only when its epoch matches that shard's request — a
    /// stale final (from a superseded search that published before its
    /// cancellation landed) is ignored, and the epoch cell has already
    /// refused to let it overwrite a newer one. Each shard's plan is
    /// adopted as it lands (the composed plan shrinks/grows per shard);
    /// the window closes when the last awaited shard publishes.
    fn replan_tick_async(&mut self) {
        let stepped = self.train_step(true);
        if self.awaiting.is_empty() {
            // nothing in flight to wait for (a drained shard's
            // recompose-only window): finish synchronously
            let tasks_for_certify = self.mgr.fleet_tasks();
            let outcome = self.mgr.finish_replan();
            self.close_window();
            self.adopt_outcome(outcome, true, &tasks_for_certify);
            return;
        }
        let ready: Vec<_> = self
            .awaiting
            .iter()
            .filter_map(|&s| {
                let submitted = *self.submitted_epochs.get(&s)?;
                self.service
                    .as_ref()
                    .and_then(|svc| svc.poll_shard(s))
                    .map(|(_, u)| (s, u))
                    .filter(|(_, u)| u.epoch == submitted)
            })
            .collect();
        let adopted = !ready.is_empty();
        for (s, u) in ready {
            self.report.search_seconds_total += u.search_seconds;
            self.report.replan_slices_total += u.slices as u64;
            self.report.plans_enumerated_total += u.n_enumerated as u64;
            if u.exhausted {
                self.report.budget_exhausted += 1;
            }
            let tasks_for_certify = self.mgr.fleet_tasks();
            let outcome = self.mgr.finish_shard_with(s, u.plan.clone());
            self.awaiting.remove(&s);
            self.adopt_outcome(outcome, u.done, &tasks_for_certify);
        }
        if adopted {
            if self.awaiting.is_empty() {
                self.close_window();
            }
            return;
        }
        if !stepped {
            // Cold start: nothing to overlap, so the residual wait for the
            // service is what's exposed on the serving clock — the search
            // itself is off-thread. This (and the service's slice walls)
            // is why async serving is wall-timing-dependent; the sync path
            // stays the deterministic sim double.
            let t0 = Stopwatch::start();
            std::thread::sleep(std::time::Duration::from_millis(1));
            let waited = t0.elapsed_secs();
            self.now += waited;
            self.report.search_seconds_unoverlapped += waited;
        }
    }

    /// Adopt the replan at a step boundary and redeploy the training loop,
    /// charging checkpoint+restart only for changed replica groups.
    fn swap(&mut self, completed: bool) {
        let tasks_for_certify = self.mgr.fleet_tasks();
        let outcome = self.mgr.finish_replan();
        self.close_window();
        self.adopt_outcome(outcome, completed, &tasks_for_certify);
    }

    /// Close the replan window, recording its overlap proof, and reset the
    /// async awaited-shard state.
    fn close_window(&mut self) {
        if let Some(w) = self.window.take() {
            if w.had_deployment {
                self.report.min_steps_in_replan_window = Some(
                    self.report
                        .min_steps_in_replan_window
                        .map_or(w.steps_in_window, |m| m.min(w.steps_in_window)),
                );
            }
        }
        self.awaiting.clear();
        self.submitted_epochs.clear();
    }

    /// Shared adoption tail of the sync swap and the async poll: account
    /// the outcome, certify completed searches against a cold plan, and
    /// redeploy training.
    fn adopt_outcome(
        &mut self,
        outcome: Outcome,
        completed: bool,
        tasks_for_certify: &TaskSet,
    ) {
        match outcome {
            Outcome::Unchanged => {
                self.report.plan_swaps_identical += 1;
            }
            Outcome::Redeployed { adjustment_seconds, adjustment } => {
                self.report.redeploys += 1;
                self.report.gpu_seconds_lost_redeploy +=
                    adjustment.gpu_seconds(self.opts.restart_seconds_per_replica);
                // checkpoint+restore serializes through the coordinator;
                // training is stalled for the adjustment
                self.now += adjustment_seconds;
            }
            _ => {}
        }
        // an adoption with the fleet back at full capacity closes the
        // degraded episode: record its time-to-recover
        if self.avail.is_full() && !self.mgr.replan_pending() {
            if let Some(since) = self.degraded_since.take() {
                self.report.recoveries.push(self.now - since);
            }
        }
        // certify anytime identity on completed searches, before the new
        // loop starts ticking. Only the global (single-shard, uncapped,
        // single-world, full-capacity) path is cold-comparable: a
        // capacity-sliced or budget-clamped search answers a different
        // (smaller) question than `Planner::plan`. After a full capacity
        // restore the budgets are cleared, so this gate re-arms — that is
        // the recovery-identity certificate.
        if completed
            && self.opts.certify_identity
            && self.opts.shards <= 1
            && self.worlds.len() <= 1
            && self.opts.planner.gpu_budget.is_none()
            && self.avail.is_full()
        {
            if let Some(deployed) = self.mgr.plan() {
                self.report.identity_checks += 1;
                let cold = Planner::new(self.cost, self.cluster)
                    .plan(tasks_for_certify, self.opts.planner.clone());
                let identical = cold.as_ref().is_some_and(|c| {
                    c.groups == deployed.groups
                        && c.expected_step_time.to_bits()
                            == deployed.expected_step_time.to_bits()
                });
                if !identical {
                    self.report.identity_failures += 1;
                }
            }
        }
        self.redeploy_training();
    }

    /// Rebuild the training loops for the (possibly new) plans and task
    /// sets and admit newly deployed tenants. A homogeneous fleet drives
    /// one loop with the composed plan (the pre-fleet behavior, bit for
    /// bit); a mixed fleet drives one loop per pool with a live plan.
    fn redeploy_training(&mut self) {
        self.epoch += 1;
        let mut old = std::mem::take(&mut self.train);
        let mixed = self.worlds.len() > 1;
        let pools: Vec<usize> =
            if mixed { (0..self.mgr.n_shards()).collect() } else { vec![0] };
        for p in pools {
            let planned = if mixed {
                self.mgr.shard_plan(p).cloned().map(|pl| {
                    (pl, self.mgr.shard_tasks(p).clone())
                })
            } else {
                self.mgr.plan().cloned().map(|pl| (pl, self.mgr.fleet_tasks()))
            };
            let Some((plan, tasks)) = planned else {
                continue;
            };
            let mut tenants = Vec::with_capacity(tasks.tasks.len());
            for spec in &tasks.tasks {
                if let Some(i) = self
                    .tenants
                    .iter()
                    .rposition(|t| t.name == spec.name && t.exited_at.is_none())
                {
                    if self.tenants[i].admitted_at.is_none() {
                        self.tenants[i].admitted_at = Some(self.now);
                    }
                    tenants.push(i);
                } else {
                    // keep index parity with the task set even for
                    // tasks without a record (shouldn't happen)
                    tenants.push(usize::MAX);
                }
            }
            // pool 0 keeps the pre-fleet seed exactly; later pools fold
            // their index so concurrent pools sample independent streams
            let seed = self.opts.seed
                ^ self.epoch.wrapping_mul(0x9E37_79B9)
                ^ (p as u64).wrapping_mul(0x517c_c1b7_2722_0a95);
            match old.iter().position(|pl| pl.pool == p) {
                Some(i) => {
                    let mut pl = old.swap_remove(i);
                    pl.tl.swap(plan, tasks, seed);
                    pl.tenants = tenants;
                    self.train.push(pl);
                }
                None => {
                    self.train.push(PoolLoop {
                        pool: p,
                        tl: SimTrainLoop::new(
                            self.worlds[p].0,
                            plan,
                            tasks,
                            seed,
                            self.mgr.tables(),
                        ),
                        tenants,
                    });
                }
            }
        }
        // pools whose plan drained fall out of `old` and stop stepping
    }

    /// Execute one *fleet* training step under the current deployment,
    /// advancing the sim clock and tenant progress. Every pool with a live
    /// plan steps concurrently; the fleet step is the slowest pool's (LoRA
    /// gradients synchronize at the fleet step boundary) and GPU-seconds
    /// are each pool's own compute. Returns false when no pool stepped.
    fn train_step(&mut self, in_window: bool) -> bool {
        let mut fleet_step = 0.0f64;
        let mut gpu_seconds = 0.0f64;
        let mut stepped = false;
        let mut shares: Vec<(usize, f64)> = Vec::new();
        for pl in &mut self.train {
            let Some(step) = pl.tl.step() else {
                continue;
            };
            stepped = true;
            fleet_step = fleet_step.max(step.step_time);
            gpu_seconds += step.gpu_seconds;
            let deployed = pl.tenants.iter().filter(|&&ti| ti != usize::MAX).count();
            let share =
                if deployed > 0 { step.gpu_seconds / deployed as f64 } else { 0.0 };
            for &ti in &pl.tenants {
                if ti != usize::MAX {
                    shares.push((ti, share));
                }
            }
        }
        if !stepped {
            return false;
        }
        self.now += fleet_step;
        self.last_step_time = fleet_step;
        self.report.steps_total += 1;
        self.report.gpu_seconds_trained += gpu_seconds;
        self.steps_since_rebalance += 1;
        if in_window {
            self.report.steps_during_replan += 1;
            if let Some(w) = &mut self.window {
                w.steps_in_window += 1;
            }
        }
        for (ti, share) in shares {
            self.tenants[ti].steps_trained += 1;
            self.tenants[ti].gpu_seconds += share;
        }
        true
    }
}

/// A ready-made churn trace over a task pool: arrivals staggered
/// `spacing` seconds apart, then the two oldest tenants exit and the first
/// returns — exercising admission, partial redeploys and a re-arrival. The
/// default scenario behind `lobra serve` (without `--trace`) and the churn
/// bench.
pub fn default_churn_trace(pool: &TaskSet, spacing: f64) -> Vec<TraceEvent> {
    let mut trace = Vec::new();
    for (i, t) in pool.tasks.iter().enumerate() {
        trace.push(TraceEvent {
            at: i as f64 * spacing,
            event: Event::Arrive(t.clone()),
        });
    }
    let n = pool.tasks.len();
    if n >= 2 {
        trace.push(TraceEvent {
            at: n as f64 * spacing,
            event: Event::Exit { name: pool.tasks[0].name.clone() },
        });
        trace.push(TraceEvent {
            at: (n + 1) as f64 * spacing,
            event: Event::Exit { name: pool.tasks[1].name.clone() },
        });
        trace.push(TraceEvent {
            at: (n + 2) as f64 * spacing,
            event: Event::Arrive(pool.tasks[0].clone()),
        });
    }
    trace
}

/// Generate a seeded, deterministic fleet churn trace: `tenants` arrivals
/// drawn from four workload archetypes (QA / chat / code / summarization
/// length profiles), round-robin priority tiers, staggered arrival times
/// with jitter, and roughly a quarter of tenants exiting after a dwell —
/// exercising admission, queueing, preemption and shard rebalancing at
/// fleet scale. Sorted by timestamp and reproducible from
/// `(tenants, seed)`; the fleet-scaling bench and the shard tests share
/// it.
pub fn gen_churn_trace(tenants: usize, seed: u64) -> Vec<TraceEvent> {
    use crate::data::LengthDistribution;
    use crate::util::Rng;
    // (archetype, batch, mean, skew, min, max)
    const ARCHETYPES: [(&str, u32, f64, f64, u32, u32); 4] = [
        ("qa", 24, 210.0, 6.0, 16, 2048),
        ("chat", 16, 420.0, 4.0, 16, 4096),
        ("code", 12, 700.0, 6.5, 16, 8192),
        ("sum", 8, 3600.0, 4.3, 16, 16384),
    ];
    let mut rng = Rng::new(seed ^ 0x5eed_7ace);
    let spacing = 240.0;
    let mut out = Vec::new();
    for i in 0..tenants {
        let (arch, batch, mean, skew, min, max) = ARCHETYPES[i % ARCHETYPES.len()];
        let tier = (i % 4) as u8;
        let name = format!("t{i:04}-{arch}");
        let at = i as f64 * spacing + rng.f64() * spacing * 0.5;
        // vary the batch so identically shaped tenants still differ
        let batch = batch + 4 * rng.below(3) as u32;
        let spec = TaskSpec::new(&name, batch, LengthDistribution::fit(mean, skew, min, max))
            .with_tier(tier);
        out.push(TraceEvent { at, event: Event::Arrive(spec) });
        if rng.below(4) == 0 {
            // ~25% exit after a dwell, freeing capacity for later arrivals
            let dwell = spacing * (4.0 + rng.f64() * 8.0);
            out.push(TraceEvent { at: at + dwell, event: Event::Exit { name } });
        }
    }
    out.sort_by(|a, b| a.at.total_cmp(&b.at));
    out
}

/// [`gen_churn_trace`] plus seeded **cluster-event injection**: on top of
/// the identical tenant skeleton (same `(tenants, seed)` → same tenant
/// lines, bit for bit), each arrival slot rolls a server `leave` with
/// probability `leave_rate` and a half-server GPU-range `preempt` with
/// probability `preempt_rate` against `fleet`'s geometry. Every loss
/// schedules the server's `join` after a dwell, and any capacity still
/// down at the end of the trace is restored — the trace always ends at
/// full fleet capacity, so recovery-identity checks have a terminal
/// full-capacity adoption to certify. Generation tracks a
/// [`FleetAvailability`] ledger, so the emitted events always pass
/// [`parse_trace_for`]-style geometry validation.
pub fn gen_churn_trace_elastic(
    tenants: usize,
    seed: u64,
    fleet: &VirtualCluster,
    leave_rate: f64,
    preempt_rate: f64,
) -> Vec<TraceEvent> {
    use crate::util::Rng;
    let mut out = gen_churn_trace(tenants, seed);
    let spacing = 240.0;
    // an independent stream: injecting cluster churn must not perturb the
    // tenant lines (the same-skeleton guarantee above)
    let mut rng = Rng::new(seed ^ 0xc1a5_7e2e_5eed_0001);
    let mut avail = FleetAvailability::full(fleet);
    // (restore time, server) — applied to the ledger in time order, which
    // the slot-sequential walk below guarantees
    let mut pending: Vec<(f64, u32)> = Vec::new();
    let mut last_at = 0.0f64;
    for i in 0..tenants {
        let at = i as f64 * spacing + spacing * 0.61;
        last_at = at;
        // restores due before this slot fire first
        pending.sort_by(|a, b| a.0.total_cmp(&b.0));
        while let Some(&(t, server)) = pending.first() {
            if t > at {
                break;
            }
            pending.remove(0);
            if avail.node_join(fleet, server).is_ok() {
                out.push(TraceEvent { at: t, event: Event::NodeJoin { server } });
            }
        }
        // a whole server departs: pick among fully-up servers
        if rng.f64() < leave_rate {
            let candidates: Vec<u32> = (0..fleet.n_servers())
                .filter(|&s| {
                    let mut probe = avail.clone();
                    probe.node_leave(fleet, s).is_ok()
                })
                .collect();
            if !candidates.is_empty() {
                let s = candidates[rng.below(candidates.len() as u64) as usize];
                if avail.node_leave(fleet, s).is_ok() {
                    out.push(TraceEvent {
                        at,
                        event: Event::NodeLeave { server: s },
                    });
                    let dwell = spacing * (2.0 + rng.f64() * 4.0);
                    pending.push((at + dwell, s));
                }
            }
        }
        // half of one server's GPUs get reclaimed
        if rng.f64() < preempt_rate {
            let candidates: Vec<(u32, (u32, u32))> = (0..fleet.n_servers())
                .filter_map(|s| {
                    let (a, b) = fleet.server_gpu_span(s)?;
                    let mid = a + (b - a).div_ceil(2);
                    let mut probe = avail.clone();
                    probe.preempt(fleet, (a, mid)).ok()?;
                    Some((s, (a, mid)))
                })
                .collect();
            if !candidates.is_empty() {
                let (s, range) =
                    candidates[rng.below(candidates.len() as u64) as usize];
                if avail.preempt(fleet, range).is_ok() {
                    out.push(TraceEvent {
                        at: at + spacing * 0.13,
                        event: Event::Preempt { gpu_range: range },
                    });
                    let dwell = spacing * (2.0 + rng.f64() * 4.0);
                    pending.push((at + spacing * 0.13 + dwell, s));
                }
            }
        }
    }
    // restore everything still down, in order, after the last slot
    pending.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut t = last_at + spacing;
    for (due, server) in pending {
        t = t.max(due) + spacing * 0.29;
        if avail.node_join(fleet, server).is_ok() {
            out.push(TraceEvent { at: t, event: Event::NodeJoin { server } });
        }
    }
    out.sort_by(|a, b| a.at.total_cmp(&b.at));
    out
}

/// Convenience: build a runtime, replay `trace`, return the report.
pub fn serve_trace(
    cost: &CostModel,
    cluster: &ClusterSpec,
    trace: &[TraceEvent],
    opts: ServeOptions,
) -> ServeReport {
    ServeRuntime::new(cost, cluster, opts).run_trace(trace)
}

/// Parse a churn-trace file — **trace grammar v2** (whitespace-separated,
/// `#` comments). Tenant lines are unchanged from v1, bit for bit; cluster
/// lines are new:
///
/// ```text
/// # at    op       name/args                                   meaning
/// 0       arrive   qa-short  128  210.0  6.0  16  2048  [1]  # tenant joins ([tier] optional, 0 = highest)
/// 1800    exit     qa-short                                  # tenant leaves
/// 2000    leave    3                                         # server 3 departs (all its GPUs down)
/// 2600    preempt  8 12                                      # GPUs [8, 12) reclaimed
/// 3300    join     3                                         # server 3 returns (its down GPUs restore)
/// ```
///
/// This structural parse validates shapes and numbers only; it cannot
/// check cluster events against a fleet it does not know. Use
/// [`parse_trace_for`] to additionally reject geometry violations
/// (unknown server, overlapping preempt range, join of an up server).
pub fn parse_trace(text: &str) -> Result<Vec<TraceEvent>, String> {
    parse_trace_impl(text, None)
}

/// [`parse_trace`], then validate cluster events against `fleet` in
/// delivery order (timestamp, then line order): a `leave` must name a
/// known, up server; a `preempt` range must lie inside the fleet and
/// overlap nothing already down; a `join` must restore something. The
/// runtime drops invalid cluster events at delivery — this rejects them
/// up front with the offending line, like the tenant-line checks.
pub fn parse_trace_for(
    text: &str,
    fleet: &VirtualCluster,
) -> Result<Vec<TraceEvent>, String> {
    parse_trace_impl(text, Some(fleet))
}

fn parse_trace_impl(
    text: &str,
    fleet: Option<&VirtualCluster>,
) -> Result<Vec<TraceEvent>, String> {
    use crate::data::LengthDistribution;
    // (line number, cleaned line) per event, for geometry errors below
    let mut lines: Vec<(usize, String)> = Vec::new();
    let mut out = Vec::new();
    // live-in-file-order tenant names: a second arrive for a live name is
    // almost always a typo'd exit — running it would double the tenant
    let mut live: BTreeSet<String> = BTreeSet::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let err = |what: &str| format!("trace line {}: {what}: {line}", ln + 1);
        if fields.len() < 3 {
            return Err(err("expected at least `at op name`"));
        }
        // reject non-finite timestamps ("nan"/"inf" parse as f64!) — a NaN
        // event time would never satisfy `at <= now` and wedge the loop
        let at: f64 = fields[0]
            .parse()
            .ok()
            .filter(|t: &f64| t.is_finite())
            .ok_or_else(|| err("bad timestamp"))?;
        if at < 0.0 {
            // the sim clock starts at 0: a negative event time would be
            // silently delivered at startup, reordering the trace
            return Err(err("negative timestamp"));
        }
        let name = fields[2].to_string();
        let event = match fields[1] {
            "exit" => {
                if fields.len() != 3 {
                    // stray columns usually mean an arrive-shaped line
                    // with the wrong op — fail loudly, don't run a
                    // materially different scenario
                    return Err(err("exit takes exactly `at exit name`"));
                }
                live.remove(&name);
                Event::Exit { name }
            }
            "arrive" => {
                if fields.len() != 8 && fields.len() != 9 {
                    return Err(err(
                        "arrive needs `at arrive name batch mean skew min max [tier]`",
                    ));
                }
                let batch: u32 = fields[3].parse().map_err(|_| err("bad batch"))?;
                let mean: f64 = fields[4].parse().map_err(|_| err("bad mean"))?;
                let skew: f64 = fields[5].parse().map_err(|_| err("bad skew"))?;
                let min: u32 = fields[6].parse().map_err(|_| err("bad min len"))?;
                let max: u32 = fields[7].parse().map_err(|_| err("bad max len"))?;
                let tier: u8 = match fields.get(8) {
                    Some(f) => f.parse().map_err(|_| err("bad tier"))?,
                    None => 0,
                };
                if !live.insert(name.clone()) {
                    return Err(err("duplicate arrive for live tenant"));
                }
                Event::Arrive(
                    TaskSpec::new(
                        &name,
                        batch,
                        LengthDistribution::fit(mean, skew, min, max),
                    )
                    .with_tier(tier),
                )
            }
            "leave" | "join" => {
                if fields.len() != 3 {
                    return Err(err(&format!(
                        "{} takes exactly `at {} server`",
                        fields[1], fields[1]
                    )));
                }
                let server: u32 =
                    fields[2].parse().map_err(|_| err("bad server id"))?;
                if fields[1] == "leave" {
                    Event::NodeLeave { server }
                } else {
                    Event::NodeJoin { server }
                }
            }
            "preempt" => {
                if fields.len() != 4 {
                    return Err(err("preempt takes exactly `at preempt start end`"));
                }
                let start: u32 =
                    fields[2].parse().map_err(|_| err("bad range start"))?;
                let end: u32 = fields[3].parse().map_err(|_| err("bad range end"))?;
                if start >= end {
                    return Err(err("empty preempt range"));
                }
                Event::Preempt { gpu_range: (start, end) }
            }
            other => return Err(err(&format!("unknown op `{other}`"))),
        };
        lines.push((ln, line.to_string()));
        out.push(TraceEvent { at, event });
    }
    if let Some(fleet) = fleet {
        // replay the cluster events against the fleet in delivery order —
        // stable sort by timestamp, line order breaking ties, exactly like
        // the runtime's own event ordering
        let mut order: Vec<usize> = (0..out.len()).collect();
        order.sort_by(|&a, &b| out[a].at.total_cmp(&out[b].at));
        let mut avail = FleetAvailability::full(fleet);
        for i in order {
            let resolved = match &out[i].event {
                Event::NodeJoin { server } => avail.node_join(fleet, *server),
                Event::NodeLeave { server } => avail.node_leave(fleet, *server),
                Event::Preempt { gpu_range } => avail.preempt(fleet, *gpu_range),
                _ => Ok(0),
            };
            if let Err(what) = resolved {
                let (ln, line) = &lines[i];
                return Err(format!("trace line {}: {what}: {line}", ln + 1));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;
    use crate::data::LengthDistribution;

    fn world() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    fn fast_opts() -> ServeOptions {
        let mut planner = PlannerOptions::default();
        planner.calibration_multiple = 20;
        planner.eval_batches = 1;
        planner.max_evaluated = 200;
        ServeOptions {
            replan_budget: None,
            slice_plans: 16,
            meter: BudgetMeter::SimPerPlan(1e-3),
            planner,
            seed: 7,
            restart_seconds_per_replica: 15.0,
            certify_identity: true,
            tail_steps: 3,
            ..ServeOptions::default()
        }
    }

    fn pool() -> TaskSet {
        TaskSet::new(vec![
            TaskSpec::new("qa", 128, LengthDistribution::fit(210.0, 6.0, 16, 2048)),
            TaskSpec::new("code", 64, LengthDistribution::fit(700.0, 6.5, 16, 8192)),
            TaskSpec::new("sum", 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384)),
        ])
    }

    #[test]
    fn serve_overlaps_training_with_replanning() {
        let (cost, cluster) = world();
        let trace = default_churn_trace(&pool(), 400.0);
        let report = serve_trace(&cost, &cluster, &trace, fast_opts());
        // every tenant was admitted, with sane time-to-admission
        assert_eq!(report.tenants.len(), 4, "{:#?}", report.tenants);
        for t in &report.tenants {
            assert!(t.admitted_at.is_some(), "tenant {} never admitted", t.name);
            assert!(t.time_to_admission().unwrap() >= 0.0);
            assert!(t.steps_trained > 0, "tenant {} made no progress", t.name);
        }
        // the acceptance bar: windows with a live deployment never
        // stop the world — every one saw at least one training step
        assert!(report.replan_windows >= 5, "{report:#?}");
        let min_steps = report
            .min_steps_in_replan_window
            .expect("no replan window overlapped a live deployment");
        assert!(min_steps >= 1, "a replan window stalled training: {report:#?}");
        assert!(report.steps_during_replan >= 1);
        // unlimited budget: every completed replan certified cold-identical
        assert!(report.identity_checks > 0);
        assert_eq!(report.identity_failures, 0, "anytime != cold: {report:#?}");
        assert_eq!(report.budget_exhausted, 0);
        assert!(report.gpu_seconds_trained > 0.0);
        assert!(report.sim_seconds > 0.0);
    }

    #[test]
    fn async_service_serves_trace_and_certifies_identity() {
        let (cost, cluster) = world();
        let mut opts = fast_opts();
        // unlimited budget: every adoption is a completed search, and
        // certify_identity re-verifies each deployed plan against a cold
        // `Planner::plan` — async == sync == cold at the plan level, even
        // though admission timestamps are wall-timing-dependent here
        opts.planner_threads = 2;
        let trace = default_churn_trace(&pool(), 400.0);
        let report = serve_trace(&cost, &cluster, &trace, opts);
        assert_eq!(report.tenants.len(), 4, "{:#?}", report.tenants);
        for t in &report.tenants {
            assert!(t.admitted_at.is_some(), "tenant {} never admitted", t.name);
        }
        assert!(report.identity_checks > 0);
        assert_eq!(report.identity_failures, 0, "async != cold: {report:#?}");
        assert_eq!(report.budget_exhausted, 0);
        assert!(report.steps_total > 0);
    }

    #[test]
    fn exhausted_budget_deploys_best_so_far() {
        let (cost, cluster) = world();
        let mut opts = fast_opts();
        // a budget so small the very first slice exhausts it
        opts.replan_budget = Some(1e-9);
        opts.slice_plans = 4;
        opts.certify_identity = false;
        let trace = default_churn_trace(&pool(), 400.0);
        let report = serve_trace(&cost, &cluster, &trace, opts);
        assert!(report.budget_exhausted > 0, "{report:#?}");
        // best-so-far plans are still feasible: tenants admitted + trained
        for t in &report.tenants {
            assert!(t.admitted_at.is_some(), "tenant {} never admitted", t.name);
        }
        assert!(report.steps_total > 0);
    }

    #[test]
    fn unknown_exit_opens_no_replan_window() {
        let (cost, cluster) = world();
        let mut opts = fast_opts();
        opts.certify_identity = false;
        // two tenants with identical length profiles: admitting the second
        // then draining it back leaves the plan unchanged on the re-plan
        let a = TaskSpec::new("a", 64, LengthDistribution::fit(210.0, 6.0, 16, 2048));
        let trace = vec![
            TraceEvent { at: 0.0, event: Event::Arrive(a) },
            TraceEvent {
                at: 200.0,
                event: Event::Exit { name: "never-there".into() },
            },
        ];
        let report = serve_trace(&cost, &cluster, &trace, opts);
        // the unknown exit changed nothing: one window (the arrival), one
        // redeploy (the cold deploy), and only that deploy charged GPU loss
        assert_eq!(report.replan_windows, 1, "{report:#?}");
        assert_eq!(report.redeploys, 1, "only the initial deploy pays");
        assert!(report.gpu_seconds_lost_redeploy > 0.0);
        assert_eq!(report.plan_swaps_identical, 0);
    }

    #[test]
    fn drain_tears_down_and_rearrival_redeploys() {
        let (cost, cluster) = world();
        let mut opts = fast_opts();
        opts.certify_identity = false;
        let a = TaskSpec::new("solo", 64, LengthDistribution::fit(250.0, 3.0, 16, 2048));
        let trace = vec![
            TraceEvent { at: 0.0, event: Event::Arrive(a.clone()) },
            TraceEvent { at: 300.0, event: Event::Exit { name: "solo".into() } },
            TraceEvent { at: 600.0, event: Event::Arrive(a) },
        ];
        let report = serve_trace(&cost, &cluster, &trace, opts);
        // two tenant lifetimes for the same name
        assert_eq!(report.tenants.len(), 2);
        assert!(report.tenants[0].exited_at.is_some());
        assert!(report.tenants[1].exited_at.is_none());
        assert!(report.tenants[1].admitted_at.unwrap() >= 600.0);
        assert_eq!(report.redeploys, 2, "cold deploy + re-arrival deploy");
    }

    #[test]
    fn trace_parser_round_trips() {
        let text = "\
# at  op      name  batch mean  skew min max
0     arrive  qa    128   210.0 6.0  16  2048
120.5 arrive  sum   32    3600  4.3  16  16384   # inline comment
900   exit    qa
";
        let trace = parse_trace(text).unwrap();
        assert_eq!(trace.len(), 3);
        assert!(matches!(&trace[0].event, Event::Arrive(s) if s.name == "qa"));
        assert!((trace[1].at - 120.5).abs() < 1e-9);
        assert!(matches!(&trace[2].event, Event::Exit { name } if name == "qa"));
        assert!(parse_trace("0 arrive broken 1 2").is_err());
        assert!(parse_trace("x arrive a 1 2 3 4 5").is_err());
        assert!(parse_trace("nan arrive a 1 2 3 4 5").is_err(), "non-finite at");
        assert!(parse_trace("inf exit a").is_err());
        assert!(parse_trace("0 exit a 128 210.0 6.0 16 2048").is_err(), "stray columns");
        assert!(parse_trace("0 vanish a").is_err());
        assert!(parse_trace("# only comments\n\n").unwrap().is_empty());
    }

    #[test]
    fn trace_parser_tiers_and_guards() {
        let text = "\
0    arrive  qa   128  210.0  6.0  16  2048   3
100  exit    qa
200  arrive  qa   128  210.0  6.0  16  2048
";
        let trace = parse_trace(text).unwrap();
        assert_eq!(trace.len(), 3);
        assert!(
            matches!(&trace[0].event, Event::Arrive(s) if s.meta.tier == 3),
            "explicit tier column"
        );
        assert!(
            matches!(&trace[2].event, Event::Arrive(s) if s.meta.tier == 0),
            "tier defaults to 0 — and re-arrival after exit is legal"
        );
        assert!(parse_trace("-5 arrive a 1 2.0 3.0 4 5").is_err(), "negative at");
        assert!(
            parse_trace("0 arrive a 1 2.0 3.0 4 5 nine").is_err(),
            "non-numeric tier"
        );
        let dup = "\
0   arrive  a  1  2.0  3.0  4  5
50  arrive  a  1  2.0  3.0  4  5
";
        assert!(parse_trace(dup).is_err(), "duplicate live arrive");
    }

    #[test]
    fn gen_trace_is_deterministic_and_sorted() {
        let a = gen_churn_trace(40, 9);
        let b = gen_churn_trace(40, 9);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same (tenants, seed)");
        let c = gen_churn_trace(40, 10);
        assert_ne!(format!("{a:?}"), format!("{c:?}"), "seed changes the trace");
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "sorted by timestamp");
        }
        let arrivals: Vec<&TaskSpec> = a
            .iter()
            .filter_map(|e| match &e.event {
                Event::Arrive(s) => Some(s),
                _ => None,
            })
            .collect();
        assert_eq!(arrivals.len(), 40);
        // all four tiers and all four archetype length profiles appear
        for tier in 0u8..4 {
            assert!(arrivals.iter().any(|s| s.meta.tier == tier), "tier {tier}");
        }
        assert!(arrivals.iter().any(|s| s.lengths.max_len == 2048));
        assert!(arrivals.iter().any(|s| s.lengths.max_len == 16384));
        let exits = a.len() - arrivals.len();
        assert!(exits > 0 && exits < 40 / 2, "some but not most tenants exit");
    }

    #[test]
    fn sharded_serve_admits_and_reports_fairness() {
        let cluster = ClusterSpec::a100_40g(32);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let mut opts = fast_opts();
        opts.certify_identity = false;
        opts.shards = 2;
        opts.rebalance_every = 40;
        let trace = gen_churn_trace(6, 11);
        let report = serve_trace(&cost, &cluster, &trace, opts);
        let arrivals =
            trace.iter().filter(|e| matches!(e.event, Event::Arrive(_))).count();
        assert_eq!(
            report.tenants.len() + report.rejected_arrivals as usize,
            arrivals,
            "every arrival is recorded or rejected: {report:#?}"
        );
        assert!(report.steps_total > 0, "{report:#?}");
        assert!(
            report.tenants.iter().any(|t| t.admitted_at.is_some()),
            "{report:#?}"
        );
        let jain = report.jain_fairness().expect("someone trained");
        assert!(jain > 0.0 && jain <= 1.0 + 1e-12, "jain {jain}");
        // per-tier TTA covers only admitted tenants and is non-negative
        for (_, tta) in report.tta_by_tier() {
            assert!(tta >= 0.0);
        }
    }

    #[test]
    fn default_trace_shape() {
        let trace = default_churn_trace(&pool(), 100.0);
        assert_eq!(trace.len(), 3 + 3);
        assert!(matches!(&trace[3].event, Event::Exit { name } if name == "qa"));
        assert!(matches!(&trace[5].event, Event::Arrive(s) if s.name == "qa"));
        // timestamps are sorted
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn trace_parser_v2_cluster_lines() {
        let text = "\
# grammar v2: tenant lines + cluster lines interleave
0     arrive   qa  128  210.0  6.0  16  2048
500   leave    1                       # server 1 departs
900   preempt  0 4                     # GPUs [0, 4) reclaimed
1400  join     1
";
        let trace = parse_trace(text).unwrap();
        assert_eq!(trace.len(), 4);
        assert!(matches!(trace[1].event, Event::NodeLeave { server: 1 }));
        assert!(matches!(trace[2].event, Event::Preempt { gpu_range: (0, 4) }));
        assert!(matches!(trace[3].event, Event::NodeJoin { server: 1 }));
        // shape rejections, mirroring the tenant-line guard suite
        assert!(parse_trace("0 leave 1 2").is_err(), "leave takes one arg");
        assert!(parse_trace("0 join one").is_err(), "bad server id");
        assert!(parse_trace("0 leave -3").is_err(), "negative server id");
        assert!(parse_trace("0 preempt 4").is_err(), "preempt needs start+end");
        assert!(parse_trace("0 preempt 0 4 8").is_err(), "stray columns");
        assert!(parse_trace("0 preempt a 4").is_err(), "bad range start");
        assert!(parse_trace("0 preempt 0 b").is_err(), "bad range end");
        assert!(parse_trace("0 preempt 4 4").is_err(), "empty range");
        assert!(parse_trace("0 preempt 5 4").is_err(), "inverted range");
        assert!(parse_trace("nan leave 1").is_err(), "non-finite at");
    }

    #[test]
    fn trace_parser_v2_geometry_guards() {
        // two 8-GPU servers: servers {0, 1}, GPUs [0, 16)
        let fleet = VirtualCluster::homogeneous(ClusterSpec::a100_40g(16));
        let ok = "\
0     leave    1
200   preempt  0 4
600   join     1
900   join     0        # restores the preempted half of server 0
";
        assert_eq!(parse_trace_for(ok, &fleet).unwrap().len(), 4);
        // the same text passes the structural parse but fails geometry
        let unknown = "0 leave 2";
        assert!(parse_trace(unknown).is_ok());
        let e = parse_trace_for(unknown, &fleet).unwrap_err();
        assert!(e.contains("leave of unknown server"), "{e}");
        let double = "0 leave 1\n100 leave 1";
        let e = parse_trace_for(double, &fleet).unwrap_err();
        assert!(e.contains("already-down server"), "{e}");
        let overlap = "0 preempt 0 8\n100 preempt 4 12";
        let e = parse_trace_for(overlap, &fleet).unwrap_err();
        assert!(e.contains("overlaps already-down GPU"), "{e}");
        let oob = "0 preempt 12 20";
        let e = parse_trace_for(oob, &fleet).unwrap_err();
        assert!(e.contains("exceeds fleet"), "{e}");
        let up_join = "0 join 0";
        let e = parse_trace_for(up_join, &fleet).unwrap_err();
        assert!(e.contains("already-up server"), "{e}");
        // validation replays in delivery order (timestamp, not line order):
        // the join line appears first in the file but fires after the leave
        let reordered = "500 join 1\n0 leave 1";
        assert!(parse_trace_for(reordered, &fleet).is_ok());
    }

    #[test]
    fn gen_elastic_trace_is_deterministic_and_ends_full() {
        let fleet = VirtualCluster::homogeneous(ClusterSpec::a100_40g(32));
        let a = gen_churn_trace_elastic(20, 9, &fleet, 0.3, 0.3);
        let b = gen_churn_trace_elastic(20, 9, &fleet, 0.3, 0.3);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same inputs, same trace");
        // the tenant skeleton is gen_churn_trace, bit for bit
        let skeleton: Vec<&TraceEvent> =
            a.iter().filter(|e| !e.event.is_cluster()).collect();
        let plain = gen_churn_trace(20, 9);
        assert_eq!(format!("{skeleton:?}"), format!("{:?}", plain.iter().collect::<Vec<_>>()));
        // cluster events were injected and replay cleanly to full capacity
        let cluster: Vec<&TraceEvent> =
            a.iter().filter(|e| e.event.is_cluster()).collect();
        assert!(!cluster.is_empty(), "rates 0.3 must inject something");
        let mut avail = FleetAvailability::full(&fleet);
        for ev in &cluster {
            let ok = match &ev.event {
                Event::NodeJoin { server } => avail.node_join(&fleet, *server),
                Event::NodeLeave { server } => avail.node_leave(&fleet, *server),
                Event::Preempt { gpu_range } => avail.preempt(&fleet, *gpu_range),
                _ => unreachable!(),
            };
            assert!(ok.is_ok(), "ledger-invalid event {:?}: {ok:?}", ev.event);
        }
        assert!(avail.is_full(), "trace must end at full capacity");
        // rate 0 collapses to the plain trace exactly
        let none = gen_churn_trace_elastic(20, 9, &fleet, 0.0, 0.0);
        assert_eq!(format!("{none:?}"), format!("{plain:?}"));
    }

    #[test]
    fn elastic_serve_recovers_from_preempt_and_join() {
        let (cost, cluster) = world(); // 16 GPUs = servers {0, 1}
        let a = TaskSpec::new("qa", 128, LengthDistribution::fit(210.0, 6.0, 16, 2048));
        let trace = vec![
            TraceEvent { at: 0.0, event: Event::Arrive(a) },
            // half of server 0 is reclaimed mid-training…
            TraceEvent { at: 600.0, event: Event::Preempt { gpu_range: (0, 4) } },
            // …and comes back later
            TraceEvent { at: 2400.0, event: Event::NodeJoin { server: 0 } },
        ];
        let mut rt = ServeRuntime::new(&cost, &cluster, fast_opts());
        let report = rt.run_trace(&trace);
        assert_eq!(report.preempt_events, 1);
        assert_eq!(report.join_events, 1);
        // the interrupted step's work on the 4 reclaimed GPUs is charged
        assert!(report.gpu_seconds_lost_preempt > 0.0, "{report:#?}");
        // three adoptions: cold deploy, shrink swap, restore swap (the
        // latter two are redeploys when the 12-GPU plan differs, identical
        // swaps when the cold plan already fit the survivors)
        assert!(report.redeploys >= 1, "{report:#?}");
        assert!(
            report.redeploys + report.plan_swaps_identical >= 3,
            "{report:#?}"
        );
        // the shrunk plan fit the surviving 12 GPUs; the restored plan is
        // re-certified against the never-shrunk cold plan (recovery
        // identity — budgets cleared, certify gate re-armed)
        assert!(report.identity_checks > 0, "{report:#?}");
        assert_eq!(report.identity_failures, 0, "{report:#?}");
        assert_eq!(report.recoveries.len(), 1, "{report:#?}");
        assert!(report.recoveries[0] > 0.0);
        // after the restore the budget clamp is gone
        assert_eq!(rt.manager().gpu_budget(0), None);
        let plan = rt.manager().plan().expect("live deployment");
        assert!(plan.groups.iter().map(|g| g.n()).sum::<u32>() <= 16);
        assert!(report.steps_total > 0);
    }

    #[test]
    fn mixed_fleet_serve_admits_on_both_pools() {
        let a100 = ClusterSpec::a100_40g(8);
        let h100 = ClusterSpec::h100_80g(8);
        let model = ModelDesc::llama2_7b();
        let cost_a = CostModel::calibrated(&model, &a100);
        let cost_h = CostModel::calibrated(&model, &h100);
        let mut opts = fast_opts();
        opts.certify_identity = false; // mixed fleets are not cold-comparable
        let qa = TaskSpec::new("qa", 64, LengthDistribution::fit(210.0, 6.0, 16, 2048));
        let sum = TaskSpec::new("sum", 16, LengthDistribution::fit(3600.0, 4.3, 16, 16384));
        let trace = vec![
            TraceEvent { at: 0.0, event: Event::Arrive(qa) },
            TraceEvent { at: 500.0, event: Event::Arrive(sum) },
        ];
        let mut rt = ServeRuntime::new_fleet(vec![(&cost_a, &a100), (&cost_h, &h100)], opts);
        let report = rt.run_trace(&trace);
        assert_eq!(report.tenants.len(), 2, "{report:#?}");
        for t in &report.tenants {
            assert!(t.admitted_at.is_some(), "tenant {} never admitted", t.name);
            assert!(t.steps_trained > 0, "tenant {} made no progress", t.name);
        }
        assert!(rt.manager().device_mode());
        assert_eq!(rt.manager().n_shards(), 2);
        assert!(report.steps_total > 0);
    }
}
