//! Tenant lifecycle management (paper §5.1 "dynamic batches").
//!
//! FT requests arrive rarely and live long (the paper cites ≈8.5 tasks/hour
//! with multi-hour durations), so LobRA treats the task batch as fixed and
//! re-plans only when it changes: on arrival or exit, a new deployment plan
//! is computed from the updated length distributions; if it differs from
//! the current one, LoRA adapters are checkpointed and the joint task is
//! restarted under the new plan (the base model needs no checkpoint).
//!
//! The manager is **event-driven and non-blocking**: [`TaskManager::apply_event`]
//! updates the live task set and *begins* a resumable
//! [`AnytimeReplan`] through the persistent [`PlanningSession`] — it never
//! runs the search itself. The caller (normally the serving runtime,
//! [`crate::coordinator::runtime::ServeRuntime`]) pumps the search in
//! budget slices between training steps ([`TaskManager::pump_replan`]) and
//! adopts the result at a step boundary ([`TaskManager::finish_replan`]).
//! The blocking [`TaskManager::handle`] survives as the
//! unlimited-budget composition of those three calls — same plans,
//! bit-identical `expected_step_time`, inverted control flow. Under the
//! async planner service ([`crate::coordinator::service`]) the search runs
//! off-thread instead: `apply_event` still opens the replan window (its
//! admission/supersession semantics are shared verbatim), but the
//! service's published plan is adopted through
//! [`TaskManager::finish_replan_with`] and the local pending search is
//! simply never pumped.
//!
//! Redeploy accounting is **incremental**: [`plan_adjustment`] diffs the
//! `(ParallelConfig, count)` groups of the old and new plans, and only
//! replicas whose group actually changed pay checkpoint+restart — a
//! plan-identical redeploy charges exactly zero (regression-tested), and
//! an exit that shrinks one group charges just that group's delta instead
//! of the old flat 120 s constant.

use crate::cluster::ClusterSpec;
use crate::config::{TaskSet, TaskSpec};
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::session::{AnytimeReplan, PlanningSession, SliceReport};
use crate::costmodel::{CostModel, CostTables};

/// Events the serving stack reacts to: tenant lifecycle (trace grammar v1)
/// plus cluster capacity churn (grammar v2). One enum serves the blocking
/// manager, the sharded fleet manager, and the serving runtime — cluster
/// events address the [`crate::cluster::VirtualCluster`]'s global
/// server/GPU numbering and are resolved to capacity budgets by the
/// runtime before any planner sees them.
#[derive(Debug, Clone)]
pub enum Event {
    Arrive(TaskSpec),
    Exit { name: String },
    /// A server (re)joins the fleet: its down GPUs come back and a grow
    /// replan is opened, diff-charged like any other redeploy.
    NodeJoin { server: u32 },
    /// A whole server leaves (hardware failure, scale-down).
    NodeLeave { server: u32 },
    /// A `[start, end)` global GPU range is spot-preempted mid-step:
    /// checkpoint + shrink + redeploy on the surviving capacity.
    Preempt { gpu_range: (u32, u32) },
}

impl Event {
    /// Cluster capacity event (as opposed to tenant lifecycle)?
    pub fn is_cluster(&self) -> bool {
        matches!(
            self,
            Event::NodeJoin { .. } | Event::NodeLeave { .. } | Event::Preempt { .. }
        )
    }
}

/// What an event (or an adopted replan) did. One outcome type serves both
/// control-flow shapes: the **non-blocking** view reports
/// [`Outcome::Planning`] when a background replan was opened (pump it,
/// then adopt at a step boundary — adoption reports one of the terminal
/// variants), while the **blocking** view ([`TaskManager::handle`]) runs
/// the search inline and only ever returns terminal variants
/// ([`Outcome::is_terminal`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// The task set or capacity changed; a background replan is now
    /// pending on the listed shards (empty for a single-manager world).
    /// Non-terminal: pump and finish at a step boundary.
    Planning { opened: Vec<usize> },
    /// Plan unchanged — training continues uninterrupted.
    Unchanged,
    /// New plan deployed; adapters checkpointed + restarted.
    Redeployed {
        /// Simulated adjustment cost in seconds (paper: < 3 minutes),
        /// charged only for the replica groups that actually changed.
        adjustment_seconds: f64,
        /// The group diff the charge was computed from — carried so
        /// callers (the serving runtime's GPU-seconds accounting) never
        /// re-derive it under possibly divergent rules.
        adjustment: PlanAdjustment,
    },
    /// Arrival rejected: a live task already uses this name (`Exit`
    /// removes by name, so admitting a duplicate would make teardown
    /// ambiguous), the world is infeasible for it, or a malformed cluster
    /// event addressed unknown capacity.
    Rejected,
    /// No capacity anywhere for this arrival: held in the admission queue
    /// (sharded manager only), re-admitted in (tier, FIFO) order.
    Queued,
    /// No tasks left; any pending replan is dropped and the plan cleared.
    Drained,
}

impl Outcome {
    /// Terminal (blocking-view) outcome — everything except an open
    /// background replan.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, Outcome::Planning { .. })
    }
}

/// The per-group redeploy delta between two deployment plans: replicas in
/// groups whose `(ParallelConfig, count)` changed. Unchanged groups keep
/// training through a redeploy and pay nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlanAdjustment {
    /// Replicas added plus removed across all configuration groups.
    pub changed_replicas: u32,
    /// GPUs under those changed replicas.
    pub changed_gpus: u32,
}

impl PlanAdjustment {
    /// Wall-clock adjustment: checkpoint+restore serialized through the
    /// coordinator at `per_replica` seconds per changed replica.
    pub fn seconds(&self, per_replica: f64) -> f64 {
        self.changed_replicas as f64 * per_replica
    }

    /// GPU-seconds lost: every GPU under a changed replica idles for that
    /// replica's restart.
    pub fn gpu_seconds(&self, per_replica: f64) -> f64 {
        self.changed_gpus as f64 * per_replica
    }

    pub fn is_zero(&self) -> bool {
        self.changed_replicas == 0
    }
}

/// Diff two deployment plans into the set of changed replica groups. For
/// each configuration, `|before_count − after_count|` replicas must be
/// torn down or brought up; replicas in the common `min(before, after)`
/// share are untouched. Identical plans diff to zero.
pub fn plan_adjustment(before: &DeploymentPlan, after: &DeploymentPlan) -> PlanAdjustment {
    use std::collections::BTreeMap;
    let mut counts: BTreeMap<crate::config::ParallelConfig, (u32, u32)> = BTreeMap::new();
    for &(c, p) in &before.groups {
        counts.entry(c).or_default().0 += p;
    }
    for &(c, p) in &after.groups {
        counts.entry(c).or_default().1 += p;
    }
    let mut adj = PlanAdjustment::default();
    for (c, (b, a)) in counts {
        let d = b.abs_diff(a);
        adj.changed_replicas += d;
        adj.changed_gpus += d * c.n();
    }
    adj
}

/// Multi-tenant task manager: owns the live task set, the current plan,
/// the persistent [`PlanningSession`] serving every replan, and (between
/// `apply_event` and `finish_replan`) the in-flight background search.
pub struct TaskManager<'a> {
    cost: &'a CostModel,
    cluster: &'a ClusterSpec,
    session: PlanningSession,
    tasks: TaskSet,
    plan: Option<DeploymentPlan>,
    /// In-flight background replan (non-blocking path).
    pending: Option<AnytimeReplan>,
    /// An `apply_event` opened a replan that has not been adopted yet.
    /// Distinct from `pending.is_some()`: a replan whose planning context
    /// turned out infeasible has no search to pump but must still be
    /// finished (adopting "no plan" → drain).
    replan_open: bool,
    /// Count of redeployments (exposed for tests / reports).
    pub redeploys: u32,
    /// Count of planner invocations (one per begun-and-adopted replan,
    /// whether or not it yielded a plan) — events that leave the task set
    /// unchanged (e.g. an `Exit` naming an unknown task) must not add one.
    /// Equals `session().stats.plans` as long as every replan's world was
    /// feasible; an infeasible replan counts here but not there.
    pub replans: u32,
    /// Background replans abandoned because a newer event superseded them
    /// before they finished (the search targeted a stale task set).
    pub superseded: u32,
    /// Per-replica checkpoint+restart seconds; a redeploy charges
    /// `this × changed replicas` (paper: the whole adjustment stays under
    /// 3 minutes — LoRA checkpoints are tiny, the cost is process
    /// restart + load).
    pub restart_seconds_per_replica: f64,
}

impl<'a> TaskManager<'a> {
    pub fn new(
        cost: &'a CostModel,
        cluster: &'a ClusterSpec,
        initial: TaskSet,
        opts: PlannerOptions,
    ) -> Self {
        Self::with_tables(cost, cluster, initial, opts, CostTables::default())
    }

    /// Like [`Self::new`] but sharing an existing cost-table LRU — sharded
    /// planning ([`crate::coordinator::shard::ShardManager`]) runs one
    /// manager per shard over a single cache so a `(config, multiple)`
    /// table built for one shard warms every other.
    pub fn with_tables(
        cost: &'a CostModel,
        cluster: &'a ClusterSpec,
        initial: TaskSet,
        opts: PlannerOptions,
        tables: CostTables,
    ) -> Self {
        let mut mgr = Self {
            cost,
            cluster,
            session: PlanningSession::with_tables(opts, tables),
            tasks: initial,
            plan: None,
            pending: None,
            replan_open: false,
            redeploys: 0,
            replans: 0,
            superseded: 0,
            restart_seconds_per_replica: 15.0,
        };
        if !mgr.tasks.is_empty() {
            // initial deployment: run the anytime machinery to completion
            // (not a redeploy — nothing was running before)
            mgr.begin_replan();
            let budget = mgr.session.options().max_plans;
            mgr.pump_replan(budget);
            mgr.adopt_pending();
        }
        mgr
    }

    pub fn tasks(&self) -> &TaskSet {
        &self.tasks
    }

    pub fn plan(&self) -> Option<&DeploymentPlan> {
        self.plan.as_ref()
    }

    /// The persistent planning session (warm-start + cache statistics).
    pub fn session(&self) -> &PlanningSession {
        &self.session
    }

    /// Shared cost-table cache — hand this to a
    /// [`crate::coordinator::scheduler::Scheduler`] so per-step dispatch
    /// tables and planning tables come from one LRU.
    pub fn tables(&self) -> CostTables {
        self.session.tables()
    }

    /// A background replan is open (begun but not yet adopted).
    pub fn replan_pending(&self) -> bool {
        self.replan_open
    }

    /// Re-slice this manager's GPU capacity: the planning session searches
    /// within `budget` GPUs (clamped to the cluster) from the next replan
    /// on. A changed budget invalidates the warm-start memo — candidates
    /// found under a different capacity may be infeasible or non-optimal
    /// under the new one. `None` restores full-cluster search.
    pub fn set_gpu_budget(&mut self, budget: Option<u32>) {
        self.session.set_gpu_budget(budget);
    }

    /// Begin a fresh background replan for the *current* task set without
    /// an event — used after a capacity rebalance changed this shard's GPU
    /// budget. Returns `false` (and opens nothing) when the manager has no
    /// tasks or the planning context is infeasible under the new budget.
    pub fn reopen_replan(&mut self) -> bool {
        if self.tasks.is_empty() {
            return false;
        }
        self.begin_replan();
        if self.pending.is_none() {
            self.replan_open = false;
            return false;
        }
        true
    }

    /// The in-flight search finished its enumeration (a `finish_replan`
    /// now adopts a certified cold-identical plan).
    pub fn replan_done(&self) -> bool {
        self.pending.as_ref().is_some_and(AnytimeReplan::enumeration_done)
    }

    /// An open replan actually has a search to pump. False with an open
    /// window whose planning context was infeasible (nothing pending) —
    /// the sharded manager treats such shards as finished rather than
    /// waiting on slices that will never come.
    pub fn replan_searching(&self) -> bool {
        self.pending.is_some()
    }

    /// Begin (or restart) the background replan for the current task set.
    fn begin_replan(&mut self) {
        if self.pending.take().is_some() {
            self.superseded += 1;
        }
        let planner = Planner::new(self.cost, self.cluster);
        self.pending = self.session.begin_anytime(&planner, &self.tasks);
        self.replan_open = true;
    }

    /// Adopt whatever the pending search has (its final evaluation), set
    /// it as the current plan and account the replan. `None` when the
    /// world is infeasible for the current task set.
    fn adopt_pending(&mut self) -> Option<DeploymentPlan> {
        self.replan_open = false;
        self.replans += 1;
        let planner = Planner::new(self.cost, self.cluster);
        let plan = match self.pending.take() {
            Some(search) => {
                self.session.finish_anytime(&planner, search).map(|(p, _)| p)
            }
            // begin_anytime found no feasible context (e.g. no candidate
            // config supports the longest bucket)
            None => None,
        };
        self.plan = plan.clone();
        plan
    }

    /// Apply an event **without blocking on the planner**: the task set is
    /// updated and a background [`AnytimeReplan`] is begun — superseding
    /// any in-flight one, whose target set just went stale. Training may
    /// continue under the current plan while the caller pumps the search
    /// with [`Self::pump_replan`] and adopts it with
    /// [`Self::finish_replan`] at a step boundary.
    pub fn apply_event(&mut self, event: Event) -> Outcome {
        let was_open = self.replan_open;
        let arrived = match event {
            // Cluster capacity events are fleet-level: the runtime resolves
            // them to GPU budgets (`set_gpu_budget` + `reopen_replan`)
            // before any manager is involved. Reaching a bare manager with
            // one is a no-op by construction.
            Event::NodeJoin { .. } | Event::NodeLeave { .. } | Event::Preempt { .. } => {
                return Outcome::Unchanged;
            }
            Event::Arrive(spec) => {
                // `Exit` removes by name, so a duplicate name would let one
                // tenant tear down another's task; silently renaming would
                // leave the submitter unable to address its own task. The
                // task set is unchanged, so no replan either.
                if self.tasks.tasks.iter().any(|t| t.name == spec.name) {
                    return Outcome::Rejected;
                }
                self.tasks.tasks.push(spec);
                true
            }
            Event::Exit { name } => {
                if !self.tasks.tasks.iter().any(|t| t.name == name) {
                    // unknown task: the set did not change — a full replan
                    // here would burn minutes of planner time for nothing
                    return Outcome::Unchanged;
                }
                self.tasks.tasks.retain(|t| t.name != name);
                false
            }
        };
        if self.tasks.is_empty() {
            if self.pending.take().is_some() {
                self.superseded += 1;
            }
            self.replan_open = false;
            self.plan = None;
            return Outcome::Drained;
        }
        self.begin_replan();
        if self.pending.is_none() && arrived {
            // The newcomer made the world infeasible (no candidate config
            // can serve its longest sequences — exits can only *shrink*
            // the longest bucket, so infeasibility here is attributable to
            // the arrival). Reject it and keep serving the previous
            // tenants instead of draining a healthy deployment.
            self.tasks.tasks.pop();
            if was_open && !self.tasks.is_empty() {
                // an earlier event's search was superseded by this begin;
                // restart it for the restored (feasible) task set
                self.begin_replan();
            } else {
                self.replan_open = false;
            }
            return Outcome::Rejected;
        }
        Outcome::Planning { opened: Vec::new() }
    }

    /// Advance the in-flight background replan by one enumeration slice of
    /// up to `slice_plans` plans. Returns `None` when no replan is
    /// pending.
    pub fn pump_replan(&mut self, slice_plans: usize) -> Option<SliceReport> {
        let mut pending = self.pending.take()?;
        let planner = Planner::new(self.cost, self.cluster);
        let report = self.session.pump_anytime(&planner, &mut pending, slice_plans);
        self.pending = Some(pending);
        Some(report)
    }

    /// Adopt the pending replan's result at a step boundary — the
    /// best-so-far plan when the budget expired mid-search (still a valid
    /// feasible deployment), the certified cold-identical plan when the
    /// enumeration completed. Charges checkpoint+restart only for the
    /// replica groups that actually changed ([`plan_adjustment`]): a
    /// plan-identical swap reports [`Outcome::Unchanged`] and costs
    /// nothing.
    pub fn finish_replan(&mut self) -> Outcome {
        if !self.replan_open {
            // nothing to adopt — never wipe a healthy deployment
            return Outcome::Unchanged;
        }
        let before = self.plan.clone();
        self.adopt_pending();
        self.outcome_from(before)
    }

    /// Adopt a plan computed *outside* the manager — the async planner
    /// service's published result — at a step boundary. Replan accounting
    /// (`replans`, window close, dropping the never-pumped local pending
    /// search) and the redeploy diff are identical to
    /// [`Self::finish_replan`]; only the search itself happened elsewhere.
    /// `None` means the service found the world infeasible — the
    /// deployment drains, exactly as when the local search finds nothing.
    pub fn finish_replan_with(&mut self, plan: Option<DeploymentPlan>) -> Outcome {
        if !self.replan_open {
            return Outcome::Unchanged;
        }
        let before = self.plan.clone();
        self.replan_open = false;
        self.replans += 1;
        self.pending = None;
        self.plan = plan;
        self.outcome_from(before)
    }

    /// Diff the freshly adopted `self.plan` against `before` into the
    /// caller-visible outcome, charging checkpoint+restart for the changed
    /// replica groups only.
    fn outcome_from(&mut self, before: Option<DeploymentPlan>) -> Outcome {
        match (&before, &self.plan) {
            (Some(a), Some(b)) if a.groups == b.groups => Outcome::Unchanged,
            (Some(a), Some(b)) => {
                self.redeploys += 1;
                let adjustment = plan_adjustment(a, b);
                Outcome::Redeployed {
                    adjustment_seconds: adjustment
                        .seconds(self.restart_seconds_per_replica),
                    adjustment,
                }
            }
            (None, Some(b)) => {
                // cold (re-)deploy after a drain: every replica starts
                self.redeploys += 1;
                let fresh = DeploymentPlan {
                    groups: Vec::new(),
                    n_tasks: b.n_tasks,
                    expected_step_time: 0.0,
                };
                let adjustment = plan_adjustment(&fresh, b);
                Outcome::Redeployed {
                    adjustment_seconds: adjustment
                        .seconds(self.restart_seconds_per_replica),
                    adjustment,
                }
            }
            (_, None) => Outcome::Drained,
        }
    }

    /// Apply an event and replan **synchronously** — the unlimited-budget
    /// composition of [`Self::apply_event`] + [`Self::pump_replan`] +
    /// [`Self::finish_replan`]. Events that leave the task set unchanged
    /// (unknown `Exit`, duplicate-name `Arrive`) skip the replan entirely.
    pub fn handle(&mut self, event: Event) -> Outcome {
        match self.apply_event(event) {
            Outcome::Rejected => Outcome::Rejected,
            Outcome::Unchanged => Outcome::Unchanged,
            Outcome::Drained => Outcome::Drained,
            Outcome::Planning { .. } => {
                let budget = self.session.options().max_plans;
                self.pump_replan(budget);
                self.finish_replan()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, ParallelConfig};
    use crate::data::LengthDistribution;

    fn world() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    fn dp(groups: Vec<(ParallelConfig, u32)>) -> DeploymentPlan {
        DeploymentPlan { groups, n_tasks: 2, expected_step_time: 1.0 }
    }

    #[test]
    fn initial_plan_exists() {
        let (cost, cluster) = world();
        let mgr = TaskManager::new(
            &cost,
            &cluster,
            TaskSet::paper_7b_subset(),
            PlannerOptions::default(),
        );
        assert!(mgr.plan().is_some());
        assert_eq!(mgr.tasks().len(), 6);
        assert!(!mgr.replan_pending());
        // the initial deployment is not counted as a redeploy
        assert_eq!(mgr.redeploys, 0);
        assert_eq!(mgr.replans, 1);
    }

    #[test]
    fn long_task_arrival_triggers_redeploy() {
        let (cost, cluster) = world();
        // start with short-only tasks → small replicas suffice
        let short = TaskSet::new(vec![TaskSpec::new(
            "short-qa",
            128,
            LengthDistribution::fit(200.0, 2.0, 16, 1024),
        )]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, short, PlannerOptions::default());
        let before = mgr.plan().unwrap().clone();
        // a summarization task with a long tail arrives
        let outcome = mgr.handle(Event::Arrive(TaskSpec::new(
            "billsum-like",
            32,
            LengthDistribution::fit(3900.0, 0.85, 16, 16384),
        )));
        assert!(matches!(outcome, Outcome::Redeployed { .. }), "{outcome:?}");
        // the adjustment was computed from the actual group diff
        let after = mgr.plan().unwrap().clone();
        if let Outcome::Redeployed { adjustment_seconds, adjustment } = outcome {
            assert!(adjustment.changed_replicas > 0);
            assert_eq!(adjustment, plan_adjustment(&before, &after));
            assert_eq!(
                adjustment_seconds,
                adjustment.seconds(mgr.restart_seconds_per_replica)
            );
        }
        // every replan went through the persistent session
        assert_eq!(mgr.session().stats.plans, mgr.replans as u64);
        let cap_before: u64 = before.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
        let cap_after: u64 = after.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
        assert!(cap_after >= cap_before, "capacity must grow: {cap_before} -> {cap_after}");
    }

    #[test]
    fn exit_to_empty_drains() {
        let (cost, cluster) = world();
        let one = TaskSet::new(vec![TaskSpec::new(
            "only",
            64,
            LengthDistribution::fit(300.0, 2.0, 16, 2048),
        )]);
        let mut mgr = TaskManager::new(&cost, &cluster, one, PlannerOptions::default());
        let out = mgr.handle(Event::Exit { name: "only".into() });
        assert_eq!(out, Outcome::Drained);
        assert!(mgr.plan().is_none());
        assert!(!mgr.replan_pending());
    }

    #[test]
    fn unknown_exit_keeps_plan_without_replanning() {
        let (cost, cluster) = world();
        let mut mgr = TaskManager::new(
            &cost,
            &cluster,
            TaskSet::paper_7b_subset(),
            PlannerOptions::default(),
        );
        let replans_before = mgr.replans;
        let out = mgr.handle(Event::Exit { name: "not-a-task".into() });
        assert_eq!(out, Outcome::Unchanged);
        assert_eq!(mgr.tasks().len(), 6);
        // regression: the unchanged task set must not trigger a replan
        assert_eq!(mgr.replans, replans_before, "unknown exit ran the planner");
        assert_eq!(mgr.redeploys, 0);
    }

    #[test]
    fn duplicate_arrival_rejected_without_replanning() {
        let (cost, cluster) = world();
        let spec = TaskSpec::new("dup", 64, LengthDistribution::fit(200.0, 2.0, 16, 1024));
        let initial = TaskSet::new(vec![spec.clone()]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
        let replans_before = mgr.replans;
        let out = mgr.handle(Event::Arrive(spec.clone()));
        assert_eq!(out, Outcome::Rejected);
        assert_eq!(mgr.tasks().len(), 1, "duplicate must not be admitted");
        assert_eq!(mgr.replans, replans_before, "rejection must not replan");
        // a uniquely named resubmission is admitted normally
        let mut renamed = spec;
        renamed.name = "dup-2".into();
        let out = mgr.handle(Event::Arrive(renamed));
        assert_ne!(out, Outcome::Rejected);
        assert_eq!(mgr.tasks().len(), 2);
        // exits stay unambiguous: each name removes exactly one task
        assert_ne!(
            mgr.handle(Event::Exit { name: "dup".into() }),
            Outcome::Drained
        );
        assert_eq!(
            mgr.handle(Event::Exit { name: "dup-2".into() }),
            Outcome::Drained
        );
        assert!(mgr.tasks().is_empty());
    }

    #[test]
    fn plan_identical_redeploy_charges_zero() {
        // regression for the flat-cost bug: the adjustment is computed
        // from the changed groups, so an identical plan costs exactly 0
        let c1 = ParallelConfig::new(1, 1);
        let c8 = ParallelConfig::new(8, 1);
        let a = dp(vec![(c1, 6), (c8, 1)]);
        let adj = plan_adjustment(&a, &a);
        assert!(adj.is_zero());
        assert_eq!(adj.seconds(15.0), 0.0);
        assert_eq!(adj.gpu_seconds(15.0), 0.0);
    }

    #[test]
    fn adjustment_charges_only_changed_groups() {
        let c1 = ParallelConfig::new(1, 1);
        let c2 = ParallelConfig::new(2, 1);
        let c8 = ParallelConfig::new(8, 1);
        // shrink the <1,1> group by two replicas, keep <8,1> untouched
        let before = dp(vec![(c1, 6), (c8, 1)]);
        let after = dp(vec![(c1, 4), (c8, 1)]);
        let adj = plan_adjustment(&before, &after);
        assert_eq!(adj.changed_replicas, 2);
        assert_eq!(adj.changed_gpus, 2);
        assert_eq!(adj.seconds(15.0), 30.0);
        // swap a <2,1> pair for one <8,1>: 2 removed + 1 added replicas
        let before = dp(vec![(c1, 4), (c2, 2)]);
        let after = dp(vec![(c1, 4), (c8, 1)]);
        let adj = plan_adjustment(&before, &after);
        assert_eq!(adj.changed_replicas, 3);
        assert_eq!(adj.changed_gpus, 2 * 2 + 8);
        // the diff is symmetric
        assert_eq!(plan_adjustment(&after, &before), adj);
        // cold deploy from nothing: every replica pays
        let empty = dp(vec![]);
        let adj = plan_adjustment(&empty, &after);
        assert_eq!(adj.changed_replicas, 5);
        assert_eq!(adj.changed_gpus, 12);
    }

    #[test]
    fn infeasible_arrival_rejected_without_draining() {
        // regression: an arrival no configuration can serve used to adopt
        // plan=None and drain every healthy tenant's deployment — it must
        // be rejected while the previous plan keeps serving
        let (cost, cluster) = world();
        let initial = TaskSet::new(vec![TaskSpec::new(
            "base",
            96,
            LengthDistribution::fit(250.0, 3.0, 16, 2048),
        )]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
        let healthy = mgr.plan().unwrap().clone();
        // million-token sequences: no 16×A100-40G config holds them
        let out = mgr.handle(Event::Arrive(TaskSpec::new(
            "huge",
            8,
            LengthDistribution::fit(60_000.0, 1.0, 16, 1_000_000),
        )));
        assert_eq!(out, Outcome::Rejected);
        assert_eq!(mgr.tasks().len(), 1, "infeasible tenant must not be admitted");
        assert_eq!(
            mgr.plan().unwrap().groups,
            healthy.groups,
            "healthy deployment must survive an infeasible arrival"
        );
        assert!(!mgr.replan_pending());
        // the survivor set memo was cleared, but normal service continues:
        // a feasible arrival afterwards replans as usual
        let out = mgr.handle(Event::Arrive(TaskSpec::new(
            "ok",
            32,
            LengthDistribution::fit(700.0, 4.0, 16, 4096),
        )));
        assert_ne!(out, Outcome::Rejected);
        assert_eq!(mgr.tasks().len(), 2);
        assert!(mgr.plan().is_some());
    }

    #[test]
    fn nonblocking_event_flow_matches_blocking_handle() {
        // the async API (apply_event → pump slices → finish) adopts the
        // same plan the blocking handle() would, and training-visible
        // state (current plan) is untouched until finish_replan
        let (cost, cluster) = world();
        let opts = PlannerOptions::default();
        let initial = TaskSet::new(vec![TaskSpec::new(
            "base",
            96,
            LengthDistribution::fit(250.0, 3.0, 16, 2048),
        )]);
        let arrive = TaskSpec::new(
            "long-tail",
            32,
            LengthDistribution::fit(2800.0, 1.2, 16, 8192),
        );

        let mut sync_mgr =
            TaskManager::new(&cost, &cluster, initial.clone(), opts.clone());
        let mut async_mgr = TaskManager::new(&cost, &cluster, initial, opts);

        let sync_out = sync_mgr.handle(Event::Arrive(arrive.clone()));
        assert!(matches!(sync_out, Outcome::Redeployed { .. }));

        let stale = async_mgr.plan().unwrap().clone();
        assert_eq!(
            async_mgr.apply_event(Event::Arrive(arrive)),
            Outcome::Planning { opened: vec![] }
        );
        assert!(async_mgr.replan_pending());
        // the deployed plan is untouched while the search runs
        assert_eq!(async_mgr.plan().unwrap().groups, stale.groups);
        let mut slices = 0;
        loop {
            let r = async_mgr.pump_replan(16).expect("replan pending");
            slices += 1;
            assert!(slices < 100_000, "anytime search failed to converge");
            if r.done {
                break;
            }
        }
        assert!(slices > 1, "slice budget too generous to exercise resume");
        let async_out = async_mgr.finish_replan();
        assert_eq!(async_out, sync_out);
        assert_eq!(
            async_mgr.plan().unwrap().groups,
            sync_mgr.plan().unwrap().groups
        );
        assert_eq!(
            async_mgr.plan().unwrap().expected_step_time.to_bits(),
            sync_mgr.plan().unwrap().expected_step_time.to_bits()
        );
    }

    #[test]
    fn superseding_event_restarts_pending_replan() {
        let (cost, cluster) = world();
        let initial = TaskSet::new(vec![TaskSpec::new(
            "base",
            96,
            LengthDistribution::fit(250.0, 3.0, 16, 2048),
        )]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
        let a = TaskSpec::new("a", 32, LengthDistribution::fit(700.0, 4.0, 16, 4096));
        let b = TaskSpec::new("b", 32, LengthDistribution::fit(2800.0, 1.2, 16, 8192));
        assert_eq!(mgr.apply_event(Event::Arrive(a)), Outcome::Planning { opened: vec![] });
        mgr.pump_replan(4);
        // a second event lands while the first search is in flight: the
        // stale-target search is abandoned and a fresh one begun
        assert_eq!(mgr.apply_event(Event::Arrive(b)), Outcome::Planning { opened: vec![] });
        assert_eq!(mgr.superseded, 1);
        let budget = mgr.session().options().max_plans;
        mgr.pump_replan(budget);
        assert!(mgr.replan_done());
        mgr.finish_replan();
        // the adopted plan targets the *final* 3-task set
        assert_eq!(mgr.plan().unwrap().n_tasks, 3);
        assert_eq!(mgr.tasks().len(), 3);
    }
}
