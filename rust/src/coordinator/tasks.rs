//! Tenant lifecycle management (paper §5.1 "dynamic batches").
//!
//! FT requests arrive rarely and live long (the paper cites ≈8.5 tasks/hour
//! with multi-hour durations), so LobRA treats the task batch as fixed and
//! re-plans only when it changes: on arrival or exit, a new deployment plan
//! is computed from the updated length distributions; if it differs from
//! the current one, LoRA adapters are checkpointed and the joint task is
//! restarted under the new plan (the base model needs no checkpoint).
//!
//! Replanning goes through a persistent [`PlanningSession`] held across
//! events: each replan warm-starts the streaming search from the previous
//! survivor set and draws its cost table from the session's shared LRU,
//! producing the exact plan a cold `Planner::plan` would — just faster.

use crate::cluster::ClusterSpec;
use crate::config::{TaskSet, TaskSpec};
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::session::PlanningSession;
use crate::costmodel::{CostModel, CostTables};

/// Events the manager reacts to.
#[derive(Debug, Clone)]
pub enum TaskEvent {
    Arrive(TaskSpec),
    Exit { name: String },
}

/// What happened as a result of an event.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplanOutcome {
    /// Plan unchanged — training continues uninterrupted.
    Unchanged,
    /// New plan deployed; adapters checkpointed + restarted.
    Redeployed {
        /// Simulated adjustment cost in seconds (paper: < 3 minutes).
        adjustment_seconds: f64,
    },
    /// No tasks left; the joint FT job drains.
    Drained,
    /// Arrival rejected: a live task already uses this name. `Exit`
    /// removes by name, so admitting a duplicate would make teardown
    /// ambiguous — the tenant must resubmit under a unique name.
    Rejected,
}

/// Multi-tenant task manager: owns the live task set, the current plan and
/// the persistent [`PlanningSession`] that serves every replan.
pub struct TaskManager<'a> {
    cost: &'a CostModel,
    cluster: &'a ClusterSpec,
    session: PlanningSession,
    tasks: TaskSet,
    plan: Option<DeploymentPlan>,
    /// Count of redeployments (exposed for tests / reports).
    pub redeploys: u32,
    /// Count of planner invocations — events that leave the task set
    /// unchanged (e.g. an `Exit` naming an unknown task) must not add one.
    pub replans: u32,
    /// Simulated checkpoint+restart cost per redeploy, seconds.
    pub adjustment_cost: f64,
}

impl<'a> TaskManager<'a> {
    pub fn new(
        cost: &'a CostModel,
        cluster: &'a ClusterSpec,
        initial: TaskSet,
        opts: PlannerOptions,
    ) -> Self {
        let mut mgr = Self {
            cost,
            cluster,
            session: PlanningSession::new(opts),
            tasks: initial,
            plan: None,
            redeploys: 0,
            replans: 0,
            // paper: "consistently less than 3 minutes"; LoRA checkpoints
            // are tiny, the cost is dominated by process restart + load.
            adjustment_cost: 120.0,
        };
        mgr.replan();
        mgr
    }

    pub fn tasks(&self) -> &TaskSet {
        &self.tasks
    }

    pub fn plan(&self) -> Option<&DeploymentPlan> {
        self.plan.as_ref()
    }

    /// The persistent planning session (warm-start + cache statistics).
    pub fn session(&self) -> &PlanningSession {
        &self.session
    }

    /// Shared cost-table cache — hand this to a
    /// [`crate::coordinator::scheduler::Scheduler`] so per-step dispatch
    /// tables and planning tables come from one LRU.
    pub fn tables(&self) -> CostTables {
        self.session.tables()
    }

    fn replan(&mut self) -> Option<DeploymentPlan> {
        if self.tasks.is_empty() {
            self.plan = None;
            return None;
        }
        self.replans += 1;
        let planner = Planner::new(self.cost, self.cluster);
        let plan = self.session.plan(&planner, &self.tasks);
        self.plan = plan.clone();
        plan
    }

    /// Apply an event; re-plan with the updated task batch. Events that
    /// leave the task set unchanged (unknown `Exit`, duplicate-name
    /// `Arrive`) skip the replan entirely.
    pub fn handle(&mut self, event: TaskEvent) -> ReplanOutcome {
        let before = self.plan.clone();
        match event {
            TaskEvent::Arrive(spec) => {
                // `Exit` removes by name, so a duplicate name would let one
                // tenant tear down another's task; silently renaming would
                // leave the submitter unable to address its own task. The
                // task set is unchanged, so no replan either.
                if self.tasks.tasks.iter().any(|t| t.name == spec.name) {
                    return ReplanOutcome::Rejected;
                }
                self.tasks.tasks.push(spec);
            }
            TaskEvent::Exit { name } => {
                if !self.tasks.tasks.iter().any(|t| t.name == name) {
                    // unknown task: the set did not change — a full replan
                    // here would burn minutes of planner time for nothing
                    return ReplanOutcome::Unchanged;
                }
                self.tasks.tasks.retain(|t| t.name != name);
            }
        }
        if self.tasks.is_empty() {
            self.plan = None;
            return ReplanOutcome::Drained;
        }
        self.replan();
        match (&before, &self.plan) {
            (Some(a), Some(b)) if a.groups == b.groups => ReplanOutcome::Unchanged,
            (_, Some(_)) => {
                self.redeploys += 1;
                ReplanOutcome::Redeployed { adjustment_seconds: self.adjustment_cost }
            }
            (_, None) => ReplanOutcome::Drained,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;
    use crate::data::LengthDistribution;

    fn world() -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    #[test]
    fn initial_plan_exists() {
        let (cost, cluster) = world();
        let mgr = TaskManager::new(
            &cost,
            &cluster,
            TaskSet::paper_7b_subset(),
            PlannerOptions::default(),
        );
        assert!(mgr.plan().is_some());
        assert_eq!(mgr.tasks().len(), 6);
    }

    #[test]
    fn long_task_arrival_triggers_redeploy() {
        let (cost, cluster) = world();
        // start with short-only tasks → small replicas suffice
        let short = TaskSet::new(vec![TaskSpec::new(
            "short-qa",
            128,
            LengthDistribution::fit(200.0, 2.0, 16, 1024),
        )]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, short, PlannerOptions::default());
        let before = mgr.plan().unwrap().clone();
        // a summarization task with a long tail arrives
        let outcome = mgr.handle(TaskEvent::Arrive(TaskSpec::new(
            "billsum-like",
            32,
            LengthDistribution::fit(3900.0, 0.85, 16, 16384),
        )));
        assert!(matches!(outcome, ReplanOutcome::Redeployed { .. }), "{outcome:?}");
        // every replan went through the persistent session
        assert_eq!(mgr.session().stats.plans, mgr.replans as u64);
        let after = mgr.plan().unwrap();
        let cap_before: u64 = before.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
        let cap_after: u64 = after.groups.iter().map(|&(c, _)| cost.max_seq_len(c)).max().unwrap();
        assert!(cap_after >= cap_before, "capacity must grow: {cap_before} -> {cap_after}");
    }

    #[test]
    fn exit_to_empty_drains() {
        let (cost, cluster) = world();
        let one = TaskSet::new(vec![TaskSpec::new(
            "only",
            64,
            LengthDistribution::fit(300.0, 2.0, 16, 2048),
        )]);
        let mut mgr = TaskManager::new(&cost, &cluster, one, PlannerOptions::default());
        let out = mgr.handle(TaskEvent::Exit { name: "only".into() });
        assert_eq!(out, ReplanOutcome::Drained);
        assert!(mgr.plan().is_none());
    }

    #[test]
    fn unknown_exit_keeps_plan_without_replanning() {
        let (cost, cluster) = world();
        let mut mgr = TaskManager::new(
            &cost,
            &cluster,
            TaskSet::paper_7b_subset(),
            PlannerOptions::default(),
        );
        let replans_before = mgr.replans;
        let out = mgr.handle(TaskEvent::Exit { name: "not-a-task".into() });
        assert_eq!(out, ReplanOutcome::Unchanged);
        assert_eq!(mgr.tasks().len(), 6);
        // regression: the unchanged task set must not trigger a replan
        assert_eq!(mgr.replans, replans_before, "unknown exit ran the planner");
        assert_eq!(mgr.redeploys, 0);
    }

    #[test]
    fn duplicate_arrival_rejected_without_replanning() {
        let (cost, cluster) = world();
        let spec = TaskSpec::new("dup", 64, LengthDistribution::fit(200.0, 2.0, 16, 1024));
        let initial = TaskSet::new(vec![spec.clone()]);
        let mut mgr =
            TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
        let replans_before = mgr.replans;
        let out = mgr.handle(TaskEvent::Arrive(spec.clone()));
        assert_eq!(out, ReplanOutcome::Rejected);
        assert_eq!(mgr.tasks().len(), 1, "duplicate must not be admitted");
        assert_eq!(mgr.replans, replans_before, "rejection must not replan");
        // a uniquely named resubmission is admitted normally
        let mut renamed = spec;
        renamed.name = "dup-2".into();
        let out = mgr.handle(TaskEvent::Arrive(renamed));
        assert_ne!(out, ReplanOutcome::Rejected);
        assert_eq!(mgr.tasks().len(), 2);
        // exits stay unambiguous: each name removes exactly one task
        assert_ne!(
            mgr.handle(TaskEvent::Exit { name: "dup".into() }),
            ReplanOutcome::Drained
        );
        assert_eq!(
            mgr.handle(TaskEvent::Exit { name: "dup-2".into() }),
            ReplanOutcome::Drained
        );
        assert!(mgr.tasks().is_empty());
    }
}
