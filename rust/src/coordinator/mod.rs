//! The LobRA coordinator — the paper's system contribution, layer 3.
//!
//! * [`bucketing`] — dynamic bucketing DP (paper Eq. 4): choose `R` bucket
//!   boundaries per batch to minimize padding.
//! * [`dispatcher`] — per-step workload-balanced data dispatching (Eq. 3).
//! * [`planner`] — one-shot deployment of heterogeneous FT replicas
//!   (Eq. 2) with configuration-proposal and lower-bound pruning
//!   (Observation 1 / Theorem 1).
//! * [`scheduler`] — the joint-FT step loop tying it all together.
//! * [`tasks`] — tenant lifecycle: arrivals/exits trigger re-planning.

pub mod bucketing;
pub mod dispatcher;
pub mod planner;
pub mod scheduler;
pub mod tasks;
