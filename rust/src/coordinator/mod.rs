//! The LobRA coordinator — the paper's system contribution, layer 3.
//!
//! ## Modules
//!
//! * [`bucketing`] — dynamic bucketing DP (paper Eq. 4): choose `R` bucket
//!   boundaries per batch to minimize padding.
//! * [`dispatcher`] — per-step workload-balanced data dispatching (Eq. 3).
//! * [`planner`] — deployment of heterogeneous FT replicas (Eq. 2) as a
//!   fused streaming search: configuration proposal (Observation 1),
//!   Theorem-1 lower-bound filtering with online top-K selection of the
//!   evaluation set, and the exact inner dispatch solve.
//! * [`session`] — persistent planning sessions: the long-lived search
//!   state between replans (previous survivor set, shared cost-table LRU,
//!   resume checkpoints of capped searches).
//! * [`scheduler`] — the joint-FT step loop tying it all together: per
//!   step it builds a [`crate::exec::ExecutionPlan`] (dispatch solve +
//!   concrete per-replica sequence assignment) and hands it to a
//!   [`crate::exec::ReplicaExecutor`] backend. Simulated benches use the
//!   cost-clock backend; `lobra train` runs the identical pipeline with
//!   the PJRT backend, so both report GPU-seconds from the same dispatch
//!   code (see the [`crate::exec`] module docs for the backend diagram).
//! * [`tasks`] — tenant lifecycle: arrivals/exits trigger re-planning.
//!
//! ## State flow
//!
//! The planner itself is stateless: `Planner::plan` derives everything —
//! expectation buckets, candidate configs, cost table, survivor set — from
//! scratch, which is the right mental model but the wrong cost model for a
//! multi-tenant deployment where arrivals/exits force replans against a
//! mostly-unchanged world. Long-lived search state therefore lives in a
//! [`session::PlanningSession`]:
//!
//! ```text
//!                   TaskEvent (Arrive/Exit)
//!                            │
//!                  ┌─────────▼─────────┐  warm-start seed   ┌──────────┐
//!                  │   TaskManager     │───────────────────►│ Planner  │
//!                  │  PlanningSession  │  (prev survivors,   │ top-K    │
//!                  │   ┌───────────┐   │   re-scored)        │ search   │
//!                  │   │ CostTables│◄──┼─────────────────────┴──────────┘
//!                  │   │   (LRU)   │   │  tables keyed by
//!                  │   └─────▲─────┘   │  (configs, boundaries)
//!                  └─────────┼─────────┘
//!                            │ shared handle
//!                  ┌─────────┴─────────┐
//!                  │    Scheduler      │  per-step dispatch tables
//!                  └───────────────────┘
//! ```
//!
//! * `TaskManager` holds one session across events; each replan re-scores
//!   the previous survivor set against the new expectation buckets and
//!   seeds the streaming search's incumbent bound, so the visitor prunes
//!   most candidate plans with cheap table lookups. Warm-started replans
//!   are plan-identical (bit-identical `expected_step_time`) to a cold
//!   `Planner::plan` — seeding only accelerates, never alters.
//! * `Scheduler` draws its per-step cost tables from the same
//!   [`crate::costmodel::CostTables`] LRU (share the handle via
//!   `TaskManager::tables` / `Scheduler::with_tables`), so boundary
//!   vectors revisited by the dynamic-bucketing DP reuse their tables.
//! * Capped searches record a resume checkpoint;
//!   `PlanningSession::extend_capped_search` continues strictly after it
//!   instead of re-walking the enumeration prefix.

pub mod bucketing;
pub mod dispatcher;
pub mod planner;
pub mod scheduler;
pub mod session;
pub mod tasks;
