//! The LobRA coordinator — the paper's system contribution, layer 3.
//!
//! ## Modules
//!
//! * [`bucketing`] — dynamic bucketing DP (paper Eq. 4): choose `R` bucket
//!   boundaries per batch to minimize padding.
//! * [`dispatcher`] — per-step workload-balanced data dispatching (Eq. 3).
//! * [`planner`] — deployment of heterogeneous FT replicas (Eq. 2) as a
//!   fused streaming search: configuration proposal (Observation 1),
//!   Theorem-1 lower-bound filtering with online top-K selection of the
//!   evaluation set, and the exact inner dispatch solve.
//! * [`session`] — persistent planning sessions: the long-lived search
//!   state between replans (previous survivor set, shared cost-table LRU,
//!   resume checkpoints) and the resumable **anytime search**
//!   ([`session::AnytimeReplan`]) that spends a replan budget in slices.
//! * [`scheduler`] — the fixed-plan joint-FT step loop behind the paper
//!   benches: per step it builds a [`crate::exec::ExecutionPlan`]
//!   (dispatch solve + concrete per-replica sequence assignment) and hands
//!   it to a [`crate::exec::ReplicaExecutor`] backend.
//! * [`tasks`] — tenant lifecycle: a **non-blocking** [`tasks::TaskManager`]
//!   whose `apply_event` opens a background replan instead of running one,
//!   with diff-based redeploy accounting ([`tasks::plan_adjustment`]).
//! * [`runtime`] — the event-driven **serving runtime**: replays a churn
//!   trace, overlapping training under the current plan with the budgeted
//!   anytime replan, swapping plans at step boundaries.
//! * [`service`] — the **async planner service**: a dedicated thread owns
//!   a planning session and pumps the anytime search continuously,
//!   publishing terminal plans through a lock-free epoch-counted cell
//!   ([`crate::util::par::EpochCell`]); superseding events cancel the
//!   in-flight search mid-slice via [`crate::util::par::CancelToken`].
//!   With `--planner-threads N`, search overlaps training even on cold
//!   starts, where the sync path's slices are exposed on the serving
//!   clock.
//! * [`shard`] — **sharded localized replanning**: tenants partition into
//!   planning shards by sequence-length profile, each with its own GPU
//!   capacity slice and [`session::PlanningSession`] over the shared
//!   cost-table LRU. An event replans only its shard (O(change), not
//!   O(fleet)); per-shard plans compose deterministically; priority tiers
//!   drive admission (queue + preempt-lowest-tier) when capacity runs out.
//!
//! ## The serving event loop
//!
//! The planner itself is stateless and the blocking mental model —
//! "arrival: stop, replan, redeploy" — is the wrong *cost* model for a
//! multi-tenant deployment: on large clusters the search takes minutes,
//! and blocking stalls every live tenant. The coordinator therefore runs
//! as an event loop in which replanning is a background activity:
//!
//! ```text
//!        Event (Arrive/Exit/churn)          training steps (sim clock)
//!                 │                                   ▲
//!        ┌────────▼──────────┐   step boundary  ┌─────┴────────────┐
//!        │   TaskManager     │  plan swap, diff │  SimTrainLoop    │
//!        │  (apply_event:    │  -charged adjust │  (current plan,  │
//!        │   opens replan)   │─────────────────►│   swappable)     │
//!        │  PlanningSession  │                  └─────▲────────────┘
//!        │   ┌───────────┐   │   pump slice           │ shared LRU
//!        │   │ Anytime   │   │  (budget-metered)      │
//!        │   │ Replan    │◄──┼────────────────────────┘
//!        │   └───────────┘   │   between steps
//!        │   ┌───────────┐   │
//!        │   │ CostTables│   │  tables keyed by (configs, boundaries),
//!        │   │   (LRU)   │   │  shared by search, dispatch and training
//!        │   └───────────┘   │
//!        └───────────────────┘
//! ```
//!
//! * **Events never block.** `TaskManager::apply_event` mutates the task
//!   set and *begins* an [`session::AnytimeReplan`] (superseding a stale
//!   in-flight one). The current deployment keeps training.
//! * **Budgeted anytime search.** The runtime pumps one enumeration slice
//!   between training steps, charging the slice against the replan budget
//!   (wall-clock in production, a deterministic per-plan sim clock in
//!   tests). The search always holds a feasible best-so-far plan; on
//!   budget exhaustion that plan deploys, on completion the result is
//!   plan-identical — bit-identical `expected_step_time` — to a cold
//!   `Planner::plan` (certified by `tests/session_replan.rs` and the
//!   runtime's own identity checks).
//! * **Step-boundary swaps, diff-charged.** Plans swap only between steps;
//!   [`tasks::plan_adjustment`] diffs the `(ParallelConfig, count)` groups
//!   and only changed replicas pay checkpoint+restart — a plan-identical
//!   replan charges zero.
//! * **Warm state persists across everything.** The session's survivor
//!   memo warm-starts the next search; the [`crate::costmodel::CostTables`]
//!   LRU serves the planner's tables, the scheduler's per-step tables and
//!   the serving loop's post-swap tables from one cache.
//!
//! The blocking `TaskManager::handle` survives as the unlimited-budget
//! composition (`apply_event` + one full-budget pump + `finish_replan`), so
//! every pre-runtime caller sees identical plans through the inverted
//! control flow.

pub mod bucketing;
pub mod dispatcher;
pub mod planner;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod session;
pub mod shard;
pub mod tasks;
