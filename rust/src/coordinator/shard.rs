//! Sharded localized replanning: O(change) replan cost for large fleets,
//! and the device-type dimension of the replica-placement search.
//!
//! The global planner re-searches the whole deployment on every tenant
//! event, so replan cost grows with fleet size even when the event touches
//! one tenant. This module partitions tenants into **planning shards**
//! keyed by their sequence-length profile (tasks with similar dominant
//! lengths co-locate, so each shard's bucket grid stays tight), gives each
//! shard its own slice of the cluster's GPU capacity, and runs one
//! [`TaskManager`] — hence one [`crate::coordinator::session::PlanningSession`]
//! — per shard over a *shared* [`CostTables`] LRU. A tenant event replans
//! only its own shard; the other shards' plans (and any in-flight searches
//! they own) are untouched. Per-shard plans compose into the global
//! deployment deterministically: groups merge by configuration and the
//! expected step time is the slowest shard's (shards train concurrently on
//! disjoint GPU slices).
//!
//! With `n_shards <= 1` every call is a bit-exact passthrough to the
//! single inner [`TaskManager`] — same plans, same
//! `expected_step_time` bits, same counters — certified by
//! `tests/shard_replan.rs`.
//!
//! **Device pools.** A mixed-generation fleet ([`ShardManager::new_fleet`])
//! runs one shard per device pool, each with its *own* `(CostModel,
//! ClusterSpec)` world: cost tables are per-device-type (the world
//! fingerprint keys on the [`crate::cluster::DeviceProfile`]), and the
//! placement search gains a device dimension through routing — each task
//! goes to the pool minimizing the Theorem-1 lower bound specialized per
//! type (aggregate assigned work over the pool's aggregate effective
//! FLOPs), with pools whose devices cannot hold the task's longest
//! sequences pruned outright. Inside each pool the ordinary per-world
//! Theorem-1 bound prunes the replica search as before.
//!
//! **Elastic capacity.** Cluster churn (join/leave/preempt) lands here as
//! [`ShardManager::apply_capacity`]: the surviving GPU counts become
//! planner budgets (re-sliced across profile shards with
//! [`capacity_slices`], or applied per pool), budget changes invalidate the
//! affected shards' warm-start memos and reopen their replans, and a
//! restore to full capacity clears the budgets entirely — which is why a
//! shrink→grow round trip re-adopts a plan bit-identical to the
//! never-shrunk cold plan (`tests/elastic_replan.rs`).
//!
//! **Admission classes.** Tenants carry a priority tier
//! ([`crate::config::TaskMeta`], 0 = highest). When an arrival's shard
//! cannot be given enough capacity (the per-shard GPU floors no longer fit
//! the cluster), the manager first tries to **rebalance** capacity across
//! shards ([`capacity_slices`]), then **preempts** strictly
//! lower-priority tenants (numerically higher tier, most recent admission
//! first), and only then **holds** the arrival in an admission queue —
//! never silently rejecting a feasible tenant. Queued and preempted
//! tenants re-enter in (tier, FIFO) order whenever capacity frees up.
//! Planning itself stays tier-blind: tiers decide *who runs*, never how a
//! shard's search scores plans, so plan-identity certificates are
//! unaffected.

use std::collections::BTreeMap;

use crate::cluster::ClusterSpec;
use crate::config::{TaskSet, TaskSpec};
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::session::SliceReport;
use crate::coordinator::tasks::{plan_adjustment, Event, Outcome, TaskManager};
use crate::costmodel::{CostModel, CostTables};
use crate::solver::partition::capacity_slices;
use crate::util::Rng;

/// An arrival held (or a preempted tenant parked) until capacity frees.
#[derive(Debug, Clone)]
struct QueuedArrival {
    spec: TaskSpec,
    /// Queue admission sequence — FIFO order within a tier.
    seq: u64,
}

/// Shard router + per-shard capacity governor + admission control.
pub struct ShardManager<'a> {
    /// Per-shard `(cost model, cluster pool)` world. Profile sharding
    /// replicates one homogeneous world across every shard; device-pool
    /// mode gives each shard its own pool.
    worlds: Vec<(&'a CostModel, &'a ClusterSpec)>,
    /// One shard per device pool, routed by the per-type Theorem-1 bound
    /// instead of the sequence-length profile.
    device_mode: bool,
    opts: PlannerOptions,
    n_shards: usize,
    shards: Vec<TaskManager<'a>>,
    budgets: Vec<Option<u32>>,
    /// Currently *available* capacity under cluster churn. Invariant:
    /// `device_mode` → one entry per pool; otherwise a single entry
    /// holding the fleet total (profile shards slice it).
    capacity: Vec<u32>,
    /// `(gpus, max supported sequence length)` of every feasible
    /// configuration, per shard world — the capacity-floor oracle.
    config_caps: Vec<Vec<(u32, u64)>>,
    /// The composed global plan (single shard: a clone of that shard's).
    composed: Option<DeploymentPlan>,
    queue: Vec<QueuedArrival>,
    next_seq: u64,
    /// Live task name → admission sequence (preemption picks the most
    /// recently admitted among the lowest-priority candidates).
    seqs: BTreeMap<String, u64>,
    /// Budget vector snapshotted at the first capacity shrink from a full
    /// fleet (homogeneous profile sharding only). A restore to full brings
    /// these exact slices back — re-slicing from the live loads would not
    /// reproduce them (fast-path admissions never re-slice), and recovery
    /// identity demands the restored search spaces match the never-shrunk
    /// run bit for bit.
    saved_budgets: Option<Vec<Option<u32>>>,
    /// Arrivals that entered the admission queue (held, not rejected).
    pub queued_admissions: u32,
    /// Tenants evicted to make room for a higher-priority arrival.
    pub preemptions: u32,
    /// Capacity rebalances that actually changed some shard's budget.
    pub rebalances: u32,
}

/// Dominant-length grid for shard routing: tasks whose sampled lengths
/// concentrate in the same band share a shard, keeping each shard's bucket
/// boundaries (and therefore its candidate configurations) tight.
const SHARD_GRID: [u64; 5] = [512, 2048, 8192, 32768, u64::MAX];

/// Deterministically sample a task's length profile. Seeded from the
/// distribution's parameter bits — not the name — so identically
/// distributed tenants always land in the same shard.
fn profile_lengths(spec: &TaskSpec) -> Vec<u32> {
    let mut seed = 0xcbf2_9ce4_8422_2325u64;
    for bits in [
        spec.lengths.mu.to_bits(),
        spec.lengths.sigma.to_bits(),
        spec.lengths.tail_weight.to_bits(),
        spec.lengths.tail_mu.to_bits(),
        spec.lengths.tail_sigma.to_bits(),
        spec.lengths.min_len as u64,
        spec.lengths.max_len as u64,
    ] {
        seed ^= bits;
        seed = seed.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut rng = Rng::new(seed);
    (0..64).map(|_| spec.lengths.sample(&mut rng)).collect()
}

/// The shard a task routes to: dominant bucket of its sampled lengths on
/// the geometric [`SHARD_GRID`], clamped to the shard count (ties break
/// toward the shorter bucket). Pure and deterministic — the same spec
/// always routes identically, across processes and thread counts.
pub fn shard_of(spec: &TaskSpec, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let mut counts = [0usize; SHARD_GRID.len()];
    for l in profile_lengths(spec) {
        let b = SHARD_GRID.partition_point(|&g| g < l as u64).min(SHARD_GRID.len() - 1);
        counts[b] += 1;
    }
    let mut dominant = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[dominant] {
            dominant = i;
        }
    }
    dominant.min(n_shards - 1)
}

/// Conservative upper bound on the longest bucket boundary the planner can
/// derive for a task: the distribution's hard cap plus the interval-grid
/// round-up headroom (`bucketize` widens intervals for very long tails).
fn padded_max_len(spec: &TaskSpec) -> u64 {
    let m = spec.lengths.max_len as u64;
    m + (m / 64).max(512)
}

/// Mean sampled length × batch size: the task's GPU-demand proxy used to
/// split spare capacity proportionally across shards.
fn task_load(spec: &TaskSpec) -> f64 {
    let lengths = profile_lengths(spec);
    let mut total = 0.0f64;
    for l in &lengths {
        total += *l as f64;
    }
    spec.batch_size as f64 * total / lengths.len() as f64
}

/// Smallest configuration (GPUs) in `caps` holding sequences of `len`.
fn min_config_for(caps: &[(u32, u64)], len: u64) -> Option<u32> {
    caps.iter().filter(|&&(_, cap)| cap >= len).map(|&(n, _)| n).min()
}

/// Smallest configuration in `caps` holding a task's longest (padded)
/// sequences, falling back to the un-padded cap when the headroom
/// overshoots every configuration. `None`: this device type can never
/// serve the task.
fn task_floor(caps: &[(u32, u64)], spec: &TaskSpec) -> Option<u32> {
    min_config_for(caps, padded_max_len(spec))
        .or_else(|| min_config_for(caps, spec.lengths.max_len as u64))
}

/// GPU floor of a task set: the smallest configuration serving its longest
/// (padded) sequences; an empty set needs nothing. Falls back to the
/// un-padded requirement when the padding headroom overshoots every
/// configuration.
fn required_floor(caps: &[(u32, u64)], tasks: &TaskSet) -> Option<u32> {
    let mut padded = 0u64;
    let mut raw = 0u64;
    for t in &tasks.tasks {
        padded = padded.max(padded_max_len(t));
        raw = raw.max(t.lengths.max_len as u64);
    }
    if padded == 0 {
        return Some(0);
    }
    min_config_for(caps, padded).or_else(|| min_config_for(caps, raw))
}

/// Total GPU-demand proxy of a task set.
fn shard_load(tasks: &TaskSet) -> f64 {
    let mut load = 0.0f64;
    for t in &tasks.tasks {
        load += task_load(t);
    }
    load
}

/// The per-type Theorem-1 lower bound used as a device-routing score: the
/// aggregate assigned work of a pool over its aggregate effective
/// throughput. No schedule on `gpus` devices of this type can step faster
/// than work/throughput, so greedily minimizing it is LPT makespan
/// assignment across device types.
fn type_bound(work: f64, gpus: u32, pool: &ClusterSpec) -> f64 {
    work / (gpus.max(1) as f64 * pool.effective_flops())
}

/// Why a capacity-sliced admission attempt failed.
enum AdmitFailure {
    /// The per-shard floors (with the newcomer) no longer fit the cluster.
    NoCapacity,
    /// The shard's own planner rejected the arrival (its derived bucket
    /// boundaries exceeded every configuration despite the floor
    /// estimate) — a permanent rejection, not a capacity problem.
    ShardRejected,
}

impl<'a> ShardManager<'a> {
    pub fn new(
        cost: &'a CostModel,
        cluster: &'a ClusterSpec,
        initial: TaskSet,
        opts: PlannerOptions,
        n_shards: usize,
    ) -> Self {
        let n = n_shards.max(1);
        Self::build(vec![(cost, cluster); n], false, initial, opts)
    }

    /// One planning shard per device pool of a mixed-generation fleet.
    /// Each shard plans against its own pool's cost model (per-device-type
    /// cost tables via the world fingerprint); tasks route by the
    /// per-type Theorem-1 bound. A single pool degenerates to the
    /// bit-exact single-shard passthrough.
    pub fn new_fleet(
        pools: Vec<(&'a CostModel, &'a ClusterSpec)>,
        initial: TaskSet,
        opts: PlannerOptions,
    ) -> Self {
        let device_mode = pools.len() > 1;
        Self::build(pools, device_mode, initial, opts)
    }

    fn build(
        worlds: Vec<(&'a CostModel, &'a ClusterSpec)>,
        device_mode: bool,
        initial: TaskSet,
        opts: PlannerOptions,
    ) -> Self {
        assert!(!worlds.is_empty(), "ShardManager needs at least one world");
        let n_shards = worlds.len();
        let config_caps: Vec<Vec<(u32, u64)>> = worlds
            .iter()
            .map(|&(cost, cluster)| {
                Planner::new(cost, cluster)
                    .feasible_configs(opts.allow_cross_server_tp)
                    .into_iter()
                    .map(|c| (c.n(), cost.max_seq_len(c)))
                    .collect()
            })
            .collect();

        // Partition the initial set: device pools by the per-type bound,
        // profile shards by dominant length.
        let mut parts: Vec<TaskSet> = (0..n_shards).map(|_| TaskSet::default()).collect();
        for t in initial.tasks {
            let dest = if device_mode {
                let mut best: Option<(f64, usize)> = None;
                for p in 0..n_shards {
                    if task_floor(&config_caps[p], &t).is_none() {
                        continue;
                    }
                    let mut work = task_load(&t);
                    for prev in &parts[p].tasks {
                        work += task_load(prev);
                    }
                    let bound = type_bound(work, worlds[p].1.n_gpus, worlds[p].1);
                    if best.map_or(true, |(b, _)| bound.total_cmp(&b).is_lt()) {
                        best = Some((bound, p));
                    }
                }
                // a task no pool can serve goes to pool 0, whose manager
                // rejects it with the usual infeasible-arrival rule
                best.map_or(0, |(_, p)| p)
            } else {
                shard_of(&t, n_shards)
            };
            parts[dest].tasks.push(t);
        }

        // Initial capacity: full fleet. A single shard (or each device
        // pool) searches its whole world — budget None, the bit-identical
        // cold path; profile shards slice the fleet total.
        let capacity: Vec<u32> = if device_mode {
            worlds.iter().map(|&(_, cl)| cl.n_gpus).collect()
        } else {
            vec![worlds[0].1.n_gpus]
        };
        let budgets: Vec<Option<u32>> = if device_mode || n_shards <= 1 {
            vec![None; n_shards]
        } else {
            let floors: Vec<u32> = parts
                .iter()
                .map(|p| required_floor(&config_caps[0], p).unwrap_or(0))
                .collect();
            let loads: Vec<f64> = parts.iter().map(shard_load).collect();
            match capacity_slices(capacity[0], &loads, &floors) {
                Some(slices) => slices.into_iter().map(Some).collect(),
                // Infeasible initial set: equal split; the per-shard
                // managers reject what they cannot serve.
                None => {
                    let each = (capacity[0] / n_shards as u32).max(1);
                    vec![Some(each); n_shards]
                }
            }
        };

        let tables = CostTables::default();
        let mut seqs = BTreeMap::new();
        let mut next_seq = 0u64;
        let shards: Vec<TaskManager<'a>> = parts
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                for t in &p.tasks {
                    seqs.insert(t.name.clone(), next_seq);
                    next_seq += 1;
                }
                let mut shard_opts = opts.clone();
                shard_opts.gpu_budget = budgets[i];
                TaskManager::with_tables(
                    worlds[i].0,
                    worlds[i].1,
                    p,
                    shard_opts,
                    tables.clone(),
                )
            })
            .collect();

        let mut mgr = Self {
            worlds,
            device_mode,
            opts,
            n_shards,
            shards,
            budgets,
            capacity,
            config_caps,
            composed: None,
            queue: Vec::new(),
            next_seq,
            seqs,
            saved_budgets: None,
            queued_admissions: 0,
            preemptions: 0,
            rebalances: 0,
        };
        mgr.recompose();
        mgr
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Device-pool mode (one shard per GPU generation)?
    pub fn device_mode(&self) -> bool {
        self.device_mode
    }

    /// Shard `i`'s `(cost model, cluster pool)` world.
    pub fn shard_world(&self, i: usize) -> (&'a CostModel, &'a ClusterSpec) {
        self.worlds[i]
    }

    /// The per-shard managers (counters, sessions, plans) — read-only.
    pub fn shards(&self) -> &[TaskManager<'a>] {
        &self.shards
    }

    /// Current GPU budget of shard `i` (`None`: its whole world).
    pub fn gpu_budget(&self, i: usize) -> Option<u32> {
        self.budgets.get(i).copied().flatten()
    }

    /// Shard `i`'s live task set (the async service submits this).
    pub fn shard_tasks(&self, i: usize) -> &TaskSet {
        self.shards[i].tasks()
    }

    /// Shard `i`'s current deployment plan (device mode: the pool's
    /// sub-plan, driving that pool's training loop).
    pub fn shard_plan(&self, i: usize) -> Option<&DeploymentPlan> {
        self.shards[i].plan()
    }

    /// Arrivals currently held in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Currently available fleet capacity (GPUs up across all pools).
    pub fn total_capacity(&self) -> u32 {
        let mut total = 0;
        for c in &self.capacity {
            total += *c;
        }
        total
    }

    /// Every live task across all shards, shard-major order — the global
    /// training task set. For a single shard this is exactly the inner
    /// manager's set.
    pub fn fleet_tasks(&self) -> TaskSet {
        let mut out = TaskSet::default();
        for m in &self.shards {
            out.tasks.extend(m.tasks().tasks.iter().cloned());
        }
        out
    }

    /// The composed global deployment plan.
    pub fn plan(&self) -> Option<&DeploymentPlan> {
        self.composed.as_ref()
    }

    /// The shared cost-table LRU (one cache across every shard — in device
    /// mode each world keys its own tables inside it).
    pub fn tables(&self) -> CostTables {
        self.shards[0].tables()
    }

    /// Per-replica restart charge, pushed into every shard manager.
    pub fn set_restart_seconds(&mut self, seconds: f64) {
        for m in &mut self.shards {
            m.restart_seconds_per_replica = seconds;
        }
    }

    fn restart_seconds(&self) -> f64 {
        self.shards[0].restart_seconds_per_replica
    }

    /// Total replans across all shards.
    pub fn replans_total(&self) -> u32 {
        self.shards.iter().map(|m| m.replans).sum()
    }

    /// Total redeploys across all shards.
    pub fn redeploys_total(&self) -> u32 {
        self.shards.iter().map(|m| m.redeploys).sum()
    }

    /// Any shard has an open (begun, unadopted) replan.
    pub fn replan_pending(&self) -> bool {
        self.shards.iter().any(TaskManager::replan_pending)
    }

    /// Every open replan has finished its enumeration (shards whose
    /// planning context was infeasible have nothing to pump and count as
    /// finished — adopting them drains that shard only).
    pub fn replan_done(&self) -> bool {
        self.shards
            .iter()
            .all(|m| !m.replan_pending() || m.replan_done() || !m.replan_searching())
    }

    /// Priority tier of a live task, if any shard holds it.
    fn live_tier(&self, name: &str) -> Option<u8> {
        for m in &self.shards {
            if let Some(t) = m.tasks().tasks.iter().find(|t| t.name == name) {
                return Some(t.meta.tier);
            }
        }
        None
    }

    fn shard_of_live(&self, name: &str) -> Option<usize> {
        self.shards
            .iter()
            .position(|m| m.tasks().tasks.iter().any(|t| t.name == name))
    }

    fn fleet_empty(&self) -> bool {
        self.shards.iter().all(|m| m.tasks().is_empty())
    }

    /// Smallest configuration (GPUs) on *any* pool that can hold sequences
    /// of `len` — `None` means no device type ever serves it.
    fn required_gpus(&self, len: u64) -> Option<u32> {
        self.config_caps
            .iter()
            .filter_map(|caps| min_config_for(caps, len))
            .min()
    }

    /// GPU floor for a shard extended by an optional newcomer.
    fn floor_with(&self, shard: usize, extra: Option<&TaskSpec>) -> Option<u32> {
        let caps = &self.config_caps[shard];
        let mut padded = 0u64;
        let mut raw = 0u64;
        for t in self.shards[shard].tasks().tasks.iter().chain(extra) {
            padded = padded.max(padded_max_len(t));
            raw = raw.max(t.lengths.max_len as u64);
        }
        if padded == 0 {
            return Some(0);
        }
        min_config_for(caps, padded).or_else(|| min_config_for(caps, raw))
    }

    fn load_with(&self, shard: usize, extra: Option<&TaskSpec>) -> f64 {
        let mut load = 0.0f64;
        for t in self.shards[shard].tasks().tasks.iter().chain(extra) {
            load += task_load(t);
        }
        load
    }

    /// Apply one tenant event at fleet level. Non-blocking, like
    /// [`TaskManager::apply_event`]: opened replans are pumped by the
    /// caller and adopted at a step boundary. Cluster capacity events are
    /// resolved by the serving runtime into [`Self::apply_capacity`] and
    /// never arrive here.
    pub fn apply_event(&mut self, event: Event) -> Outcome {
        match event {
            Event::Arrive(spec) => self.arrive(spec),
            Event::Exit { name } => self.exit(&name),
            Event::NodeJoin { .. } | Event::NodeLeave { .. } | Event::Preempt { .. } => {
                Outcome::Unchanged
            }
        }
    }

    fn passthrough(&mut self, event: Event) -> Outcome {
        let out = self.shards[0].apply_event(event);
        let out = match out {
            Outcome::Planning { .. } => Outcome::Planning { opened: vec![0] },
            other => other,
        };
        if out == Outcome::Drained {
            self.recompose();
        }
        out
    }

    fn arrive(&mut self, spec: TaskSpec) -> Outcome {
        if self.n_shards <= 1 {
            return self.passthrough(Event::Arrive(spec));
        }
        if self.seqs.contains_key(&spec.name)
            || self.queue.iter().any(|q| q.spec.name == spec.name)
        {
            // duplicate names make exits ambiguous — same rule as the
            // global manager, extended to cover held arrivals
            return Outcome::Rejected;
        }
        if self.required_gpus(spec.lengths.max_len as u64).is_none() {
            // no configuration on any pool ever serves it: a permanent
            // rejection, not a hold
            return Outcome::Rejected;
        }
        match self.try_admit(&spec) {
            Ok(opened) => Outcome::Planning { opened },
            Err(AdmitFailure::ShardRejected) => Outcome::Rejected,
            Err(AdmitFailure::NoCapacity) => {
                let mut opened: Vec<usize> = Vec::new();
                loop {
                    let Some(victim) = self.preemption_victim(spec.meta.tier) else {
                        break;
                    };
                    if let Some(s) = self.evict(&victim) {
                        opened.push(s);
                    }
                    match self.try_admit(&spec) {
                        Ok(more) => {
                            opened.extend(more);
                            opened.sort_unstable();
                            opened.dedup();
                            return Outcome::Planning { opened };
                        }
                        Err(AdmitFailure::ShardRejected) => {
                            // permanently unservable: same terminal answer
                            // the global manager gives (the evictions
                            // stand — their searches are already open)
                            return Outcome::Rejected;
                        }
                        Err(AdmitFailure::NoCapacity) => continue,
                    }
                }
                self.enqueue(spec);
                self.queued_admissions += 1;
                if opened.is_empty() {
                    Outcome::Queued
                } else {
                    // preemptions landed but the arrival still waits: the
                    // opened shards must be pumped and adopted
                    opened.sort_unstable();
                    opened.dedup();
                    Outcome::Planning { opened }
                }
            }
        }
    }

    fn exit(&mut self, name: &str) -> Outcome {
        if self.n_shards <= 1 {
            return self.passthrough(Event::Exit { name: name.to_string() });
        }
        if let Some(pos) = self.queue.iter().position(|q| q.spec.name == name) {
            // a held tenant withdrew before ever being admitted
            self.queue.remove(pos);
            return Outcome::Unchanged;
        }
        let Some(s) = self.shard_of_live(name) else {
            return Outcome::Unchanged;
        };
        let mut opened: Vec<usize> = Vec::new();
        let mut drained_shard = false;
        match self.shards[s].apply_event(Event::Exit { name: name.to_string() }) {
            Outcome::Planning { .. } => opened.push(s),
            Outcome::Drained => drained_shard = true,
            _ => {}
        }
        self.seqs.remove(name);
        // freed capacity: re-admit held arrivals, highest priority first
        opened.extend(self.drain_queue());
        opened.sort_unstable();
        opened.dedup();
        if self.fleet_empty() && self.queue.is_empty() && opened.is_empty() {
            self.recompose();
            return Outcome::Drained;
        }
        if opened.is_empty() && !drained_shard {
            return Outcome::Unchanged;
        }
        // a drained shard with no reopened searches still needs a
        // finish-replan pass to re-adopt the shrunken composed plan
        Outcome::Planning { opened }
    }

    /// Device-type placement for one arrival: among pools whose device can
    /// hold the task (and whose available capacity covers the shard's
    /// floor with it), pick the one minimizing the per-type Theorem-1
    /// bound. `None`: no pool currently has the capacity (the caller
    /// preempts or queues).
    fn device_route(&self, spec: &TaskSpec) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for p in 0..self.n_shards {
            if task_floor(&self.config_caps[p], spec).is_none() {
                continue;
            }
            let avail = self.capacity[p];
            match self.floor_with(p, Some(spec)) {
                Some(floor) if floor <= avail => {}
                _ => continue,
            }
            let work = self.load_with(p, Some(spec));
            let bound = type_bound(work, avail, self.worlds[p].1);
            if best.map_or(true, |(b, _)| bound.total_cmp(&b).is_lt()) {
                best = Some((bound, p));
            }
        }
        best.map(|(_, p)| p)
    }

    /// Capacity-sliced admission. On success returns the shards that
    /// opened a replan (the target shard plus any shard whose budget
    /// changed and restarted its search).
    ///
    /// The **fast path** keeps replan cost O(change): when the newcomer's
    /// shard can already serve it within its current slice, only that
    /// shard replans — no other shard's budget (or in-flight search) is
    /// touched. The full re-slice runs only when the shard's floor
    /// outgrows its slice. Device pools never re-slice (their capacity is
    /// hardware): the arrival either fits its routed pool or waits.
    fn try_admit(&mut self, spec: &TaskSpec) -> Result<Vec<usize>, AdmitFailure> {
        if self.device_mode {
            let s = self.device_route(spec).ok_or(AdmitFailure::NoCapacity)?;
            return match self.shards[s].apply_event(Event::Arrive(spec.clone())) {
                Outcome::Planning { .. } => {
                    self.seqs.insert(spec.name.clone(), self.next_seq);
                    self.next_seq += 1;
                    Ok(vec![s])
                }
                _ => Err(AdmitFailure::ShardRejected),
            };
        }
        let s = shard_of(spec, self.n_shards);
        let floor_s = self.floor_with(s, Some(spec)).ok_or(AdmitFailure::NoCapacity)?;
        let current = self.budgets[s].unwrap_or(self.capacity[0]);
        if floor_s <= current {
            return match self.shards[s].apply_event(Event::Arrive(spec.clone())) {
                Outcome::Planning { .. } => {
                    self.seqs.insert(spec.name.clone(), self.next_seq);
                    self.next_seq += 1;
                    Ok(vec![s])
                }
                _ => Err(AdmitFailure::ShardRejected),
            };
        }
        let mut floors = Vec::with_capacity(self.n_shards);
        let mut loads = Vec::with_capacity(self.n_shards);
        for i in 0..self.n_shards {
            let extra = (i == s).then_some(spec);
            floors.push(self.floor_with(i, extra).ok_or(AdmitFailure::NoCapacity)?);
            loads.push(self.load_with(i, extra));
        }
        let slices = capacity_slices(self.capacity[0], &loads, &floors)
            .ok_or(AdmitFailure::NoCapacity)?;

        // Admit into the target shard first, under its new slice — if the
        // shard's planner still rejects (bucket boundaries beyond every
        // configuration), nothing else has been touched.
        let old_budget = self.budgets[s];
        self.shards[s].set_gpu_budget(Some(slices[s]));
        self.budgets[s] = Some(slices[s]);
        match self.shards[s].apply_event(Event::Arrive(spec.clone())) {
            Outcome::Planning { .. } => {}
            _ => {
                self.shards[s].set_gpu_budget(old_budget);
                self.budgets[s] = old_budget;
                return Err(AdmitFailure::ShardRejected);
            }
        }
        self.seqs.insert(spec.name.clone(), self.next_seq);
        self.next_seq += 1;

        let mut opened = vec![s];
        for i in 0..self.n_shards {
            if i == s {
                continue;
            }
            let b = Some(slices[i]);
            if self.budgets[i] != b {
                self.shards[i].set_gpu_budget(b);
                self.budgets[i] = b;
                if self.shards[i].reopen_replan() {
                    opened.push(i);
                }
            }
        }
        opened.sort_unstable();
        opened.dedup();
        Ok(opened)
    }

    /// The most recently admitted tenant among those with a strictly lower
    /// priority than `tier` (numerically greater). Deterministic: ties
    /// cannot occur, admission sequences are unique.
    fn preemption_victim(&self, tier: u8) -> Option<String> {
        let mut best: Option<(u8, u64, String)> = None;
        for m in &self.shards {
            for t in &m.tasks().tasks {
                if t.meta.tier <= tier {
                    continue;
                }
                let seq = self.seqs.get(&t.name).copied().unwrap_or(0);
                let better = match &best {
                    None => true,
                    Some((bt, bs, _)) => {
                        (t.meta.tier, seq) > (*bt, *bs)
                    }
                };
                if better {
                    best = Some((t.meta.tier, seq, t.name.clone()));
                }
            }
        }
        best.map(|(_, _, name)| name)
    }

    /// Evict a live tenant back into the admission queue (it re-enters in
    /// tier order behind its peers). Returns the shard that opened a
    /// replan, if the eviction left it non-empty.
    fn evict(&mut self, name: &str) -> Option<usize> {
        let s = self.shard_of_live(name)?;
        let spec = self.shards[s]
            .tasks()
            .tasks
            .iter()
            .find(|t| t.name == name)?
            .clone();
        let out = self.shards[s].apply_event(Event::Exit { name: name.to_string() });
        self.seqs.remove(name);
        self.enqueue(spec);
        self.preemptions += 1;
        matches!(out, Outcome::Planning { .. }).then_some(s)
    }

    fn enqueue(&mut self, spec: TaskSpec) {
        self.queue.push(QueuedArrival { spec, seq: self.next_seq });
        self.next_seq += 1;
    }

    /// Try to admit held arrivals in (tier, FIFO) order. Strict priority:
    /// the first arrival that still does not fit blocks the rest of the
    /// queue (no backfilling past a waiting higher-priority tenant).
    fn drain_queue(&mut self) -> Vec<usize> {
        let mut opened = Vec::new();
        loop {
            let Some(pos) = self
                .queue
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| (q.spec.meta.tier, q.seq))
                .map(|(i, _)| i)
            else {
                break;
            };
            let spec = self.queue[pos].spec.clone();
            match self.try_admit(&spec) {
                Ok(more) => {
                    self.queue.remove(pos);
                    opened.extend(more);
                }
                Err(AdmitFailure::ShardRejected) => {
                    // permanently unservable from the queue — drop it
                    // rather than wedging every lower-priority arrival
                    self.queue.remove(pos);
                }
                Err(AdmitFailure::NoCapacity) => break,
            }
        }
        opened
    }

    /// Apply the fleet's surviving capacity after cluster churn: one
    /// available-GPU count per device pool (a homogeneous fleet passes a
    /// single total). Changed budgets invalidate the affected shards'
    /// warm-start memos and reopen their replans; a restore to full
    /// capacity clears the budgets, so the next adoption is certified
    /// bit-identical to the never-shrunk cold plan. Returns the shards
    /// that opened a replan.
    pub fn apply_capacity(&mut self, avail: &[u32]) -> Vec<usize> {
        let mut opened: Vec<usize> = Vec::new();
        if self.device_mode {
            for p in 0..self.n_shards {
                let a = avail.get(p).copied().unwrap_or(self.capacity[p]);
                if self.capacity[p] == a {
                    continue;
                }
                self.capacity[p] = a;
                let b = (a < self.worlds[p].1.n_gpus).then_some(a);
                if self.budgets[p] != b {
                    self.shards[p].set_gpu_budget(b);
                    self.budgets[p] = b;
                    if self.shards[p].reopen_replan() {
                        opened.push(p);
                    }
                }
            }
        } else {
            let mut total = 0u32;
            for a in avail {
                total += *a;
            }
            if self.capacity[0] == total {
                return Vec::new();
            }
            self.capacity[0] = total;
            if self.n_shards <= 1 {
                let b = (total < self.worlds[0].1.n_gpus).then_some(total);
                if self.budgets[0] != b {
                    self.shards[0].set_gpu_budget(b);
                    self.budgets[0] = b;
                    if self.shards[0].reopen_replan() {
                        opened.push(0);
                    }
                }
            } else if total >= self.worlds[0].1.n_gpus {
                // full restore: bring back the exact pre-shrink slices —
                // unless churn during the degraded period outgrew one of
                // them, in which case re-slice from the live loads
                let saved = self.saved_budgets.take();
                let restorable = saved.filter(|b| {
                    (0..self.n_shards).all(|i| match b[i] {
                        Some(cap) => {
                            self.floor_with(i, None).is_some_and(|f| f <= cap)
                        }
                        None => true,
                    })
                });
                match restorable {
                    Some(b) => {
                        for i in 0..self.n_shards {
                            if self.budgets[i] != b[i] {
                                self.shards[i].set_gpu_budget(b[i]);
                                self.budgets[i] = b[i];
                                if self.shards[i].reopen_replan() {
                                    opened.push(i);
                                }
                            }
                        }
                    }
                    None => opened.extend(self.reslice()),
                }
            } else {
                // shrink (or partial restore): snapshot the full-capacity
                // slices once, then re-slice the survivors
                if self.saved_budgets.is_none() {
                    self.saved_budgets = Some(self.budgets.clone());
                }
                opened.extend(self.reslice());
            }
        }
        opened.extend(self.drain_queue());
        opened.sort_unstable();
        opened.dedup();
        opened
    }

    /// Recompute the proportional capacity slices of the profile shards
    /// from the live load profile against the currently available fleet
    /// total, restarting searches of shards whose budget changed.
    fn reslice(&mut self) -> Vec<usize> {
        let mut floors = Vec::with_capacity(self.n_shards);
        let mut loads = Vec::with_capacity(self.n_shards);
        for i in 0..self.n_shards {
            let Some(f) = self.floor_with(i, None) else {
                return Vec::new();
            };
            floors.push(f);
            loads.push(self.load_with(i, None));
        }
        let Some(slices) = capacity_slices(self.capacity[0], &loads, &floors) else {
            return Vec::new();
        };
        let mut opened = Vec::new();
        for i in 0..self.n_shards {
            let b = Some(slices[i]);
            if self.budgets[i] != b {
                self.shards[i].set_gpu_budget(b);
                self.budgets[i] = b;
                if self.shards[i].reopen_replan() {
                    opened.push(i);
                }
            }
        }
        opened
    }

    /// Periodic capacity rebalance: recompute the proportional slices from
    /// the live load profile and restart the searches of shards whose
    /// budget changed, then re-try held arrivals. Returns the shards that
    /// opened a replan (empty: capacity was already balanced). Device
    /// pools have nothing to rebalance — their capacity is hardware.
    pub fn rebalance(&mut self) -> Vec<usize> {
        if self.n_shards <= 1 || self.device_mode {
            return Vec::new();
        }
        let before = self.budgets.clone();
        let mut opened = self.reslice();
        if self.budgets != before {
            self.rebalances += 1;
        }
        opened.extend(self.drain_queue());
        opened.sort_unstable();
        opened.dedup();
        opened
    }

    /// Advance the first unfinished open replan by one enumeration slice.
    /// The returned report's `done` covers the whole fleet: true only when
    /// *every* open shard finished. `None` when nothing is pumpable.
    pub fn pump_replan(&mut self, slice_plans: usize) -> Option<SliceReport> {
        if self.n_shards <= 1 {
            return self.shards[0].pump_replan(slice_plans);
        }
        for i in 0..self.shards.len() {
            if self.shards[i].replan_pending() && !self.shards[i].replan_done() {
                if let Some(mut r) = self.shards[i].pump_replan(slice_plans) {
                    r.done = self.replan_done();
                    return Some(r);
                }
            }
        }
        None
    }

    /// Adopt every open shard's replan at a step boundary and diff the
    /// *composed* plan — only replica groups that actually changed across
    /// the whole fleet pay checkpoint+restart.
    pub fn finish_replan(&mut self) -> Outcome {
        if self.n_shards <= 1 {
            let out = self.shards[0].finish_replan();
            self.recompose();
            return out;
        }
        let before = self.composed.clone();
        for m in &mut self.shards {
            if m.replan_pending() {
                m.finish_replan();
            }
        }
        self.recompose();
        self.outcome_between(before)
    }

    /// Adopt a plan computed by the async planner service for one shard
    /// (the sharded analogue of [`TaskManager::finish_replan_with`]). The
    /// outcome diffs the composed plan, so each shard's adoption charges
    /// only the groups it changed.
    pub fn finish_shard_with(
        &mut self,
        shard: usize,
        plan: Option<DeploymentPlan>,
    ) -> Outcome {
        if self.n_shards <= 1 {
            let out = self.shards[0].finish_replan_with(plan);
            self.recompose();
            return out;
        }
        let before = self.composed.clone();
        self.shards[shard].finish_replan_with(plan);
        self.recompose();
        self.outcome_between(before)
    }

    /// Diff the freshly recomposed plan against `before` into a
    /// fleet-level outcome (mirrors the single-manager accounting).
    fn outcome_between(&self, before: Option<DeploymentPlan>) -> Outcome {
        let per_replica = self.restart_seconds();
        match (&before, &self.composed) {
            (Some(a), Some(b)) if a.groups == b.groups => Outcome::Unchanged,
            (Some(a), Some(b)) => {
                let adjustment = plan_adjustment(a, b);
                Outcome::Redeployed {
                    adjustment_seconds: adjustment.seconds(per_replica),
                    adjustment,
                }
            }
            (None, Some(b)) => {
                let fresh = DeploymentPlan {
                    groups: Vec::new(),
                    n_tasks: b.n_tasks,
                    expected_step_time: 0.0,
                };
                let adjustment = plan_adjustment(&fresh, b);
                Outcome::Redeployed {
                    adjustment_seconds: adjustment.seconds(per_replica),
                    adjustment,
                }
            }
            (_, None) => Outcome::Drained,
        }
    }

    /// Rebuild the composed global plan from the per-shard plans: groups
    /// merge by configuration (sorted by `(gpus, tp)` like the planner's
    /// own output), task counts add, and the expected step time is the
    /// slowest shard's — shards train concurrently on disjoint capacity
    /// (device pools synchronize LoRA gradients at the fleet step
    /// boundary, so the fleet step is the slowest pool's).
    fn recompose(&mut self) {
        if self.n_shards <= 1 {
            self.composed = self.shards[0].plan().cloned();
            return;
        }
        let mut groups: BTreeMap<crate::config::ParallelConfig, u32> = BTreeMap::new();
        let mut n_tasks = 0u32;
        let mut step = 0.0f64;
        let mut any = false;
        for m in &self.shards {
            if let Some(p) = m.plan() {
                any = true;
                for &(c, k) in &p.groups {
                    *groups.entry(c).or_default() += k;
                }
                n_tasks += p.n_tasks;
                step = step.max(p.expected_step_time);
            }
        }
        self.composed = any.then(|| {
            let mut g: Vec<_> = groups.into_iter().collect();
            g.sort_by_key(|&(c, _)| (c.n(), c.tp));
            DeploymentPlan { groups: g, n_tasks, expected_step_time: step }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;
    use crate::data::LengthDistribution;

    fn world(n: u32) -> (CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_40g(n);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        (cost, cluster)
    }

    fn fast_opts() -> PlannerOptions {
        let mut o = PlannerOptions::default();
        o.calibration_multiple = 20;
        o.eval_batches = 1;
        o.max_evaluated = 100;
        o
    }

    fn short(name: &str) -> TaskSpec {
        TaskSpec::new(name, 64, LengthDistribution::fit(210.0, 6.0, 16, 2048))
    }

    fn long(name: &str) -> TaskSpec {
        TaskSpec::new(name, 32, LengthDistribution::fit(3600.0, 4.3, 16, 16384))
    }

    #[test]
    fn shard_routing_is_deterministic_and_length_keyed() {
        let s = short("a");
        let l = long("b");
        assert_eq!(shard_of(&s, 4), shard_of(&short("renamed"), 4), "name-blind");
        assert_eq!(shard_of(&s, 1), 0);
        assert!(shard_of(&l, 4) >= shard_of(&s, 4), "longer profile, later shard");
        // clamped to the shard count
        assert!(shard_of(&l, 2) <= 1);
    }

    #[test]
    fn single_shard_matches_global_manager() {
        let (cost, cluster) = world(16);
        let opts = fast_opts();
        let initial = TaskSet::new(vec![short("a"), long("b")]);
        let mut sharded =
            ShardManager::new(&cost, &cluster, initial.clone(), opts.clone(), 1);
        let mut global = TaskManager::new(&cost, &cluster, initial, opts);
        let sp = sharded.plan().expect("sharded plan");
        let gp = global.plan().expect("global plan");
        assert_eq!(sp.groups, gp.groups);
        assert_eq!(
            sp.expected_step_time.to_bits(),
            gp.expected_step_time.to_bits()
        );
        // event passthrough: same outcome class, same adopted plan
        let ev = Event::Arrive(short("c"));
        assert_eq!(
            sharded.apply_event(ev.clone()),
            Outcome::Planning { opened: vec![0] }
        );
        assert!(matches!(global.apply_event(ev), Outcome::Planning { .. }));
        loop {
            let r = sharded.pump_replan(64).expect("pending");
            if r.done {
                break;
            }
        }
        loop {
            let r = global.pump_replan(64).expect("pending");
            if r.done {
                break;
            }
        }
        sharded.finish_replan();
        global.finish_replan();
        let sp = sharded.plan().expect("sharded plan");
        let gp = global.plan().expect("global plan");
        assert_eq!(sp.groups, gp.groups);
        assert_eq!(
            sp.expected_step_time.to_bits(),
            gp.expected_step_time.to_bits()
        );
    }

    #[test]
    fn localized_event_replans_only_its_shard() {
        let (cost, cluster) = world(32);
        let initial = TaskSet::new(vec![short("s1"), short("s2"), long("l1")]);
        let mut mgr = ShardManager::new(&cost, &cluster, initial, fast_opts(), 2);
        assert!(mgr.plan().is_some());
        let replans_before: Vec<u32> = mgr.shards().iter().map(|m| m.replans).collect();
        // a short arrival routes to shard 0; shard 1 must stay untouched
        let out = mgr.apply_event(Event::Arrive(short("s3")));
        let Outcome::Planning { opened } = out else {
            panic!("expected planning, got {out:?}");
        };
        assert!(opened.contains(&0), "{opened:?}");
        while let Some(r) = mgr.pump_replan(10_000) {
            if r.done {
                break;
            }
        }
        mgr.finish_replan();
        let replans_after: Vec<u32> = mgr.shards().iter().map(|m| m.replans).collect();
        assert!(replans_after[0] > replans_before[0]);
        if !opened.contains(&1) {
            assert_eq!(replans_after[1], replans_before[1], "shard 1 replanned");
        }
        // the composed plan covers all four tasks
        assert_eq!(mgr.plan().expect("plan").n_tasks, 4);
        assert_eq!(mgr.fleet_tasks().len(), 4);
    }

    #[test]
    fn composed_plan_fits_cluster_and_is_sorted() {
        let (cost, cluster) = world(32);
        let initial = TaskSet::new(vec![short("a"), short("b"), long("c"), long("d")]);
        let mgr = ShardManager::new(&cost, &cluster, initial, fast_opts(), 3);
        let plan = mgr.plan().expect("composed plan");
        let gpus: u32 = plan.groups.iter().map(|&(c, k)| c.n() * k).sum();
        assert!(gpus <= cluster.n_gpus, "{gpus} > {}", cluster.n_gpus);
        for w in plan.groups.windows(2) {
            assert!(
                (w[0].0.n(), w[0].0.tp) <= (w[1].0.n(), w[1].0.tp),
                "groups unsorted: {:?}",
                plan.groups
            );
        }
        assert!(plan.expected_step_time > 0.0);
    }

    #[test]
    fn preemption_and_queueing_respect_tiers() {
        let (cost, cluster) = world(16);
        // fill the cluster with low-priority long-profile tenants
        let initial = TaskSet::new(vec![
            long("bg-1").with_tier(3),
            long("bg-2").with_tier(3),
        ]);
        let mut mgr = ShardManager::new(&cost, &cluster, initial, fast_opts(), 2);
        // a same-tier arrival must never preempt its peers
        let out = mgr.apply_event(Event::Arrive(long("peer").with_tier(3)));
        assert_eq!(mgr.preemptions, 0, "same tier preempted: {out:?}");
        // queue withdrawal is clean
        if out == Outcome::Queued {
            assert_eq!(
                mgr.apply_event(Event::Exit { name: "peer".into() }),
                Outcome::Unchanged
            );
            assert_eq!(mgr.queue_len(), 0);
        }
        // duplicates are rejected even while held in the queue
        let dup = mgr.apply_event(Event::Arrive(long("bg-1").with_tier(0)));
        assert_eq!(dup, Outcome::Rejected);
    }

    #[test]
    fn drained_shard_shrinks_composed_plan() {
        let (cost, cluster) = world(32);
        let initial = TaskSet::new(vec![short("a"), long("b")]);
        let mut mgr = ShardManager::new(&cost, &cluster, initial, fast_opts(), 2);
        let before = mgr.plan().expect("plan").clone();
        let out = mgr.apply_event(Event::Exit { name: "b".into() });
        let Outcome::Planning { opened } = out else {
            panic!("expected planning, got {out:?}");
        };
        while let Some(r) = mgr.pump_replan(10_000) {
            if r.done {
                break;
            }
        }
        let fin = mgr.finish_replan();
        let after = mgr.plan().expect("plan").clone();
        assert_eq!(after.n_tasks, 1);
        assert_ne!(before.groups, after.groups, "{opened:?} / {fin:?}");
        // fleet-level drain
        let out = mgr.apply_event(Event::Exit { name: "a".into() });
        assert_eq!(out, Outcome::Drained);
        assert!(mgr.plan().is_none());
        assert!(mgr.fleet_empty());
    }

    #[test]
    fn capacity_shrink_and_restore_round_trips_budgets() {
        let (cost, cluster) = world(16);
        let initial = TaskSet::new(vec![short("a"), short("b")]);
        let mut mgr =
            ShardManager::new(&cost, &cluster, initial, fast_opts(), 1);
        assert_eq!(mgr.gpu_budget(0), None);
        let full = mgr.plan().expect("plan").clone();

        // shrink to 12 GPUs: the budget clamps the search and a replan opens
        let opened = mgr.apply_capacity(&[12]);
        assert_eq!(opened, vec![0]);
        assert_eq!(mgr.gpu_budget(0), Some(12));
        assert_eq!(mgr.total_capacity(), 12);
        while let Some(r) = mgr.pump_replan(10_000) {
            if r.done {
                break;
            }
        }
        mgr.finish_replan();
        let shrunk = mgr.plan().expect("plan").clone();
        let gpus: u32 = shrunk.groups.iter().map(|&(c, k)| c.n() * k).sum();
        assert!(gpus <= 12, "shrunk plan uses {gpus} > 12 GPUs");

        // restoring full capacity clears the budget entirely
        let opened = mgr.apply_capacity(&[16]);
        assert_eq!(opened, vec![0]);
        assert_eq!(mgr.gpu_budget(0), None);
        while let Some(r) = mgr.pump_replan(10_000) {
            if r.done {
                break;
            }
        }
        mgr.finish_replan();
        let restored = mgr.plan().expect("plan").clone();
        assert_eq!(restored.groups, full.groups, "recovery identity");
        assert_eq!(
            restored.expected_step_time.to_bits(),
            full.expected_step_time.to_bits()
        );

        // no-op capacity application opens nothing
        assert!(mgr.apply_capacity(&[16]).is_empty());
    }

    #[test]
    fn device_pools_route_by_type_bound_and_key_separate_tables() {
        let a100 = ClusterSpec::a100_40g(8);
        let h100 = ClusterSpec::h100_80g(8);
        let model = ModelDesc::llama2_7b();
        let cost_a = CostModel::calibrated(&model, &a100);
        let cost_h = CostModel::calibrated(&model, &h100);
        let initial = TaskSet::new(vec![short("s1"), long("l1")]);
        let mgr = ShardManager::new_fleet(
            vec![(&cost_a, &a100), (&cost_h, &h100)],
            initial,
            fast_opts(),
        );
        assert!(mgr.device_mode());
        assert_eq!(mgr.n_shards(), 2);
        // per-device-type cost tables: the two worlds key differently
        use crate::costmodel::world_fingerprint;
        assert_ne!(
            world_fingerprint(&model, &a100),
            world_fingerprint(&model, &h100)
        );
        // the composed plan draws from both pools and fits the fleet
        let plan = mgr.plan().expect("fleet plan");
        assert_eq!(plan.n_tasks, 2);
        let gpus: u32 = plan.groups.iter().map(|&(c, k)| c.n() * k).sum();
        assert!(gpus <= 16);
        // both pools were actually planned (each holds at least one task,
        // since the second task routes to the emptier pool by the bound)
        let assigned: Vec<usize> =
            (0..2).map(|i| mgr.shard_tasks(i).len()).collect();
        assert_eq!(assigned.iter().sum::<usize>(), 2);
        assert!(assigned.iter().all(|&n| n == 1), "{assigned:?}");
    }

    #[test]
    fn device_pool_preempt_shrinks_only_that_pool() {
        let a100 = ClusterSpec::a100_40g(8);
        let h100 = ClusterSpec::h100_80g(8);
        let model = ModelDesc::llama2_7b();
        let cost_a = CostModel::calibrated(&model, &a100);
        let cost_h = CostModel::calibrated(&model, &h100);
        let initial = TaskSet::new(vec![short("s1"), short("s2")]);
        let mut mgr = ShardManager::new_fleet(
            vec![(&cost_a, &a100), (&cost_h, &h100)],
            initial,
            fast_opts(),
        );
        let before: Vec<u32> =
            mgr.shards().iter().map(|m| m.replans).collect();
        // pool 1 loses half its GPUs; pool 0 keeps its full budget
        let opened = mgr.apply_capacity(&[8, 4]);
        assert_eq!(mgr.gpu_budget(0), None);
        assert_eq!(mgr.gpu_budget(1), Some(4));
        assert_eq!(mgr.total_capacity(), 12);
        while let Some(r) = mgr.pump_replan(10_000) {
            if r.done {
                break;
            }
        }
        mgr.finish_replan();
        let after: Vec<u32> = mgr.shards().iter().map(|m| m.replans).collect();
        if opened == vec![1] {
            assert_eq!(after[0], before[0], "pool 0 replanned on pool 1's loss");
        }
        assert!(after[1] > before[1] || opened.is_empty());
    }
}
