//! Shared experiment scenarios for the table/figure regeneration benches
//! (`rust/benches/`). Each paper experiment is a composition of: a world
//! (model + cluster + tasks), a deployment arm, and scheduler options.

use crate::cluster::ClusterSpec;
use crate::config::ModelDesc;
use crate::coordinator::dispatcher::DispatchPolicy;
use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
use crate::costmodel::CostModel;
use crate::metrics::JointFtReport;
use crate::prelude::TaskSet;

/// One evaluation world: base model + cluster + the batch of FT tasks.
pub struct Scenario {
    pub label: String,
    pub model: ModelDesc,
    pub cluster: ClusterSpec,
    pub tasks: TaskSet,
}

impl Scenario {
    pub fn new(label: &str, model: ModelDesc, cluster: ClusterSpec, tasks: TaskSet) -> Self {
        Self { label: label.into(), model, cluster, tasks }
    }

    /// Paper end-to-end worlds (Figure 7).
    pub fn paper_7b_16() -> Self {
        Self::new(
            "7B / 16xA100-40G / 6 tasks",
            ModelDesc::llama2_7b(),
            ClusterSpec::a100_40g(16),
            TaskSet::paper_7b_subset(),
        )
    }

    pub fn paper_32b_64() -> Self {
        Self::new(
            "32B / 64xA800-80G / 12 tasks",
            ModelDesc::qwen25_32b(),
            ClusterSpec::a800_80g(64),
            TaskSet::paper_all(),
        )
    }

    pub fn paper_70b_64() -> Self {
        Self::new(
            "70B / 64xA800-80G / 12 tasks",
            ModelDesc::llama2_70b(),
            ClusterSpec::a800_80g(64),
            TaskSet::paper_all(),
        )
    }

    pub fn cost(&self) -> CostModel {
        CostModel::calibrated(&self.model, &self.cluster)
    }

    pub fn planner_opts(&self) -> PlannerOptions {
        PlannerOptions::default()
    }

    /// The four evaluation arms of Figure 7.
    pub fn arm_report(&self, arm: Arm, steps: usize) -> Option<ArmResult> {
        let cost = self.cost();
        let planner = Planner::new(&cost, &self.cluster);
        match arm {
            Arm::TaskFused => {
                let plan = planner.plan_homogeneous(&self.tasks, &self.planner_opts())?;
                let mut opts = SchedulerOptions::default();
                opts.dynamic_bucketing = false; // naive fuse: no per-batch DP
                let report =
                    Scheduler::new(&cost, &plan, &self.tasks, opts).run_steps(steps);
                Some(ArmResult { plan: Some(plan), report, per_task: vec![], skipped: vec![] })
            }
            Arm::Lobra => {
                let plan = planner.plan(&self.tasks, self.planner_opts())?;
                let report = Scheduler::new(
                    &cost,
                    &plan,
                    &self.tasks,
                    SchedulerOptions::default(),
                )
                .run_steps(steps);
                Some(ArmResult { plan: Some(plan), report, per_task: vec![], skipped: vec![] })
            }
            Arm::TaskSequential => self.sequential(false, steps),
            Arm::LobraSequential => self.sequential(true, steps),
        }
    }

    fn sequential(&self, heterogeneous: bool, steps: usize) -> Option<ArmResult> {
        let cost = self.cost();
        let runs = crate::coordinator::scheduler::sequential_gpu_seconds(
            &cost,
            &self.cluster,
            &self.tasks,
            heterogeneous,
            steps,
            &SchedulerOptions::default(),
        );
        let mut report = JointFtReport::default();
        report.plan_notation = "(per-task)".into();
        report.gpus = self.cluster.n_gpus;
        report.steps = steps;
        report.gpu_seconds_per_step = runs.total_gpu_seconds;
        Some(ArmResult {
            plan: None,
            report,
            per_task: runs.per_task,
            skipped: runs.skipped,
        })
    }

    /// LobRA deployment plan (cached planning for case studies).
    pub fn lobra_plan(&self) -> Option<DeploymentPlan> {
        let cost = self.cost();
        Planner::new(&cost, &self.cluster).plan(&self.tasks, self.planner_opts())
    }

    /// Run a custom (plan, policy, bucketing) arm — the Figure 8 axes.
    pub fn custom_report(
        &self,
        plan: &DeploymentPlan,
        policy: DispatchPolicy,
        dynamic_bucketing: bool,
        steps: usize,
    ) -> JointFtReport {
        let cost = self.cost();
        let mut opts = SchedulerOptions::default();
        opts.policy = policy;
        opts.dynamic_bucketing = dynamic_bucketing;
        Scheduler::new(&cost, plan, &self.tasks, opts).run_steps(steps)
    }
}

/// The evaluation arms of Figure 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arm {
    TaskFused,
    TaskSequential,
    LobraSequential,
    Lobra,
}

impl Arm {
    pub fn label(&self) -> &'static str {
        match self {
            Arm::TaskFused => "Task-Fused",
            Arm::TaskSequential => "Task-Sequential",
            Arm::LobraSequential => "LobRA-Sequential",
            Arm::Lobra => "LobRA",
        }
    }
}

/// Result of one arm: plan (if joint), aggregate report, per-task detail.
pub struct ArmResult {
    pub plan: Option<DeploymentPlan>,
    pub report: JointFtReport,
    pub per_task: Vec<(String, f64)>,
    /// Tasks the sequential baselines could not plan (always empty for the
    /// joint arms). A non-empty list means the arm's total under-counts.
    pub skipped: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure7_ordering_7b() {
        // The paper's headline ordering must hold:
        // LobRA < LobRA-Sequential <= Task-Sequential < Task-Fused.
        let sc = Scenario::paper_7b_16();
        let fused = sc.arm_report(Arm::TaskFused, 10).unwrap().report;
        let lobra = sc.arm_report(Arm::Lobra, 10).unwrap().report;
        assert!(
            lobra.gpu_seconds_per_step < fused.gpu_seconds_per_step,
            "LobRA {} !< fused {}",
            lobra.gpu_seconds_per_step,
            fused.gpu_seconds_per_step
        );
        let reduction = lobra.reduction_vs(&fused);
        assert!(
            reduction > 0.2,
            "expected paper-magnitude reduction, got {:.1}%",
            reduction * 100.0
        );
    }

    #[test]
    fn sequential_reports_per_task() {
        let sc = Scenario::paper_7b_16();
        let seq = sc.arm_report(Arm::TaskSequential, 5).unwrap();
        assert_eq!(seq.per_task.len(), 6);
        assert!(seq.report.gpu_seconds_per_step > 0.0);
    }
}
