//! Real end-to-end training driver: PJRT-executed joint LoRA fine-tuning.
//!
//! This is where all three layers meet on a real workload: the engine runs
//! the AOT train-step artifacts (L2 model + L1 Pallas kernel), gradients are
//! accumulated across microbatches in Rust, Adam updates the adapters, and
//! the cost model supplies the virtual-cluster clock so the run reports the
//! same GPU-seconds accounting as the simulation benches. Used by
//! `examples/e2e_train.rs`.

mod adam;

pub use adam::{Adam, AdamConfig};

use crate::coordinator::planner::DeploymentPlan;
use crate::costmodel::{BucketLoad, CostModel};
use crate::data::SyntheticCorpus;
use crate::runtime::{Engine, ParamVector};
use crate::util::Rng;
use anyhow::{anyhow, Result};

/// Per-step training log entry.
#[derive(Debug, Clone)]
pub struct TrainLog {
    pub step: u64,
    /// Token-weighted mean loss over the step's microbatches.
    pub loss: f64,
    /// Per-task mean losses (NaN-free: tasks absent this step carry None).
    pub task_loss: Vec<Option<f64>>,
    /// Microbatches executed.
    pub microbatches: usize,
    /// Real wall-clock of the step (CPU execution).
    pub wall_seconds: f64,
    /// Virtual-cluster step time from the cost model (simulated clock).
    pub virtual_seconds: f64,
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub adam: AdamConfig,
    /// Sequences drawn per task per step.
    pub per_task_batch: usize,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self { adam: AdamConfig { lr: 2e-3, ..Default::default() }, per_task_batch: 4, seed: 0 }
    }
}

/// Joint multi-task LoRA trainer over the PJRT engine.
pub struct Trainer {
    engine: Engine,
    corpus: SyntheticCorpus,
    lora: ParamVector,
    adam: Adam,
    cfg: TrainerConfig,
    rng: Rng,
    n_tasks: usize,
    logs: Vec<TrainLog>,
    /// Optional virtual cluster for GPU-seconds accounting.
    virtual_cluster: Option<(CostModel, DeploymentPlan)>,
}

impl Trainer {
    /// Build from an artifacts directory. Initializes params per manifest.
    pub fn new(artifacts_dir: &str, cfg: TrainerConfig) -> Result<Self> {
        let mut engine = Engine::load(artifacts_dir)?;
        let (base, lora) = engine.init_params(cfg.seed);
        engine.set_base(&base)?;
        let m = engine.manifest();
        let n_tasks = m.model.n_tasks as usize;
        let vocab = m.model.vocab as u32;
        let adam = Adam::new(lora.len(), cfg.adam);
        Ok(Self {
            engine,
            corpus: SyntheticCorpus::new(vocab, n_tasks, cfg.seed ^ 0xC0FFEE),
            lora,
            adam,
            rng: Rng::new(cfg.seed ^ 0xDA7A),
            cfg,
            n_tasks,
            logs: Vec::new(),
            virtual_cluster: None,
        })
    }

    /// Attach a virtual cluster (cost model + plan) for simulated-clock
    /// GPU-seconds reporting alongside the real run.
    pub fn with_virtual_cluster(mut self, cost: CostModel, plan: DeploymentPlan) -> Self {
        self.virtual_cluster = Some((cost, plan));
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn lora(&self) -> &ParamVector {
        &self.lora
    }

    pub fn logs(&self) -> &[TrainLog] {
        &self.logs
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// Draw this step's fused workload: per task, `per_task_batch` sequences
    /// with task-dependent lengths, then pack into the artifact shapes.
    ///
    /// Packing mirrors the coordinator: sequences are padded up to the
    /// smallest artifact seq that fits and grouped into (batch, seq)
    /// microbatches, each sorted by task id (the L1 kernel contract).
    fn build_microbatches(&mut self) -> Vec<((u64, u64), Vec<i32>, Vec<i32>)> {
        let shapes = self.engine.shapes();
        // per shape: list of (task) pending sequences
        let mut pending: Vec<Vec<usize>> = vec![Vec::new(); shapes.len()];
        for t in 0..self.n_tasks {
            for _ in 0..self.cfg.per_task_batch {
                // target lengths jitter around the task's corpus mean
                let base = 32 + 32 * (t % 4) as u64;
                let len = (base as f64 * (0.5 + self.rng.f64() * 1.5)) as u64;
                let si = shapes
                    .iter()
                    .position(|&(_, s)| s >= len)
                    .unwrap_or(shapes.len() - 1);
                pending[si].push(t);
            }
        }
        let mut out = Vec::new();
        for (si, tasks) in pending.into_iter().enumerate() {
            let (b, s) = shapes[si];
            let mut tasks = tasks;
            tasks.sort_unstable();
            for chunk in tasks.chunks(b as usize) {
                // pad the microbatch with repeats of the last task to fill b
                let mut padded: Vec<usize> = chunk.to_vec();
                while padded.len() < b as usize {
                    padded.push(*padded.last().unwrap());
                }
                let (toks, segs) = self.corpus.fused_microbatch(&padded, s as usize);
                out.push(((b, s), toks, segs));
            }
        }
        out
    }

    /// Run one training step (all microbatches + one Adam update).
    pub fn step(&mut self) -> Result<TrainLog> {
        let t0 = std::time::Instant::now();
        let microbatches = self.build_microbatches();
        if microbatches.is_empty() {
            return Err(anyhow!("no microbatches built"));
        }
        let mut grad_acc = vec![0f32; self.lora.len()];
        let mut loss_sum = 0f64;
        let mut tok_sum = 0f64;
        let mut task_loss = vec![0f64; self.n_tasks];
        let mut task_toks = vec![0f64; self.n_tasks];
        let n_mb = microbatches.len();
        let mut virtual_loads: Vec<(u64, u64)> = Vec::new();
        for (shape, toks, segs) in microbatches {
            let out = self.engine.train_step(shape, &self.lora, &toks, &segs)?;
            let w = out.tokens as f64;
            loss_sum += out.loss as f64 * w;
            tok_sum += w;
            for (g, gi) in grad_acc.iter_mut().zip(&out.grad) {
                *g += gi * out.tokens;
            }
            for t in 0..self.n_tasks {
                task_loss[t] += out.task_loss[t] as f64;
                task_toks[t] += out.task_tokens[t] as f64;
            }
            virtual_loads.push(shape);
        }
        if tok_sum > 0.0 {
            for g in &mut grad_acc {
                *g /= tok_sum as f32;
            }
        }
        self.adam.update(&mut self.lora.data, &grad_acc);

        // virtual-cluster clock: pretend the microbatches were dispatched
        // over the plan's replicas round-robin.
        let virtual_seconds = if let Some((cost, plan)) = &self.virtual_cluster {
            let replicas: Vec<_> = plan
                .groups
                .iter()
                .flat_map(|&(c, p)| std::iter::repeat(c).take(p as usize))
                .collect();
            let mut per_replica: Vec<Vec<BucketLoad>> = vec![Vec::new(); replicas.len()];
            for (i, &(b, s)) in virtual_loads.iter().enumerate() {
                per_replica[i % replicas.len()]
                    .push(BucketLoad { count: b, padded_len: s });
            }
            replicas
                .iter()
                .zip(&per_replica)
                .map(|(&c, loads)| cost.replica_time(c, loads))
                .fold(0.0f64, f64::max)
        } else {
            0.0
        };

        let log = TrainLog {
            step: self.adam.step_count(),
            loss: if tok_sum > 0.0 { loss_sum / tok_sum } else { f64::NAN },
            task_loss: (0..self.n_tasks)
                .map(|t| (task_toks[t] > 0.0).then(|| task_loss[t] / task_toks[t]))
                .collect(),
            microbatches: n_mb,
            wall_seconds: t0.elapsed().as_secs_f64(),
            virtual_seconds,
        };
        self.logs.push(log.clone());
        Ok(log)
    }

    /// Run `n` steps, invoking `on_log` after each.
    pub fn run(&mut self, n: usize, mut on_log: impl FnMut(&TrainLog)) -> Result<()> {
        for _ in 0..n {
            let log = self.step()?;
            on_log(&log);
        }
        Ok(())
    }

    /// Save the LoRA adapters (the only trainable state).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        self.lora.save(path)
    }

    /// Restore LoRA adapters.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        self.lora = ParamVector::load(path, self.lora.len())?;
        Ok(())
    }
}
