//! Real end-to-end training driver: PJRT-executed joint LoRA fine-tuning.
//!
//! This is where all three layers meet on a real workload, and — since the
//! exec-layer refactor — through the *same* per-step pipeline the paper
//! evaluates: sequences are drawn with `DatasetProfile`-shaped lengths,
//! bucketized to the compiled artifact shapes, dispatched over the virtual
//! cluster's replicas by the MINMAX solve
//! ([`crate::coordinator::dispatcher`]), and executed by a
//! [`crate::exec::PjrtExecutor`] (replicas concurrent via
//! [`crate::util::par`], gradients reduced deterministically in fixed
//! replica order). The virtual GPU-seconds each step reports therefore
//! come from the dispatch algorithm itself, not from a round-robin
//! approximation of it. Every executed microbatch's measured wall-clock
//! also feeds an in-situ [`CalibrationStore`]
//! ([`Trainer::save_profile`] persists it for `--profile` planning). Adam
//! updates the adapters in Rust; checkpoints persist adapters *and*
//! optimizer state ([`TrainCheckpoint`]). Used by `examples/e2e_train.rs`
//! and `lobra train`.

mod adam;
mod checkpoint;

pub use adam::{Adam, AdamConfig};
pub use checkpoint::{TrainCheckpoint, CHECKPOINT_MAGIC};

use crate::cluster::ClusterSpec;
use crate::config::{ModelDesc, ParallelConfig};
use crate::coordinator::bucketing::buckets_from_boundaries;
use crate::coordinator::dispatcher::DispatchPolicy;
use crate::coordinator::planner::DeploymentPlan;
use crate::coordinator::tasks::{plan_adjustment, PlanAdjustment};
use crate::costmodel::{CalibrationStore, CostModel};
use crate::data::{DatasetProfile, FusedBatch, LengthDistribution, Sequence, SyntheticCorpus};
use crate::exec::{ExecutionPlan, PjrtExecutor, ReplicaExecutor};
use crate::runtime::{Engine, ParamVector};
use crate::util::clock::Stopwatch;
use crate::util::Rng;
use anyhow::{anyhow, Result};

/// Per-step training log entry.
#[derive(Debug, Clone)]
pub struct TrainLog {
    pub step: u64,
    /// Token-weighted mean loss over the step's microbatches.
    pub loss: f64,
    /// Per-task mean losses (NaN-free: tasks absent this step carry None).
    pub task_loss: Vec<Option<f64>>,
    /// Microbatches executed.
    pub microbatches: usize,
    /// Real wall-clock of the step (CPU execution).
    pub wall_seconds: f64,
    /// Virtual-cluster step time: max dispatched replica time + LoRA sync,
    /// from the MINMAX dispatch solve.
    pub virtual_seconds: f64,
    /// Virtual GPU·seconds of the step (`gpus_used × virtual_seconds`) —
    /// the paper's headline accounting, now produced by the same dispatch
    /// path the simulated benches run.
    pub virtual_gpu_seconds: f64,
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub adam: AdamConfig,
    /// Sequences drawn per task per step.
    pub per_task_batch: usize,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self { adam: AdamConfig { lr: 2e-3, ..Default::default() }, per_task_batch: 4, seed: 0 }
    }
}

/// Joint multi-task LoRA trainer over the PJRT engine.
///
/// Holds the model state (adapters + Adam) and drives the dispatch→execute
/// pipeline each step; execution itself lives in the [`PjrtExecutor`].
pub struct Trainer {
    exec: PjrtExecutor,
    lora: ParamVector,
    adam: Adam,
    cfg: TrainerConfig,
    rng: Rng,
    n_tasks: usize,
    logs: Vec<TrainLog>,
    /// Virtual deployment the step workload is dispatched over.
    vplan: DeploymentPlan,
    /// Table-4 profiles driving each task's sequence-length draws.
    profiles: Vec<&'static DatasetProfile>,
    lengths: Vec<LengthDistribution>,
    /// Bucket boundaries = the compiled artifact sequence lengths.
    boundaries: Vec<u32>,
    /// In-situ calibration: every executed microbatch's measured
    /// wall-clock accumulates here, keyed to the virtual cluster's
    /// world ([`Self::save_profile`] persists it).
    calib: CalibrationStore,
    /// Virtual-cluster redeploys performed ([`Self::redeploy`]).
    redeploys: u32,
}

impl Trainer {
    /// Build from an artifacts directory. Initializes params per manifest.
    ///
    /// The default virtual cluster is `local_cpu(4)` with four `<1,1>`
    /// replicas of the tiny model — enough for the dispatch pipeline to be
    /// exercised end to end; attach a planned deployment with
    /// [`Self::with_virtual_cluster`] for paper-scale accounting.
    pub fn new(artifacts_dir: &str, cfg: TrainerConfig) -> Result<Self> {
        let mut engine = Engine::load(artifacts_dir)?;
        let (base, lora) = engine.init_params(cfg.seed);
        engine.set_base(&base)?;
        let m = engine.manifest();
        let n_tasks = m.model.n_tasks as usize;
        let vocab = m.model.vocab as u32;
        let preset = m.preset.clone();
        let mut boundaries: Vec<u32> =
            engine.shapes().iter().map(|&(_, s)| s as u32).collect();
        boundaries.dedup();
        if boundaries.is_empty() {
            return Err(anyhow!("no train artifact shapes"));
        }
        let adam = Adam::new(lora.len(), cfg.adam);
        let corpus = SyntheticCorpus::new(vocab, n_tasks, cfg.seed ^ 0xC0FFEE);

        // each FT task draws lengths shaped like one of the paper's
        // Table 4 datasets, rescaled into the artifact window
        let profiles: Vec<&'static DatasetProfile> = (0..n_tasks)
            .map(|t| &DatasetProfile::all()[t % DatasetProfile::all().len()])
            .collect();
        let lengths = profiles.iter().map(|p| p.distribution()).collect();

        // The *engine world*: the model actually compiled into the
        // artifacts, on the local CPU "cluster". The in-situ calibration
        // store is keyed to this world — its observations are wall-clocks
        // of THIS engine, and must never masquerade as measurements of
        // whatever virtual cluster the run is accounted against.
        let engine_model =
            ModelDesc::by_name(&preset).unwrap_or_else(ModelDesc::tiny);
        let cluster = ClusterSpec::local_cpu(4);
        let cost = CostModel::calibrated(&engine_model, &cluster);
        let calib = CalibrationStore::new(&cost);
        let vplan =
            DeploymentPlan::homogeneous(ParallelConfig::new(1, 1), 4, n_tasks as u32);
        Ok(Self {
            exec: PjrtExecutor::new(engine, cost, corpus),
            lora,
            adam,
            rng: Rng::new(cfg.seed ^ 0xDA7A),
            cfg,
            n_tasks,
            logs: Vec::new(),
            vplan,
            profiles,
            lengths,
            boundaries,
            calib,
            redeploys: 0,
        })
    }

    /// Attach a virtual cluster (cost model + deployment plan): subsequent
    /// steps dispatch over `plan`'s replicas and report GPU-seconds under
    /// `cost`'s clock. The in-situ calibration store is deliberately NOT
    /// re-keyed: its observations are CPU wall-clocks of the local engine
    /// world, not measurements of the virtual cluster — keying them to
    /// the virtual (model, cluster) would let a saved profile attach as
    /// "measured A100 times" and mix units with the analytic model.
    pub fn with_virtual_cluster(mut self, cost: CostModel, plan: DeploymentPlan) -> Self {
        self.exec.set_cost(cost);
        self.vplan = plan;
        self
    }

    /// Redeploy the virtual cluster at a step boundary — the serving
    /// runtime's swap path applied to a live trainer. The LoRA adapters
    /// and optimizer state are checkpointed (in memory; a real cluster
    /// writes [`TrainCheckpoint`] to disk before the process restart),
    /// the deployment plan and its cost clock are swapped, and the state
    /// is restored — training resumes at the same step count with the
    /// same moments, so a redeploy never perturbs the optimizer
    /// trajectory. Returns the per-group diff: only replica groups that
    /// actually changed pay checkpoint+restart.
    pub fn redeploy(&mut self, cost: CostModel, plan: DeploymentPlan) -> PlanAdjustment {
        let adjustment = plan_adjustment(&self.vplan, &plan);
        // checkpoint: adapters + Adam moments + step
        let (m, v) = self.adam.moments();
        let ck = TrainCheckpoint {
            lora: self.lora.data.clone(),
            m: m.to_vec(),
            v: v.to_vec(),
            step: self.adam.step_count(),
        };
        // swap the deployment (the redeploy point: between steps)
        self.exec.set_cost(cost);
        self.vplan = plan;
        // restore: the joint task restarts under the new plan from the
        // exact state it checkpointed
        self.lora = ParamVector { data: ck.lora };
        self.adam = Adam::from_state(self.cfg.adam, ck.m, ck.v, ck.step);
        self.redeploys += 1;
        adjustment
    }

    /// Virtual-cluster redeploys performed so far.
    pub fn redeploys(&self) -> u32 {
        self.redeploys
    }

    /// The PJRT engine (`None` if the executor were backed by the native
    /// runtime; the trainer always constructs the PJRT backend today).
    pub fn engine(&self) -> Option<&Engine> {
        self.exec.engine()
    }

    /// Execution platform name, independent of backend.
    pub fn platform(&self) -> String {
        self.exec.platform()
    }

    /// Compiled/executable microbatch shapes, ascending by seq.
    pub fn shapes(&self) -> Vec<(u64, u64)> {
        self.exec.shapes()
    }

    pub fn lora(&self) -> &ParamVector {
        &self.lora
    }

    pub fn logs(&self) -> &[TrainLog] {
        &self.logs
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// The virtual deployment steps are dispatched over.
    pub fn virtual_plan(&self) -> &DeploymentPlan {
        &self.vplan
    }

    /// The in-situ calibration store (one observation per executed
    /// microbatch so far).
    pub fn calibration(&self) -> &CalibrationStore {
        &self.calib
    }

    /// Refit the in-situ observations and persist them as a calibration
    /// profile at `path` (loadable by `lobra train --profile` /
    /// [`crate::costmodel::load_profile_or_analytic`]).
    pub fn save_profile(&mut self, path: &str) -> Result<()> {
        self.calib.refit();
        self.calib.save(path)
    }

    /// Draw this step's fused batch: per task, `per_task_batch` sequences
    /// with lengths sampled from the task's Table-4 profile, rescaled from
    /// the profile's native range into the artifact window. This preserves
    /// the per-task skew the dispatcher exists to balance (the seed
    /// trainer used a hard-coded `32 + 32·(t mod 4)` jitter instead).
    fn draw_batch(&mut self) -> FusedBatch {
        let max_seq = *self.boundaries.last().unwrap();
        let min_len = 8.min(max_seq);
        let mut sequences = Vec::with_capacity(self.n_tasks * self.cfg.per_task_batch);
        for t in 0..self.n_tasks {
            let scale = max_seq as f64 / self.profiles[t].max_len as f64;
            for _ in 0..self.cfg.per_task_batch {
                let raw = self.lengths[t].sample(&mut self.rng);
                let len =
                    ((raw as f64 * scale).round() as u32).clamp(min_len, max_seq);
                sequences.push(Sequence { task: t as u32, len });
            }
        }
        FusedBatch { sequences }
    }

    /// Run one training step: dispatch the fused batch over the virtual
    /// replicas (MINMAX solve), execute the dispatched loads on the PJRT
    /// engine, reduce gradients deterministically, and apply one Adam
    /// update.
    pub fn step(&mut self) -> Result<TrainLog> {
        let t0 = Stopwatch::start();
        let batch = self.draw_batch();
        let buckets = buckets_from_boundaries(&batch.lengths(), &self.boundaries);
        let eplan = ExecutionPlan::build(
            self.exec.cost(),
            &self.vplan,
            None,
            batch,
            buckets,
            DispatchPolicy::Balanced,
        )
        .ok_or_else(|| anyhow!("virtual cluster cannot serve the sampled batch"))?;

        self.exec.set_lora(&self.lora);
        let out = self.exec.execute_step(&eplan)?;
        self.calib.record_all(&out.observations);
        let train = out
            .train
            .ok_or_else(|| anyhow!("pjrt executor returned no training output"))?;

        let mut grad = train.grad;
        if train.tokens > 0.0 {
            let inv = 1.0 / train.tokens as f32;
            for g in &mut grad {
                *g *= inv;
            }
        }
        self.adam.update(&mut self.lora.data, &grad);

        let log = TrainLog {
            step: self.adam.step_count(),
            loss: if train.tokens > 0.0 {
                train.loss_sum / train.tokens
            } else {
                f64::NAN
            },
            task_loss: (0..self.n_tasks)
                .map(|t| {
                    (train.task_tokens[t] > 0.0)
                        .then(|| train.task_loss[t] / train.task_tokens[t])
                })
                .collect(),
            microbatches: train.microbatches,
            wall_seconds: t0.elapsed_secs(),
            virtual_seconds: out.step_time,
            virtual_gpu_seconds: self.vplan.gpus_used() as f64 * out.step_time,
        };
        self.logs.push(log.clone());
        Ok(log)
    }

    /// Run `n` steps, invoking `on_log` after each.
    pub fn run(&mut self, n: usize, mut on_log: impl FnMut(&TrainLog)) -> Result<()> {
        for _ in 0..n {
            let log = self.step()?;
            on_log(&log);
        }
        Ok(())
    }

    /// Save the complete training state (adapters + Adam moments + step).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let (m, v) = self.adam.moments();
        TrainCheckpoint {
            lora: self.lora.data.clone(),
            m: m.to_vec(),
            v: v.to_vec(),
            step: self.adam.step_count(),
        }
        .save(path)
    }

    /// Restore training state. Legacy adapters-only checkpoints load with a
    /// fresh optimizer — the old behavior, but now warned about instead of
    /// silent.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let (ck, legacy) = TrainCheckpoint::load(path, self.lora.len())?;
        if legacy {
            eprintln!(
                "warning: {path}: legacy adapters-only checkpoint — optimizer \
                 moments and step count reset"
            );
        }
        self.lora = ParamVector { data: ck.lora };
        self.adam = Adam::from_state(self.cfg.adam, ck.m, ck.v, ck.step);
        Ok(())
    }
}
