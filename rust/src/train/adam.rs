//! Adam(W) optimizer over flat f32 parameter vectors.
//!
//! The optimizer lives in Rust (L3): the AOT train-step artifacts return the
//! flat LoRA gradient, the coordinator accumulates gradients across
//! microbatches and replicas, and this updates the adapters. Keeping the
//! update out of the HLO keeps one executable per microbatch shape valid
//! for the whole run (no step-count specialization).

/// Adam hyper-parameters (paper uses Adam for all experiments).
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Decoupled weight decay (0 = plain Adam).
    pub weight_decay: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// Adam state over a flat vector.
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl Adam {
    pub fn new(n_params: usize, cfg: AdamConfig) -> Self {
        Self { cfg, m: vec![0.0; n_params], v: vec![0.0; n_params], step: 0 }
    }

    /// Rebuild from checkpointed state (first/second moments + step count).
    /// Resuming without the moments silently restarts the optimizer's
    /// bias-correction schedule, so checkpoints persist them.
    pub fn from_state(cfg: AdamConfig, m: Vec<f32>, v: Vec<f32>, step: u64) -> Self {
        assert_eq!(m.len(), v.len(), "moment vectors must match");
        Self { cfg, m, v, step }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Checkpointable optimizer state: (first moments, second moments).
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// In-place update of `params` with `grad`.
    pub fn update(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let lr = self.cfg.lr;
        let eps = self.cfg.eps;
        let wd = self.cfg.weight_decay;
        for i in 0..params.len() {
            let g = grad[i] as f64;
            let m = b1 * self.m[i] as f64 + (1.0 - b1) * g;
            let v = b2 * self.v[i] as f64 + (1.0 - b2) * g * g;
            self.m[i] = m as f32;
            self.v[i] = v as f32;
            let mhat = m / bc1;
            let vhat = v / bc2;
            let mut p = params[i] as f64;
            p -= lr * (mhat / (vhat.sqrt() + eps) + wd * p);
            params[i] = p as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x_i - target_i)^2
        let target = [3.0f32, -2.0, 0.5];
        let mut x = vec![0.0f32; 3];
        let mut adam = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..500 {
            let grad: Vec<f32> = x.iter().zip(&target).map(|(&xi, &t)| 2.0 * (xi - t)).collect();
            adam.update(&mut x, &grad);
        }
        for (xi, t) in x.iter().zip(&target) {
            assert!((xi - t).abs() < 1e-2, "{x:?}");
        }
    }

    #[test]
    fn zero_grad_no_movement_from_zero_state() {
        let mut x = vec![1.0f32, 2.0];
        let mut adam = Adam::new(2, AdamConfig::default());
        adam.update(&mut x, &[0.0, 0.0]);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut x = vec![10.0f32];
        let mut adam = Adam::new(
            1,
            AdamConfig { lr: 0.1, weight_decay: 0.5, ..Default::default() },
        );
        adam.update(&mut x, &[0.0]);
        assert!(x[0] < 10.0);
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        // two optimizers: one updated straight through, one rebuilt from
        // checkpointed moments mid-run — their trajectories must match bitwise
        let cfg = AdamConfig { lr: 0.05, weight_decay: 0.01, ..Default::default() };
        let grads: Vec<Vec<f32>> =
            (0..6).map(|i| vec![0.1 * i as f32, -0.2, 0.3]).collect();
        let mut x_a = vec![1.0f32, 2.0, 3.0];
        let mut adam_a = Adam::new(3, cfg);
        for g in &grads[..3] {
            adam_a.update(&mut x_a, g);
        }
        let (m, v) = adam_a.moments();
        let mut adam_b =
            Adam::from_state(cfg, m.to_vec(), v.to_vec(), adam_a.step_count());
        let mut x_b = x_a.clone();
        for g in &grads[3..] {
            adam_a.update(&mut x_a, g);
            adam_b.update(&mut x_b, g);
        }
        assert_eq!(x_a, x_b);
        assert_eq!(adam_a.step_count(), adam_b.step_count());
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut x = vec![0.0f32; 2];
        let mut adam = Adam::new(2, AdamConfig::default());
        adam.update(&mut x, &[0.0]);
    }
}
