//! Complete training-state checkpoints (adapters + optimizer).
//!
//! The seed trainer persisted only the LoRA vector, so a resumed run
//! silently reset Adam's moments and step count — the bias-correction
//! schedule restarted and the first post-resume updates were wrong. A
//! checkpoint now carries everything `Trainer::step` depends on:
//!
//! ```text
//! magic "LOBRACK2" | n_params u64 LE | step u64 LE
//!   | lora [f32; n] | m [f32; n] | v [f32; n]      (all little-endian)
//! ```
//!
//! Legacy raw-f32 checkpoints (adapters only) still load — the optimizer
//! state comes back zeroed, exactly the old behavior, but now explicit in
//! the return value instead of silent.

use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Read `bytes[at..at + 8]` as a little-endian `u64`, as a checked error
/// instead of a panic: the caller's length guard and this slice must agree,
/// and a corrupt file must surface as `Err`, never abort `lobra train`.
fn read_u64_le(bytes: &[u8], at: usize, path: &Path) -> Result<u64> {
    let end = at.checked_add(8).filter(|&e| e <= bytes.len());
    let slice = end
        .map(|e| &bytes[at..e])
        .ok_or_else(|| anyhow!("checkpoint {path:?}: truncated header at byte {at}"))?;
    let mut le = [0u8; 8];
    le.copy_from_slice(slice);
    Ok(u64::from_le_bytes(le))
}

/// File magic; bump the trailing digit on layout changes.
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"LOBRACK2";

/// Everything a training run needs to resume exactly where it stopped.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainCheckpoint {
    /// Flat LoRA adapter vector.
    pub lora: Vec<f32>,
    /// Adam first moments (same length as `lora`).
    pub m: Vec<f32>,
    /// Adam second moments (same length as `lora`).
    pub v: Vec<f32>,
    /// Optimizer step count (drives bias correction).
    pub step: u64,
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

impl TrainCheckpoint {
    /// A fresh-state checkpoint (zero moments, step 0) around adapters.
    pub fn from_lora(lora: Vec<f32>) -> Self {
        let n = lora.len();
        Self { lora, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Serialize to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let n = self.lora.len();
        if self.m.len() != n || self.v.len() != n {
            return Err(anyhow!(
                "inconsistent checkpoint: lora {} m {} v {}",
                n,
                self.m.len(),
                self.v.len()
            ));
        }
        let mut bytes = Vec::with_capacity(24 + 12 * n);
        bytes.extend_from_slice(CHECKPOINT_MAGIC);
        bytes.extend_from_slice(&(n as u64).to_le_bytes());
        bytes.extend_from_slice(&self.step.to_le_bytes());
        push_f32s(&mut bytes, &self.lora);
        push_f32s(&mut bytes, &self.m);
        push_f32s(&mut bytes, &self.v);
        let path = path.as_ref();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {path:?}"))?;
        f.write_all(&bytes)
            .with_context(|| format!("writing checkpoint {path:?}"))?;
        Ok(())
    }

    /// Load from `path`; `expected_params` guards against artifact
    /// mismatch. Returns `(checkpoint, legacy)` where `legacy` is true for
    /// pre-optimizer-state files (adapters restored, moments zeroed).
    pub fn load(path: impl AsRef<Path>, expected_params: usize) -> Result<(Self, bool)> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        if bytes.len() >= 24 && &bytes[..8] == CHECKPOINT_MAGIC {
            let n = read_u64_le(&bytes, 8, path)? as usize;
            let step = read_u64_le(&bytes, 16, path)?;
            if n != expected_params {
                return Err(anyhow!(
                    "checkpoint {:?}: {} params, expected {}",
                    path,
                    n,
                    expected_params
                ));
            }
            let body = &bytes[24..];
            if body.len() != 12 * n {
                return Err(anyhow!(
                    "checkpoint {:?}: truncated body ({} bytes, expected {})",
                    path,
                    body.len(),
                    12 * n
                ));
            }
            Ok((
                Self {
                    lora: read_f32s(&body[..4 * n]),
                    m: read_f32s(&body[4 * n..8 * n]),
                    v: read_f32s(&body[8 * n..12 * n]),
                    step,
                },
                false,
            ))
        } else if bytes.len() == 4 * expected_params {
            // legacy adapters-only checkpoint
            Ok((Self::from_lora(read_f32s(&bytes)), true))
        } else {
            Err(anyhow!(
                "checkpoint {:?}: {} bytes is neither v2 nor legacy ({} expected)",
                path,
                bytes.len(),
                4 * expected_params
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lobra_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_optimizer_state() {
        let ck = TrainCheckpoint {
            lora: vec![1.0, -2.5, 3.25],
            m: vec![0.1, 0.2, -0.3],
            v: vec![0.01, 0.02, 0.03],
            step: 41,
        };
        let p = tmp("roundtrip.ckpt");
        ck.save(&p).unwrap();
        let (back, legacy) = TrainCheckpoint::load(&p, 3).unwrap();
        assert!(!legacy);
        assert_eq!(back, ck);
    }

    #[test]
    fn legacy_adapters_only_loads_with_zero_moments() {
        let p = tmp("legacy.ckpt");
        let lora = [4.0f32, 5.0];
        let bytes: Vec<u8> = lora.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        let (ck, legacy) = TrainCheckpoint::load(&p, 2).unwrap();
        assert!(legacy);
        assert_eq!(ck.lora, vec![4.0, 5.0]);
        assert_eq!(ck.m, vec![0.0, 0.0]);
        assert_eq!(ck.step, 0);
    }

    #[test]
    fn param_count_mismatch_rejected() {
        let ck = TrainCheckpoint::from_lora(vec![1.0, 2.0]);
        let p = tmp("mismatch.ckpt");
        ck.save(&p).unwrap();
        assert!(TrainCheckpoint::load(&p, 3).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let p = tmp("truncated.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CHECKPOINT_MAGIC);
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&7u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]); // body too short for n=2
        std::fs::write(&p, &bytes).unwrap();
        assert!(TrainCheckpoint::load(&p, 2).is_err());
    }

    #[test]
    fn inconsistent_state_rejected_on_save() {
        let ck = TrainCheckpoint {
            lora: vec![1.0, 2.0],
            m: vec![0.0],
            v: vec![0.0, 0.0],
            step: 0,
        };
        assert!(ck.save(tmp("bad.ckpt")).is_err());
    }
}
