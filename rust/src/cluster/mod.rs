//! Cluster substrate: topology description + communication timing model +
//! discrete accounting of GPU seconds.
//!
//! The paper's two testbeds (2×8 A100-40G NVLink/IB, 8×8 A800-80G) are not
//! available here; every planning and dispatching decision in LobRA is made
//! against the *profiled cost model* (paper Appendix D), so the substrate we
//! must reproduce faithfully is that model's inputs: GPU memory capacity,
//! dense-matmul rate, and intra-/inter-server bandwidth. See
//! DESIGN.md#hardware-adaptation.
//!
//! The device-level inputs live in [`DeviceProfile`]; a [`ClusterSpec`] is a
//! sized pool of one device type, and a [`VirtualCluster`] composes pools of
//! *different* device types into one elastic fleet with a global server/GPU
//! numbering ([`FleetAvailability`] tracks which of those GPUs are currently
//! up under join/leave/preempt churn).

mod comm;
mod sim;

pub use comm::CommModel;
pub use sim::{GpuLedger, ReplicaSim};

use std::collections::BTreeSet;

use crate::costmodel::fnv1a;

/// Static description of one GPU generation: the per-device numbers the cost
/// model consumes. Pools of different `DeviceProfile`s can share one
/// [`VirtualCluster`]; cost tables key on these fields (via the world
/// fingerprint), so each device type gets its own tables.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    /// Device generation name (part of the cost-table world key).
    pub name: String,
    pub gpus_per_server: u32,
    /// Per-GPU memory in GiB.
    pub gpu_mem_gib: f64,
    /// Dense bf16 rate per GPU, in TFLOP/s.
    pub tflops: f64,
    /// Achievable fraction of peak for transformer training.
    pub mfu: f64,
    /// Intra-server (NVLink) bandwidth, GB/s.
    pub intra_bw_gbs: f64,
    /// Inter-server (IB) bandwidth, GB/s.
    pub inter_bw_gbs: f64,
}

impl DeviceProfile {
    /// Paper testbed 1: servers of 8×A100-40G, 600 GB/s NVLink, 100 GB/s IB.
    pub fn a100_40g() -> Self {
        Self {
            name: "A100-40G".to_string(),
            gpus_per_server: 8,
            gpu_mem_gib: 40.0,
            tflops: 312.0,
            mfu: 0.42,
            intra_bw_gbs: 600.0,
            inter_bw_gbs: 100.0,
        }
    }

    /// Paper testbed 2: servers of 8×A800-80G, 400 GB/s NVLink, 200 GB/s IB.
    pub fn a800_80g() -> Self {
        Self {
            name: "A800-80G".to_string(),
            gpus_per_server: 8,
            gpu_mem_gib: 80.0,
            tflops: 312.0,
            mfu: 0.42,
            intra_bw_gbs: 400.0,
            inter_bw_gbs: 200.0,
        }
    }

    /// Hopper generation: 8×H100-80G SXM, 900 GB/s NVLink, 200 GB/s IB.
    /// Slightly lower MFU than Ampere at these batch shapes (the dense rate
    /// outruns memory bandwidth), still ~3× effective FLOPs per GPU.
    pub fn h100_80g() -> Self {
        Self {
            name: "H100-80G".to_string(),
            gpus_per_server: 8,
            gpu_mem_gib: 80.0,
            tflops: 989.0,
            mfu: 0.40,
            intra_bw_gbs: 900.0,
            inter_bw_gbs: 200.0,
        }
    }

    /// The local CPU "device" used by the real PJRT e2e run: bandwidth and
    /// rate numbers are only used for simulated-clock accounting.
    pub fn local_cpu() -> Self {
        Self {
            name: "CPU-virtual".to_string(),
            gpus_per_server: 1,
            gpu_mem_gib: 16.0,
            tflops: 0.1,
            mfu: 0.5,
            intra_bw_gbs: 20.0,
            inter_bw_gbs: 20.0,
        }
    }

    /// Device preset by CLI name. Accepts the short generation names used by
    /// `--cluster` ("a100", "a800", "h100", "local"/"cpu") plus the full
    /// preset spellings.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "a100" | "a100-40g" | "a100_40g" => Some(Self::a100_40g()),
            "a800" | "a800-80g" | "a800_80g" => Some(Self::a800_80g()),
            "h100" | "h100-80g" | "h100_80g" => Some(Self::h100_80g()),
            "local" | "cpu" | "cpu-virtual" => Some(Self::local_cpu()),
            _ => None,
        }
    }

    /// Effective dense rate per GPU (FLOP/s).
    pub fn effective_flops(&self) -> f64 {
        self.tflops * 1e12 * self.mfu
    }

    /// Fingerprint of this device generation: every field the cost model
    /// reads plus the generation name. Calibration profiles are keyed by
    /// this (in addition to the `(model, cluster)` world fingerprint,
    /// which folds it in), so in a mixed-generation fleet one pool's
    /// measured fits can never serve another pool's planning.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in self.name.as_bytes() {
            h = fnv1a(h, *b as u64);
        }
        h = fnv1a(h, self.gpus_per_server as u64);
        for v in [self.gpu_mem_gib, self.tflops, self.mfu, self.intra_bw_gbs, self.inter_bw_gbs]
        {
            h = fnv1a(h, v.to_bits());
        }
        h
    }
}

/// A sized pool of one device type. Historically this struct carried the
/// device numbers inline; they now live in [`DeviceProfile`] so one
/// [`VirtualCluster`] can mix generations, and the old constructors are thin
/// shims over the presets.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub n_gpus: u32,
    pub device: DeviceProfile,
}

impl ClusterSpec {
    /// A pool of `n_gpus` of the given device.
    pub fn of(device: DeviceProfile, n_gpus: u32) -> Self {
        Self { name: format!("{n_gpus}x{}", device.name), n_gpus, device }
    }

    /// Paper testbed 1: servers of 8×A100-40G, 600 GB/s NVLink, 100 GB/s IB.
    pub fn a100_40g(n_gpus: u32) -> Self {
        Self::of(DeviceProfile::a100_40g(), n_gpus)
    }

    /// Paper testbed 2: servers of 8×A800-80G, 400 GB/s NVLink, 200 GB/s IB.
    pub fn a800_80g(n_gpus: u32) -> Self {
        Self::of(DeviceProfile::a800_80g(), n_gpus)
    }

    /// Hopper pool (mixed-generation fleets; see `VirtualCluster::parse`).
    pub fn h100_80g(n_gpus: u32) -> Self {
        Self::of(DeviceProfile::h100_80g(), n_gpus)
    }

    /// The local CPU "cluster" used by the real PJRT e2e run: bandwidth and
    /// rate numbers are only used for simulated-clock accounting.
    pub fn local_cpu(n_virtual: u32) -> Self {
        let mut device = DeviceProfile::local_cpu();
        device.gpus_per_server = n_virtual.max(1);
        Self { name: format!("{n_virtual}xCPU-virtual"), n_gpus: n_virtual, device }
    }

    pub fn n_servers(&self) -> u32 {
        self.n_gpus.div_ceil(self.device.gpus_per_server)
    }

    /// Effective dense rate per GPU (FLOP/s).
    pub fn effective_flops(&self) -> f64 {
        self.device.effective_flops()
    }

    /// Does a replica of `n` GPUs with TP degree `tp` span servers with its
    /// tensor-parallel group?
    pub fn tp_spans_servers(&self, tp: u32) -> bool {
        tp > self.device.gpus_per_server
    }

    /// Bandwidth seen by a TP group of the given degree.
    ///
    /// A TP group spanning servers pays an additional effectiveness penalty
    /// beyond the raw link-rate drop: the latency-bound, unoverlapped
    /// per-layer collectives of tensor parallelism achieve a small fraction
    /// of the inter-server fabric (the paper: 70B Task-Fused "must utilize
    /// a TP degree of 16 ... extremely inefficient due to the slow
    /// communication across servers").
    pub fn tp_bandwidth(&self, tp: u32) -> f64 {
        const CROSS_SERVER_TP_PENALTY: f64 = 2.0;
        if self.tp_spans_servers(tp) {
            self.device.inter_bw_gbs / CROSS_SERVER_TP_PENALTY
        } else {
            self.device.intra_bw_gbs
        }
    }
}

/// A fleet of device pools with a single global server and GPU numbering:
/// pool 0's servers come first, then pool 1's, and a server's GPUs are
/// contiguous. Cluster churn events (`join`/`leave`/`preempt`) address this
/// global numbering.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualCluster {
    pub name: String,
    pub pools: Vec<ClusterSpec>,
}

impl VirtualCluster {
    pub fn homogeneous(pool: ClusterSpec) -> Self {
        Self { name: pool.name.clone(), pools: vec![pool] }
    }

    pub fn mixed(pools: Vec<ClusterSpec>) -> Self {
        let name = pools
            .iter()
            .map(|p| p.name.as_str())
            .collect::<Vec<_>>()
            .join("+");
        Self { name, pools }
    }

    /// Parse a `--cluster` pool spec: `+`-separated `device[:count]`
    /// segments, e.g. `a100:16+h100:8`. A bare device name (legacy single
    /// pool form, `a100`) takes `default_gpus` as its size; segments of a
    /// mixed spec must size themselves explicitly.
    pub fn parse(spec: &str, default_gpus: u32) -> Result<Self, String> {
        let segments: Vec<&str> = spec.split('+').collect();
        let mut pools = Vec::new();
        for seg in &segments {
            let (dev_name, count) = match seg.split_once(':') {
                Some((d, c)) => {
                    let n: u32 = c.parse().map_err(|_| {
                        format!("bad pool size in --cluster segment {seg:?}")
                    })?;
                    (d, n)
                }
                None if segments.len() == 1 => (*seg, default_gpus),
                None => {
                    return Err(format!(
                        "mixed --cluster segment {seg:?} needs an explicit \
                         size (device:count, e.g. h100:8)"
                    ))
                }
            };
            let device = DeviceProfile::by_name(dev_name).ok_or_else(|| {
                format!(
                    "unknown device {dev_name:?} in --cluster (known: a100, \
                     a800, h100, local)"
                )
            })?;
            if count == 0 {
                return Err(format!("empty pool in --cluster segment {seg:?}"));
            }
            pools.push(if device.name == "CPU-virtual" {
                ClusterSpec::local_cpu(count)
            } else {
                ClusterSpec::of(device, count)
            });
        }
        if pools.is_empty() {
            return Err("empty --cluster spec".to_string());
        }
        Ok(if pools.len() == 1 {
            Self::homogeneous(pools.remove(0))
        } else {
            Self::mixed(pools)
        })
    }

    pub fn is_mixed(&self) -> bool {
        self.pools.len() > 1
    }

    pub fn total_gpus(&self) -> u32 {
        self.pools.iter().map(|p| p.n_gpus).sum()
    }

    pub fn n_servers(&self) -> u32 {
        self.pools.iter().map(|p| p.n_servers()).sum()
    }

    /// Map a global server id to `(pool index, server-within-pool)`.
    pub fn pool_of_server(&self, server: u32) -> Option<(usize, u32)> {
        let mut base = 0;
        for (i, p) in self.pools.iter().enumerate() {
            let n = p.n_servers();
            if server < base + n {
                return Some((i, server - base));
            }
            base += n;
        }
        None
    }

    /// Global `[start, end)` GPU-id span of a global server id. The last
    /// server of a ragged pool (n_gpus not a multiple of gpus_per_server)
    /// holds the remainder.
    pub fn server_gpu_span(&self, server: u32) -> Option<(u32, u32)> {
        let (pool, local) = self.pool_of_server(server)?;
        let pool_base: u32 = self.pools[..pool].iter().map(|p| p.n_gpus).sum();
        let p = &self.pools[pool];
        let start = pool_base + local * p.device.gpus_per_server;
        let end = (start + p.device.gpus_per_server).min(pool_base + p.n_gpus);
        Some((start, end))
    }

    /// Map a global GPU id to its pool index.
    pub fn pool_of_gpu(&self, gpu: u32) -> Option<usize> {
        let mut base = 0;
        for (i, p) in self.pools.iter().enumerate() {
            if gpu < base + p.n_gpus {
                return Some(i);
            }
            base += p.n_gpus;
        }
        None
    }
}

/// Which GPUs of a [`VirtualCluster`] are currently up. Join/leave/preempt
/// events mutate this; the serving runtime turns the per-pool available
/// counts into planner capacity budgets. All ids are global.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetAvailability {
    /// Globally-numbered GPUs currently down.
    down: BTreeSet<u32>,
    /// Per-pool pool-GPU counts (cached geometry).
    pool_sizes: Vec<u32>,
}

impl FleetAvailability {
    /// Full fleet: everything up.
    pub fn full(fleet: &VirtualCluster) -> Self {
        Self {
            down: BTreeSet::new(),
            pool_sizes: fleet.pools.iter().map(|p| p.n_gpus).collect(),
        }
    }

    /// A whole server leaves (spot reclaim, hardware failure). Errors on an
    /// unknown server id and on a server that is already fully down.
    pub fn node_leave(
        &mut self,
        fleet: &VirtualCluster,
        server: u32,
    ) -> Result<u32, String> {
        let (start, end) = fleet
            .server_gpu_span(server)
            .ok_or_else(|| format!("leave of unknown server {server}"))?;
        let newly: Vec<u32> =
            (start..end).filter(|g| !self.down.contains(g)).collect();
        if newly.is_empty() {
            return Err(format!("leave of already-down server {server}"));
        }
        self.down.extend(newly.iter().copied());
        Ok(newly.len() as u32)
    }

    /// A server (re)joins: every down GPU it hosts comes back, whether it
    /// went down via `leave` or via a `preempt` range. Errors on an unknown
    /// server id and on a server with nothing down.
    pub fn node_join(
        &mut self,
        fleet: &VirtualCluster,
        server: u32,
    ) -> Result<u32, String> {
        let (start, end) = fleet
            .server_gpu_span(server)
            .ok_or_else(|| format!("join of unknown server {server}"))?;
        let restored: Vec<u32> =
            (start..end).filter(|g| self.down.contains(g)).collect();
        if restored.is_empty() {
            return Err(format!("join of already-up server {server}"));
        }
        for g in &restored {
            self.down.remove(g);
        }
        Ok(restored.len() as u32)
    }

    /// A `[start, end)` global GPU range is preempted. Errors on an empty or
    /// inverted range, a range past the fleet, and on overlap with GPUs that
    /// are already down.
    pub fn preempt(
        &mut self,
        fleet: &VirtualCluster,
        gpu_range: (u32, u32),
    ) -> Result<u32, String> {
        let (start, end) = gpu_range;
        if start >= end {
            return Err(format!("empty preempt range [{start}, {end})"));
        }
        if end > fleet.total_gpus() {
            return Err(format!(
                "preempt range [{start}, {end}) exceeds fleet of {} GPUs",
                fleet.total_gpus()
            ));
        }
        if let Some(g) = (start..end).find(|g| self.down.contains(g)) {
            return Err(format!(
                "preempt range [{start}, {end}) overlaps already-down GPU {g}"
            ));
        }
        self.down.extend(start..end);
        Ok(end - start)
    }

    /// Available GPUs in one pool.
    pub fn available_in_pool(&self, pool: usize) -> u32 {
        let base: u32 = self.pool_sizes[..pool].iter().sum();
        let size = self.pool_sizes[pool];
        let down = self.down.range(base..base + size).count() as u32;
        size - down
    }

    /// Available GPUs per pool.
    pub fn available(&self) -> Vec<u32> {
        (0..self.pool_sizes.len()).map(|p| self.available_in_pool(p)).collect()
    }

    pub fn total_available(&self) -> u32 {
        let total: u32 = self.pool_sizes.iter().sum();
        total - self.down.len() as u32
    }

    pub fn is_full(&self) -> bool {
        self.down.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let c = ClusterSpec::a100_40g(16);
        assert_eq!(c.n_servers(), 2);
        assert_eq!(c.device.gpu_mem_gib, 40.0);
        let c2 = ClusterSpec::a800_80g(64);
        assert_eq!(c2.n_servers(), 8);
    }

    #[test]
    fn tp_span_detection() {
        let c = ClusterSpec::a100_40g(64);
        assert!(!c.tp_spans_servers(8));
        assert!(c.tp_spans_servers(16));
        assert!(c.tp_bandwidth(16) < c.tp_bandwidth(8));
    }

    #[test]
    fn device_by_name_covers_presets() {
        for (alias, want) in [
            ("a100", "A100-40G"),
            ("A800", "A800-80G"),
            ("h100", "H100-80G"),
            ("local", "CPU-virtual"),
        ] {
            let d = DeviceProfile::by_name(alias).expect(alias);
            assert_eq!(d.name, want);
        }
        assert!(DeviceProfile::by_name("tpu").is_none());
    }

    #[test]
    fn shim_constructors_match_profiles() {
        assert_eq!(ClusterSpec::a100_40g(16).device, DeviceProfile::a100_40g());
        assert_eq!(ClusterSpec::h100_80g(8).device, DeviceProfile::h100_80g());
        // local_cpu packs all virtual devices into one server
        let l = ClusterSpec::local_cpu(4);
        assert_eq!(l.device.gpus_per_server, 4);
        assert_eq!(l.n_servers(), 1);
    }

    #[test]
    fn parse_single_and_mixed_pools() {
        let single = VirtualCluster::parse("a100", 16).unwrap();
        assert!(!single.is_mixed());
        assert_eq!(single.total_gpus(), 16);
        assert_eq!(single.pools[0], ClusterSpec::a100_40g(16));

        let sized = VirtualCluster::parse("h100:8", 16).unwrap();
        assert_eq!(sized.total_gpus(), 8);

        let mixed = VirtualCluster::parse("a100:16+h100:8", 4).unwrap();
        assert!(mixed.is_mixed());
        assert_eq!(mixed.total_gpus(), 24);
        assert_eq!(mixed.n_servers(), 3);
        assert_eq!(mixed.name, "16xA100-40G+8xH100-80G");

        assert!(VirtualCluster::parse("a100+h100:8", 16).is_err());
        assert!(VirtualCluster::parse("tpu:8", 16).is_err());
        assert!(VirtualCluster::parse("a100:0", 16).is_err());
    }

    #[test]
    fn global_geometry() {
        let fleet = VirtualCluster::parse("a100:16+h100:8", 16).unwrap();
        // servers: 0,1 = a100 pool (gpus 0..8, 8..16), 2 = h100 (16..24)
        assert_eq!(fleet.pool_of_server(0), Some((0, 0)));
        assert_eq!(fleet.pool_of_server(2), Some((1, 0)));
        assert_eq!(fleet.pool_of_server(3), None);
        assert_eq!(fleet.server_gpu_span(1), Some((8, 16)));
        assert_eq!(fleet.server_gpu_span(2), Some((16, 24)));
        assert_eq!(fleet.pool_of_gpu(15), Some(0));
        assert_eq!(fleet.pool_of_gpu(16), Some(1));
        assert_eq!(fleet.pool_of_gpu(24), None);
    }

    #[test]
    fn ragged_last_server_span() {
        let fleet =
            VirtualCluster::homogeneous(ClusterSpec::a100_40g(12));
        assert_eq!(fleet.n_servers(), 2);
        assert_eq!(fleet.server_gpu_span(1), Some((8, 12)));
    }

    #[test]
    fn availability_churn_round_trip() {
        let fleet = VirtualCluster::parse("a100:16+h100:8", 16).unwrap();
        let mut avail = FleetAvailability::full(&fleet);
        assert!(avail.is_full());
        assert_eq!(avail.available(), vec![16, 8]);

        // preempt half of server 1, then the rest leaves as a node failure
        assert_eq!(avail.preempt(&fleet, (12, 16)), Ok(4));
        assert_eq!(avail.available(), vec![12, 8]);
        assert!(avail.preempt(&fleet, (14, 18)).is_err(), "overlap rejected");
        assert_eq!(avail.node_leave(&fleet, 1), Ok(4));
        assert_eq!(avail.available(), vec![8, 8]);
        assert!(avail.node_leave(&fleet, 1).is_err(), "already fully down");
        assert!(avail.node_leave(&fleet, 9).is_err(), "unknown server");

        // one join restores both the preempted range and the left half
        assert_eq!(avail.node_join(&fleet, 1), Ok(8));
        assert!(avail.is_full());
        assert!(avail.node_join(&fleet, 1).is_err(), "already up");

        assert_eq!(avail.preempt(&fleet, (16, 24)), Ok(8));
        assert_eq!(avail.available(), vec![16, 0]);
        assert_eq!(avail.total_available(), 16);
        assert_eq!(avail.node_join(&fleet, 2), Ok(8));
        assert!(avail.is_full());
    }

    #[test]
    fn preempt_bounds_checked() {
        let fleet = VirtualCluster::homogeneous(ClusterSpec::a100_40g(8));
        let mut avail = FleetAvailability::full(&fleet);
        assert!(avail.preempt(&fleet, (4, 4)).is_err());
        assert!(avail.preempt(&fleet, (6, 5)).is_err());
        assert!(avail.preempt(&fleet, (4, 9)).is_err());
        assert!(avail.preempt(&fleet, (4, 8)).is_ok());
    }
}
