//! Cluster substrate: topology description + communication timing model +
//! discrete accounting of GPU seconds.
//!
//! The paper's two testbeds (2×8 A100-40G NVLink/IB, 8×8 A800-80G) are not
//! available here; every planning and dispatching decision in LobRA is made
//! against the *profiled cost model* (paper Appendix D), so the substrate we
//! must reproduce faithfully is that model's inputs: GPU memory capacity,
//! dense-matmul rate, and intra-/inter-server bandwidth. See
//! DESIGN.md#hardware-adaptation.

mod comm;
mod sim;

pub use comm::CommModel;
pub use sim::{GpuLedger, ReplicaSim};



/// Static description of a GPU cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub n_gpus: u32,
    pub gpus_per_server: u32,
    /// Per-GPU memory in GiB.
    pub gpu_mem_gib: f64,
    /// Dense bf16 rate per GPU, in TFLOP/s.
    pub tflops: f64,
    /// Achievable fraction of peak for transformer training.
    pub mfu: f64,
    /// Intra-server (NVLink) bandwidth, GB/s.
    pub intra_bw_gbs: f64,
    /// Inter-server (IB) bandwidth, GB/s.
    pub inter_bw_gbs: f64,
}

impl ClusterSpec {
    /// Paper testbed 1: servers of 8×A100-40G, 600 GB/s NVLink, 100 GB/s IB.
    pub fn a100_40g(n_gpus: u32) -> Self {
        Self {
            name: format!("{n_gpus}xA100-40G"),
            n_gpus,
            gpus_per_server: 8,
            gpu_mem_gib: 40.0,
            tflops: 312.0,
            mfu: 0.42,
            intra_bw_gbs: 600.0,
            inter_bw_gbs: 100.0,
        }
    }

    /// Paper testbed 2: servers of 8×A800-80G, 400 GB/s NVLink, 200 GB/s IB.
    pub fn a800_80g(n_gpus: u32) -> Self {
        Self {
            name: format!("{n_gpus}xA800-80G"),
            n_gpus,
            gpus_per_server: 8,
            gpu_mem_gib: 80.0,
            tflops: 312.0,
            mfu: 0.42,
            intra_bw_gbs: 400.0,
            inter_bw_gbs: 200.0,
        }
    }

    /// The local CPU "cluster" used by the real PJRT e2e run: bandwidth and
    /// rate numbers are only used for simulated-clock accounting.
    pub fn local_cpu(n_virtual: u32) -> Self {
        Self {
            name: format!("{n_virtual}xCPU-virtual"),
            n_gpus: n_virtual,
            gpus_per_server: n_virtual.max(1),
            gpu_mem_gib: 16.0,
            tflops: 0.1,
            mfu: 0.5,
            intra_bw_gbs: 20.0,
            inter_bw_gbs: 20.0,
        }
    }

    pub fn n_servers(&self) -> u32 {
        self.n_gpus.div_ceil(self.gpus_per_server)
    }

    /// Effective dense rate per GPU (FLOP/s).
    pub fn effective_flops(&self) -> f64 {
        self.tflops * 1e12 * self.mfu
    }

    /// Does a replica of `n` GPUs with TP degree `tp` span servers with its
    /// tensor-parallel group?
    pub fn tp_spans_servers(&self, tp: u32) -> bool {
        tp > self.gpus_per_server
    }

    /// Bandwidth seen by a TP group of the given degree.
    ///
    /// A TP group spanning servers pays an additional effectiveness penalty
    /// beyond the raw link-rate drop: the latency-bound, unoverlapped
    /// per-layer collectives of tensor parallelism achieve a small fraction
    /// of the inter-server fabric (the paper: 70B Task-Fused "must utilize
    /// a TP degree of 16 ... extremely inefficient due to the slow
    /// communication across servers").
    pub fn tp_bandwidth(&self, tp: u32) -> f64 {
        const CROSS_SERVER_TP_PENALTY: f64 = 2.0;
        if self.tp_spans_servers(tp) {
            self.inter_bw_gbs / CROSS_SERVER_TP_PENALTY
        } else {
            self.intra_bw_gbs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let c = ClusterSpec::a100_40g(16);
        assert_eq!(c.n_servers(), 2);
        assert_eq!(c.gpu_mem_gib, 40.0);
        let c2 = ClusterSpec::a800_80g(64);
        assert_eq!(c2.n_servers(), 8);
    }

    #[test]
    fn tp_span_detection() {
        let c = ClusterSpec::a100_40g(64);
        assert!(!c.tp_spans_servers(8));
        assert!(c.tp_spans_servers(16));
        assert!(c.tp_bandwidth(16) < c.tp_bandwidth(8));
    }
}
