//! Collective / point-to-point communication timing (ring model).

use super::ClusterSpec;

/// Per-collective fixed latency (launch + sync), seconds.
const COLLECTIVE_LATENCY: f64 = 20e-6;

/// Achievable fraction of link bandwidth for all-reduce at training message
/// sizes. Calibrated so the per-GPU throughput ratios between TP degrees
/// reproduce the paper's Table 3 (⟨8,1⟩/⟨1,1⟩ ≈ 0.55, ⟨2,1⟩/⟨1,1⟩ ≈ 0.84):
/// real Megatron-style TP pays unoverlapped, latency-gapped collectives
/// that land far from peak ring bandwidth.
const ALLREDUCE_BW_EFF: f64 = 0.2;

/// Analytical communication model over a [`ClusterSpec`].
#[derive(Debug, Clone)]
pub struct CommModel {
    cluster: ClusterSpec,
}

impl CommModel {
    pub fn new(cluster: &ClusterSpec) -> Self {
        Self { cluster: cluster.clone() }
    }

    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    /// Ring all-reduce of `bytes` among `n` ranks at `bw` GB/s.
    fn ring_allreduce(bytes: f64, n: u32, bw_gbs: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let eff_bytes = 2.0 * (n as f64 - 1.0) / n as f64 * bytes;
        COLLECTIVE_LATENCY * (n as f64).log2().ceil()
            + eff_bytes / (bw_gbs * ALLREDUCE_BW_EFF * 1e9)
    }

    /// One tensor-parallel all-reduce of `bytes` within a TP group.
    pub fn tp_allreduce(&self, bytes: f64, tp: u32) -> f64 {
        Self::ring_allreduce(bytes, tp, self.cluster.tp_bandwidth(tp))
    }

    /// Pipeline stage-to-stage activation send.
    ///
    /// Adjacent PP stages are placed on the same server when the stage's TP
    /// group leaves room, otherwise they cross servers.
    pub fn pp_p2p(&self, bytes: f64, tp: u32) -> f64 {
        let bw = if tp >= self.cluster.device.gpus_per_server {
            self.cluster.device.inter_bw_gbs
        } else {
            self.cluster.device.intra_bw_gbs
        };
        COLLECTIVE_LATENCY + bytes / (bw * 1e9)
    }

    /// Data-parallel gradient sync among `n_replicas` replica groups
    /// (LoRA-only gradients in LobRA — small but synchronized every step).
    pub fn dp_allreduce(&self, bytes: f64, n_replicas: u32) -> f64 {
        // Heterogeneous replicas generally live on different servers.
        Self::ring_allreduce(bytes, n_replicas, self.cluster.device.inter_bw_gbs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comm() -> CommModel {
        CommModel::new(&ClusterSpec::a100_40g(16))
    }

    #[test]
    fn single_rank_free() {
        assert_eq!(comm().tp_allreduce(1e9, 1), 0.0);
    }

    #[test]
    fn allreduce_monotone_in_bytes() {
        let c = comm();
        assert!(c.tp_allreduce(2e9, 4) > c.tp_allreduce(1e9, 4));
    }

    #[test]
    fn cross_server_tp_slower() {
        let c = CommModel::new(&ClusterSpec::a100_40g(64));
        let small = c.tp_allreduce(1e9, 8);
        let big = c.tp_allreduce(1e9, 16);
        // 16-way TP crosses servers: much slower despite only 2x ranks.
        assert!(big > small * 2.0, "{big} vs {small}");
    }

    #[test]
    fn dp_sync_scales_with_replicas() {
        let c = comm();
        assert!(c.dp_allreduce(1e6, 8) > c.dp_allreduce(1e6, 2));
    }
}
