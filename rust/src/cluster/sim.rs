//! GPU-time accounting for simulated joint-FT execution.
//!
//! The paper's headline metric is *GPU seconds per training step*: with a
//! synchronous parameter sync every step, all `N` deployed GPUs are occupied
//! until the slowest replica finishes, so a step costs `N × max_i t_i`
//! (Figure 4 counts exactly this way: 16 GPUs × 18.20 s = 291.2 GPU·s).
//! `GpuLedger` tracks busy vs. idle split per replica so the Figure 9 case
//! study can show where the idle time goes.

use crate::config::ParallelConfig;

/// One deployed FT replica's identity in the ledger.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSim {
    pub config: ParallelConfig,
    /// Index among replicas sharing this config.
    pub index: u32,
}

/// Accumulates per-replica busy time and derives GPU-seconds / utilization.
#[derive(Debug, Clone, Default)]
pub struct GpuLedger {
    /// (config, gpus, busy_seconds) per replica, rebuilt each step.
    entries: Vec<(ParallelConfig, u32, f64)>,
    /// Accumulated over steps.
    pub total_gpu_seconds: f64,
    pub total_busy_gpu_seconds: f64,
    pub steps: u64,
}

impl GpuLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one step: per-replica busy times; the step lasts until the
    /// slowest replica finishes (synchronous LoRA sync barrier).
    pub fn record_step(&mut self, replica_busy: &[(ParallelConfig, f64)]) -> StepAccounting {
        let step_time = replica_busy
            .iter()
            .map(|&(_, t)| t)
            .fold(0.0_f64, f64::max);
        self.entries.clear();
        let mut gpu_seconds = 0.0;
        let mut busy_gpu_seconds = 0.0;
        for &(cfg, busy) in replica_busy {
            let n = cfg.n();
            gpu_seconds += n as f64 * step_time;
            busy_gpu_seconds += n as f64 * busy;
            self.entries.push((cfg, n, busy));
        }
        self.total_gpu_seconds += gpu_seconds;
        self.total_busy_gpu_seconds += busy_gpu_seconds;
        self.steps += 1;
        StepAccounting {
            step_time,
            gpu_seconds,
            busy_gpu_seconds,
            utilization: if gpu_seconds > 0.0 {
                busy_gpu_seconds / gpu_seconds
            } else {
                1.0
            },
        }
    }

    /// Mean utilization across recorded steps.
    pub fn utilization(&self) -> f64 {
        if self.total_gpu_seconds > 0.0 {
            self.total_busy_gpu_seconds / self.total_gpu_seconds
        } else {
            1.0
        }
    }

    /// Mean GPU-seconds per step.
    pub fn gpu_seconds_per_step(&self) -> f64 {
        if self.steps > 0 {
            self.total_gpu_seconds / self.steps as f64
        } else {
            0.0
        }
    }
}

/// Per-step accounting summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepAccounting {
    /// Wall-clock of the step (slowest replica).
    pub step_time: f64,
    /// `Σ_replicas n_i × step_time`.
    pub gpu_seconds: f64,
    /// `Σ_replicas n_i × busy_i`.
    pub busy_gpu_seconds: f64,
    /// busy / total.
    pub utilization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tp: u32, pp: u32) -> ParallelConfig {
        ParallelConfig::new(tp, pp)
    }

    #[test]
    fn figure4_style_accounting() {
        // Fig 4(c)-like: an 8-GPU replica idles while 1-GPU replicas work.
        let mut ledger = GpuLedger::new();
        let acc = ledger.record_step(&[
            (cfg(1, 1), 18.20),
            (cfg(1, 1), 18.20),
            (cfg(8, 1), 10.47),
        ]);
        assert!((acc.step_time - 18.20).abs() < 1e-9);
        assert!((acc.gpu_seconds - 10.0 * 18.20).abs() < 1e-9);
        // 8 GPUs idle (18.20-10.47)/18.20 ≈ 42% of the time
        let idle_frac: f64 = 1.0 - 10.47 / 18.20;
        assert!((idle_frac - 0.42).abs() < 0.01);
        assert!(acc.utilization < 1.0);
    }

    #[test]
    fn perfectly_balanced_is_fully_utilized() {
        let mut ledger = GpuLedger::new();
        let acc = ledger.record_step(&[(cfg(2, 1), 5.0), (cfg(4, 1), 5.0)]);
        assert!((acc.utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accumulates_over_steps() {
        let mut ledger = GpuLedger::new();
        ledger.record_step(&[(cfg(1, 1), 1.0)]);
        ledger.record_step(&[(cfg(1, 1), 3.0)]);
        assert_eq!(ledger.steps, 2);
        assert!((ledger.gpu_seconds_per_step() - 2.0).abs() < 1e-12);
    }
}
