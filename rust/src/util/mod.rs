//! Small shared utilities (the offline build keeps external deps to the
//! `xla` bindings + `anyhow`, so JSON parsing, RNG, parallel map, and the
//! bench harness live here).

pub mod bench;
pub mod clock;
pub mod env;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;

pub use rng::Rng;
