//! Tiny benchmark harness for the table/figure regeneration binaries
//! (offline build: no criterion). Median-of-N timing with warmup, plus
//! fixed-width table printing so every bench reproduces its paper artifact
//! as a readable report.

use crate::util::clock::{Clock, Stopwatch, WallClock};

/// Time `f` with `warmup` + `iters` runs; returns (median, mean, min) secs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, f: F) -> TimingResult {
    time_fn_with(WallClock, warmup, iters, f)
}

/// [`time_fn`] against an explicit [`Clock`] — the wall clock in the bench
/// binaries, a deterministic `SimClock` in tests of the harness itself.
pub fn time_fn_with<C, F>(clock: C, warmup: usize, iters: usize, mut f: F) -> TimingResult
where
    C: Clock + Copy,
    F: FnMut(),
{
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Stopwatch::with(clock);
        f();
        samples.push(t0.elapsed_secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    TimingResult { median, mean, min: samples[0], iters: samples.len() }
}

/// Timing summary.
#[derive(Debug, Clone, Copy)]
pub struct TimingResult {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub iters: usize,
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    // The one stdout surface in library code: every bench/CLI report
    // funnels through this printer.
    #[allow(clippy::print_stdout)]
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format seconds adaptively (ns → s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive() {
        let r = time_fn(1, 5, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert!(r.median >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median);
    }

    #[test]
    fn time_fn_with_sim_clock_is_exact() {
        // the harness consumes the Clock trait: a SimClock makes its
        // arithmetic checkable bit-exactly
        let c = crate::util::clock::SimClock::new();
        let r = time_fn_with(&c, 1, 4, || c.advance(2.0));
        assert_eq!(r.median, 2.0);
        assert_eq!(r.mean, 2.0);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.iters, 4);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("us"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
