//! Tiny benchmark harness for the table/figure regeneration binaries
//! (offline build: no criterion). Median-of-N timing with warmup, plus
//! fixed-width table printing so every bench reproduces its paper artifact
//! as a readable report.

use crate::util::clock::{Clock, Stopwatch, WallClock};

/// Time `f` with `warmup` + `iters` runs; returns (median, mean, min) secs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, f: F) -> TimingResult {
    time_fn_with(WallClock, warmup, iters, f)
}

/// [`time_fn`] against an explicit [`Clock`] — the wall clock in the bench
/// binaries, a deterministic `SimClock` in tests of the harness itself.
pub fn time_fn_with<C, F>(clock: C, warmup: usize, iters: usize, mut f: F) -> TimingResult
where
    C: Clock + Copy,
    F: FnMut(),
{
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Stopwatch::with(clock);
        f();
        samples.push(t0.elapsed_secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    TimingResult { median, mean, min: samples[0], iters: samples.len() }
}

/// Timing summary.
#[derive(Debug, Clone, Copy)]
pub struct TimingResult {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub iters: usize,
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    // The one stdout surface in library code: every bench/CLI report
    // funnels through this printer.
    #[allow(clippy::print_stdout)]
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Outcome of gating a bench's JSON output against a checked-in baseline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BaselineGate {
    /// The baseline held the `"bless": true` sentinel and was overwritten
    /// with this run's output — how the first run on a new host locks in
    /// real numbers from a toolchain-less commit.
    Blessed,
    /// Every deterministic (non-host-dependent) line matched; carries the
    /// number of lines compared.
    Ok(usize),
    /// Deterministic lines drifted; carries `(want, got)` pairs of the
    /// differing lines.
    Drift(Vec<(String, String)>),
    /// The baseline file could not be read.
    Unreadable(String),
    /// Blessing the baseline failed to write.
    WriteFailed(String),
}

/// Compare a bench's JSON output line-by-line against the baseline at
/// `path`, skipping lines `host_dependent` marks (wall-clocks and other
/// host-speed values), so the deterministic metrics are what's locked. A
/// baseline containing a `"bless": true` line is rewritten with `current`
/// instead of compared. Pure apart from the file IO: no printing, no
/// exiting — each bench renders the outcome (and exits nonzero on
/// [`BaselineGate::Drift`]) itself.
pub fn gate_against_baseline(
    path: &str,
    current: &str,
    host_dependent: &dyn Fn(&str) -> bool,
) -> BaselineGate {
    let baseline = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => return BaselineGate::Unreadable(e.to_string()),
    };
    if baseline.lines().any(|l| l.contains("\"bless\": true")) {
        return match std::fs::write(path, current) {
            Ok(()) => BaselineGate::Blessed,
            Err(e) => BaselineGate::WriteFailed(e.to_string()),
        };
    }
    let want: Vec<&str> = baseline.lines().filter(|l| !host_dependent(l)).collect();
    let got: Vec<&str> = current.lines().filter(|l| !host_dependent(l)).collect();
    if want == got {
        return BaselineGate::Ok(got.len());
    }
    let mut diff = Vec::new();
    for i in 0..want.len().max(got.len()) {
        let w = want.get(i).copied().unwrap_or("<missing>");
        let g = got.get(i).copied().unwrap_or("<missing>");
        if w != g {
            diff.push((w.to_string(), g.to_string()));
        }
    }
    BaselineGate::Drift(diff)
}

/// Format seconds adaptively (ns → s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive() {
        let r = time_fn(1, 5, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert!(r.median >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median);
    }

    #[test]
    fn time_fn_with_sim_clock_is_exact() {
        // the harness consumes the Clock trait: a SimClock makes its
        // arithmetic checkable bit-exactly
        let c = crate::util::clock::SimClock::new();
        let r = time_fn_with(&c, 1, 4, || c.advance(2.0));
        assert_eq!(r.median, 2.0);
        assert_eq!(r.mean, 2.0);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.iters, 4);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("us"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn baseline_gate_blesses_compares_and_diffs() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lobra_gate_{}.json", std::process::id()));
        let path = path.to_str().expect("utf8 temp path");
        let skip_wall = |l: &str| l.contains("wall");

        // unreadable: the file does not exist yet
        assert!(matches!(
            gate_against_baseline(path, "x", &skip_wall),
            BaselineGate::Unreadable(_)
        ));

        // bless: sentinel is replaced by the current run verbatim
        std::fs::write(path, "{\n  \"bless\": true\n}\n").unwrap();
        let run1 = "{\n  \"a\": 1,\n  \"wall\": 0.5\n}\n";
        assert_eq!(gate_against_baseline(path, run1, &skip_wall), BaselineGate::Blessed);
        assert_eq!(std::fs::read_to_string(path).unwrap(), run1);

        // identical deterministic lines pass even when the wall drifts
        let run2 = "{\n  \"a\": 1,\n  \"wall\": 9.9\n}\n";
        assert_eq!(gate_against_baseline(path, run2, &skip_wall), BaselineGate::Ok(3));

        // a deterministic drift is reported as (want, got) pairs
        let run3 = "{\n  \"a\": 2,\n  \"wall\": 0.5\n}\n";
        match gate_against_baseline(path, run3, &skip_wall) {
            BaselineGate::Drift(d) => {
                assert_eq!(d.len(), 1);
                assert!(d[0].0.contains("\"a\": 1") && d[0].1.contains("\"a\": 2"));
            }
            other => panic!("expected drift, got {other:?}"),
        }
        let _ = std::fs::remove_file(path);
    }
}
