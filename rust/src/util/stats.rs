//! Moment statistics used to validate synthetic length distributions
//! against the paper's Table 4 (mean / skewness / kurtosis per dataset).

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased-ish central moments based summary.
pub struct Moments {
    pub mean: f64,
    pub std: f64,
    /// Fisher skewness g1.
    pub skewness: f64,
    /// Excess kurtosis g2 (normal = 0), matching Table 4's convention.
    pub kurtosis: f64,
}

pub fn moments(xs: &[f64]) -> Moments {
    let n = xs.len().max(1) as f64;
    let m = mean(xs);
    let (mut m2, mut m3, mut m4) = (0.0, 0.0, 0.0);
    for &x in xs {
        let d = x - m;
        m2 += d * d;
        m3 += d * d * d;
        m4 += d * d * d * d;
    }
    m2 /= n;
    m3 /= n;
    m4 /= n;
    let std = m2.sqrt();
    let skewness = if m2 > 0.0 { m3 / m2.powf(1.5) } else { 0.0 };
    let kurtosis = if m2 > 0.0 { m4 / (m2 * m2) - 3.0 } else { 0.0 };
    Moments { mean: m, std, skewness, kurtosis }
}

/// Empirical CDF evaluated at `points` (fraction of xs <= p).
pub fn ecdf(xs: &[f64], points: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|&p| {
            let idx = sorted.partition_point(|&x| x <= p);
            idx as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// p-quantile (nearest-rank) of unsorted data.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_of_constant() {
        let m = moments(&[5.0; 100]);
        assert_eq!(m.mean, 5.0);
        assert_eq!(m.std, 0.0);
        assert_eq!(m.skewness, 0.0);
    }

    #[test]
    fn skew_of_symmetric_is_zero() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let m = moments(&xs);
        assert!(m.skewness.abs() < 1e-9);
        // uniform has excess kurtosis -1.2
        assert!((m.kurtosis + 1.2).abs() < 0.01);
    }

    #[test]
    fn ecdf_monotone() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let c = ecdf(&xs, &[0.5, 1.0, 2.5, 4.0, 9.0]);
        assert_eq!(c, vec![0.0, 0.25, 0.5, 1.0, 1.0]);
    }

    #[test]
    fn quantile_basics() {
        let xs = vec![3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }
}
