//! Wall-clock confinement (lint rule **R1**): every `Instant::now()` /
//! `SystemTime` read in the tree lives in this module, and nowhere else.
//!
//! ## Why confinement
//!
//! The repo's headline guarantees — warm == cold plan identity,
//! sliced-anytime == full-budget search, sim == scheduler bit-identity,
//! thread-count-invariant gradient reduction — are *determinism*
//! certificates. A stray wall-clock read on a decision path (a timeout
//! that prunes a candidate, a budget check that ends a slice early) voids
//! them silently: the test passes on a fast machine and flakes on a loaded
//! CI runner. Routing every clock read through one module makes the
//! wall-clock surface auditable — `detlint` (rule R1) rejects
//! `Instant`/`SystemTime` tokens anywhere outside this file — and makes
//! every timing consumer swappable for the deterministic [`SimClock`].
//!
//! Wall-clock readings are only ever *reported* (solve/step wall seconds
//! in stats structs, bench tables) or charged against *budgets* that the
//! deterministic paths meter with [`SimClock`]-style counters instead
//! (`BudgetMeter::SimPerPlan`); no plan decision may branch on
//! [`WallClock`] time. The async planner service
//! (`coordinator::service`) is the one deliberately timing-dependent
//! consumer: its slice walls feed `BudgetMeter::Wall` charging and the
//! serving report's overlapped/unoverlapped search split — but the *plans*
//! it publishes are terminal search results, certified bit-identical to
//! the sync path's, so timing decides only *when* a plan lands, never
//! *which* plan.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::Instant;

/// A monotonic clock reporting seconds since its epoch.
///
/// Implementors: [`WallClock`] (real time, process-start epoch) for
/// production timing, [`SimClock`] (manually advanced) for deterministic
/// tests and simulation. Consumers — [`Stopwatch`], the bench harness
/// (`util::bench::time_fn_with`), `BudgetMeter::Wall` charging — take the
/// trait, never `std::time` directly.
pub trait Clock {
    /// Monotonic seconds since this clock's epoch.
    fn now_secs(&self) -> f64;
}

/// Clocks pass through shared references, so a non-`Copy` clock (e.g.
/// [`SimClock`]) can drive a [`Stopwatch`] it outlives.
impl<C: Clock + ?Sized> Clock for &C {
    fn now_secs(&self) -> f64 {
        (**self).now_secs()
    }
}

/// The real monotonic wall clock. Epoch = first read anywhere in the
/// process, so readings are small positive floats with full `f64`
/// precision over any realistic run length.
#[derive(Debug, Clone, Copy, Default)]
pub struct WallClock;

fn process_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

impl Clock for WallClock {
    fn now_secs(&self) -> f64 {
        process_epoch().elapsed().as_secs_f64()
    }
}

/// A manually-advanced deterministic clock: reads return exactly what the
/// test or simulation has [`advance`](SimClock::advance)d to, independent
/// of host speed. The serving runtime's `BudgetMeter::SimPerPlan` is the
/// same idea specialized to search work (seconds per enumerated plan).
#[derive(Debug, Default)]
pub struct SimClock {
    now: Cell<f64>,
}

impl SimClock {
    /// A clock at `t = 0`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance the clock by `dt` seconds (`dt` may be fractional; negative
    /// advances are ignored to keep the clock monotonic).
    pub fn advance(&self, dt: f64) {
        if dt > 0.0 {
            self.now.set(self.now.get() + dt);
        }
    }
}

impl Clock for SimClock {
    fn now_secs(&self) -> f64 {
        self.now.get()
    }
}

/// Span timer over any [`Clock`]; the one way the rest of the tree times
/// things. `Stopwatch::start()` is the wall-clock shorthand the old
/// `let t0 = Instant::now(); ... t0.elapsed().as_secs_f64()` idiom maps
/// onto.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch<C: Clock = WallClock> {
    clock: C,
    start: f64,
}

impl Stopwatch<WallClock> {
    /// Start timing against the real wall clock.
    pub fn start() -> Self {
        Self::with(WallClock)
    }
}

impl<C: Clock> Stopwatch<C> {
    /// Start timing against `clock`.
    pub fn with(clock: C) -> Self {
        let start = clock.now_secs();
        Self { clock, start }
    }

    /// Seconds elapsed on the underlying clock since this stopwatch
    /// started.
    pub fn elapsed_secs(&self) -> f64 {
        self.clock.now_secs() - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let a = WallClock.now_secs();
        let b = WallClock.now_secs();
        assert!(a >= 0.0);
        assert!(b >= a);
    }

    #[test]
    fn stopwatch_measures_nonnegative_spans() {
        let sw = Stopwatch::start();
        assert!(sw.elapsed_secs() >= 0.0);
    }

    #[test]
    fn sim_clock_is_deterministic() {
        let c = SimClock::new();
        assert_eq!(c.now_secs(), 0.0);
        c.advance(1.5);
        c.advance(0.25);
        assert_eq!(c.now_secs(), 1.75);
        c.advance(-3.0); // ignored: the clock never runs backwards
        assert_eq!(c.now_secs(), 1.75);
    }

    #[test]
    fn stopwatch_over_sim_clock() {
        let c = SimClock::new();
        c.advance(10.0);
        let sw = Stopwatch::with(&c);
        c.advance(2.5);
        assert_eq!(sw.elapsed_secs(), 2.5);
        c.advance(0.5);
        assert_eq!(sw.elapsed_secs(), 3.0);
    }
}
