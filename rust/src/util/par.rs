//! Minimal data-parallel primitives over std::thread (offline build: no
//! rayon).
//!
//! Used by the planner to evaluate candidate deployment plans concurrently
//! ([`par_map`]) and to run the fused streaming plan search without a
//! collect-then-map barrier ([`par_fold`]). The async planner service
//! builds on the cross-thread primitives here: [`CancelToken`]
//! (supersession of in-flight searches), [`EpochCell`] (lock-free
//! epoch-counted plan publication) and [`with_max_threads`] (scoped
//! worker-count control for a service thread without mutating process
//! globals).
//!
//! Raw `std::thread` spawning is confined to this module and the planner
//! service (`coordinator::service`) by detlint rule R6: ad-hoc threads
//! elsewhere could reorder float reductions or leak nondeterministic
//! timing into certified paths.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

/// Test-only worker-count override; 0 = none. See
/// [`set_max_threads_override`].
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread worker-count scope; 0 = none. See [`with_max_threads`].
    static LOCAL_OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

/// Worker count for the parallel primitives: `LOBRA_NUM_THREADS` if set
/// (≥ 1; 0 or unset = auto), else available parallelism. Results never
/// depend on this — the executors reduce in input order (see
/// [`crate::exec::tree_reduce`]) and `par_map`/`par_fold` preserve it —
/// so the env var is a tuning and determinism-*testing* knob, not a
/// correctness one.
///
/// The env knob is read through the [`crate::util::env`] snapshot and
/// cached here once per process: a mid-run `set_var` cannot change
/// parallelism between two halves of a certificate test. (That race is
/// why `tests/par_determinism.rs` lives in its own test binary —
/// concurrent `set_var`/`getenv` is UB on glibc — and with the cache the
/// binary isolation is now belt-and-suspenders rather than load-bearing.)
pub fn max_threads() -> usize {
    let scoped = LOCAL_OVERRIDE.with(Cell::get);
    if scoped > 0 {
        return scoped;
    }
    let forced = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if forced > 0 {
        return forced;
    }
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env::parse_or::<usize>("LOBRA_NUM_THREADS", 0) {
            0 => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            n => n,
        }
    })
}

/// Force the worker count for determinism tests (`None` restores the
/// cached env/auto value). The env snapshot is immutable by design
/// (rule R3), so tests that sweep thread counts — e.g.
/// `tests/par_determinism.rs` proving gradient reduction is
/// thread-count-invariant — use this instead of mutating
/// `LOBRA_NUM_THREADS` mid-process.
pub fn set_max_threads_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// Run `f` with the worker count pinned to `n` (≥ 1) *on this thread
/// only*. The innermost scope wins over both the global test override and
/// the env/auto value, and the previous scope is restored on exit (also
/// across unwinds). This is how the planner service thread bounds its
/// slice parallelism (`--planner-threads`) without mutating process-wide
/// state that the training event loop also reads.
pub fn with_max_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_OVERRIDE.with(|c| c.replace(n.max(1)));
    let _restore = Restore(prev);
    f()
}

/// Cooperative cancellation flag shared between an event thread and an
/// in-flight plan search. Cloning shares the flag. The planner checks it
/// inside `PlanCursor` slices (every enumerated plan), so a superseding
/// event interrupts a search mid-slice instead of waiting for cooperative
/// slice exhaustion. Cancellation is a *discard* signal: a cancelled
/// search's partial results are thrown away (the enumeration prefix it
/// covered depends on where the flag was observed), which is why the
/// deterministic sync path never arms a token.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm the flag. Idempotent; visible to all clones.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Has [`Self::cancel`] been called on any clone?
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// A published value plus the epoch it belongs to.
struct Slot<T> {
    epoch: u64,
    value: Arc<T>,
}

/// Lock-free epoch-counted publication cell: a single writer (or several,
/// serialized internally) publishes `Arc<T>` snapshots tagged with a
/// strictly increasing epoch; readers take a wait-free snapshot of the
/// newest published value without ever blocking on the writer. This is
/// the channel through which the planner service hands best-so-far plans
/// to the training event loop: the loop polls at step boundaries and can
/// never observe a torn value (it clones a whole `Arc`) or an epoch
/// moving backwards ([`Self::publish`] rejects stale epochs).
///
/// # Memory reclamation
///
/// Superseded slots are retired, not freed inline: a publisher frees the
/// retired list only when it observes zero in-flight readers, so a reader
/// holding a snapshot-in-progress keeps every retired slot alive (a
/// single-counter hazard scheme — reclamation can be deferred under
/// constant reader traffic, never unsound). Readers increment the
/// in-flight counter *before* loading the pointer; in the `SeqCst` total
/// order any reader still dereferencing an old slot is therefore visible
/// to the publisher's zero-check, and any reader that increments after
/// that check loads the new pointer.
pub struct EpochCell<T> {
    ptr: AtomicPtr<Slot<T>>,
    readers: AtomicUsize,
    retired: Mutex<Vec<*mut Slot<T>>>,
}

// Safety: the raw pointers are owned boxes created by `publish` and freed
// exactly once (retire list or Drop) under the publisher mutex; `T` is
// only ever shared across threads behind `Arc<T>`, hence the
// `Send + Sync` bound.
unsafe impl<T: Send + Sync> Send for EpochCell<T> {}
unsafe impl<T: Send + Sync> Sync for EpochCell<T> {}

impl<T> EpochCell<T> {
    /// An empty cell (readers observe `None` until the first publish).
    pub fn new() -> Self {
        Self {
            ptr: AtomicPtr::new(std::ptr::null_mut()),
            readers: AtomicUsize::new(0),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Publish `value` at `epoch`. Returns `false` (and publishes
    /// nothing) unless `epoch` is strictly newer than the current one —
    /// a search superseded after it computed a plan but before it
    /// published cannot overwrite its successor's plan with a stale one.
    pub fn publish(&self, epoch: u64, value: Arc<T>) -> bool {
        // Publishers serialize on the retire-list mutex, making the
        // epoch check + swap atomic with respect to other publishers.
        // Readers never touch this lock.
        let mut retired = self.retired.lock().unwrap_or_else(PoisonError::into_inner);
        let cur = self.ptr.load(Ordering::SeqCst);
        if !cur.is_null() {
            // Safety: slots are freed only by publishers, which hold the
            // mutex; `cur` is therefore alive here.
            let cur_epoch = unsafe { (*cur).epoch };
            if epoch <= cur_epoch {
                return false;
            }
        }
        let fresh = Box::into_raw(Box::new(Slot { epoch, value }));
        let old = self.ptr.swap(fresh, Ordering::SeqCst);
        if !old.is_null() {
            retired.push(old);
        }
        if self.readers.load(Ordering::SeqCst) == 0 {
            // No reader can be mid-snapshot on any retired slot (see the
            // type-level safety note), and none that starts now can reach
            // one: new readers load `fresh`.
            for p in retired.drain(..) {
                // Safety: retired slots were created by Box::into_raw in
                // this function and are dropped exactly once (the drain
                // removes them from the list).
                unsafe { drop(Box::from_raw(p)) };
            }
        }
        true
    }

    /// Wait-free snapshot of the newest published `(epoch, value)`, or
    /// `None` before the first publish. Never blocks on publishers.
    pub fn read(&self) -> Option<(u64, Arc<T>)> {
        self.readers.fetch_add(1, Ordering::SeqCst);
        let p = self.ptr.load(Ordering::SeqCst);
        let out = if p.is_null() {
            None
        } else {
            // Safety: the incremented reader count (ordered before this
            // load) keeps the slot alive until the decrement below.
            let slot = unsafe { &*p };
            Some((slot.epoch, Arc::clone(&slot.value)))
        };
        self.readers.fetch_sub(1, Ordering::SeqCst);
        out
    }
}

impl<T> Default for EpochCell<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Drop for EpochCell<T> {
    fn drop(&mut self) {
        let retired = self.retired.get_mut().unwrap_or_else(PoisonError::into_inner);
        for p in retired.drain(..) {
            // Safety: see `publish`; &mut self means no concurrent reader.
            unsafe { drop(Box::from_raw(p)) };
        }
        let cur = *self.ptr.get_mut();
        if !cur.is_null() {
            // Safety: the current slot is the one live box not on the
            // retire list.
            unsafe { drop(Box::from_raw(cur)) };
        }
    }
}

/// Parallel map preserving input order. Spawns up to `max_threads()`
/// workers pulling items off a shared atomic cursor.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads().min(n);
    if threads <= 1 || n == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let mut results = run_stealing(&items, threads, &f);
    // every index in 0..n was claimed exactly once, so sorting the
    // (index, result) pairs restores input order without an Option
    // placeholder vector
    results.sort_by_key(|&(i, _)| i);
    results.into_iter().map(|(_, r)| r).collect()
}

/// Bounded work-stealing fold: `fold` maps each item to an accumulator
/// (items are claimed off a shared cursor, so idle workers steal the next
/// unprocessed item as soon as they finish), and the per-item accumulators
/// are merged with `merge` in *input order* — the combined result is
/// deterministic regardless of thread timing. Returns `None` for empty
/// input.
///
/// Peak memory is bounded by the live accumulators (one per item, each
/// typically already filtered/pruned by `fold`), never by a full map
/// output — this is what lets the planner fuse plan enumeration with
/// lower-bound filtering instead of materializing millions of plans.
pub fn par_fold<T, A, F, M>(items: Vec<T>, fold: F, mut merge: M) -> Option<A>
where
    T: Send + Sync,
    A: Send,
    F: Fn(&T) -> A + Sync,
    M: FnMut(A, A) -> A,
{
    let n = items.len();
    if n == 0 {
        return None;
    }
    let threads = max_threads().min(n);
    let mut accs: Vec<(usize, A)> = if threads <= 1 {
        items.iter().enumerate().map(|(i, t)| (i, fold(t))).collect()
    } else {
        run_stealing(&items, threads, &fold)
    };
    accs.sort_by_key(|&(i, _)| i);
    accs.into_iter().map(|(_, a)| a).reduce(|a, b| merge(a, b))
}

/// Shared work-stealing driver: apply `f` to every item, returning
/// `(index, result)` pairs in arbitrary completion order.
fn run_stealing<T, R, F>(items: &[T], threads: usize, f: &F) -> Vec<(usize, R)>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            handles.push(scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(&items[i])));
                }
                local
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    })
}

/// Deterministic binary-tree reduction in input order: pairs `(0,1)`,
/// `(2,3)`, … are combined level by level until one value remains. The
/// shape depends only on `items.len()`, never on thread timing, so
/// reductions over `par_map` outputs — and the staged runtime's
/// tensor-parallel all-reduces, which reuse this exact ordering — are
/// reproducible for any worker count.
pub fn tree_reduce<T>(items: Vec<T>, mut combine: impl FnMut(T, T) -> T) -> Option<T> {
    let mut level = items;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        level = next;
    }
    level.pop()
}

/// Run one closure per pipeline stage on its own OS thread and join them
/// all, resuming any stage panic on the caller.
///
/// This is the raw-thread home (rule R6) for the staged runtime's 1F1B
/// microbatch pipeline: each stage *blocks* on channel recvs from its
/// neighbors, so stages must not share a bounded worker pool —
/// `max_threads()` capping would deadlock the pipeline (a stage waiting
/// for a worker slot held by the stage it feeds). Pipeline depth is pp
/// (≤ a replica's GPU count), so the thread count stays small and
/// bounded by the plan, not the data.
///
/// Determinism: stage results are returned in stage order, and the
/// stages themselves communicate over channels in a schedule fixed by
/// (pp, microbatch count) alone — thread timing affects wall-clock
/// only, never values.
pub fn scoped_pipeline<R, F>(stages: Vec<F>) -> Vec<R>
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(stages.len());
        for stage in stages {
            handles.push(scope.spawn(stage));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(xs.clone(), |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(e, |&x| x).is_empty());
        assert_eq!(par_map(vec![7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn actually_parallel_under_load() {
        // smoke: heavy closure across many items completes correctly
        let xs: Vec<u64> = (0..64).collect();
        let ys = par_map(xs, |&x| (0..10_000u64).fold(x, |a, b| a.wrapping_add(b)));
        assert_eq!(ys.len(), 64);
    }

    #[test]
    fn fold_merges_in_input_order() {
        // merge is order-sensitive (string concat): the result must follow
        // input order no matter how the items were stolen
        let xs: Vec<u32> = (0..200).collect();
        let merged = par_fold(
            xs.clone(),
            |&x| x.to_string(),
            |a, b| format!("{a},{b}"),
        )
        .unwrap();
        let expect = xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        assert_eq!(merged, expect);
    }

    #[test]
    fn fold_empty_is_none() {
        let e: Vec<u32> = vec![];
        assert!(par_fold(e, |&x| x, |a, b| a + b).is_none());
    }

    #[test]
    fn fold_sums_match_sequential() {
        let xs: Vec<u64> = (0..10_000).collect();
        let total = par_fold(xs.clone(), |&x| x, |a, b| a + b).unwrap();
        assert_eq!(total, xs.iter().sum::<u64>());
    }

    #[test]
    fn scoped_thread_override_wins_and_restores() {
        // single test covers scoping + precedence: the global override is
        // process-wide, so exercising it from two parallel #[test] threads
        // would race
        let inner = with_max_threads(3, || {
            // nested scope: innermost wins
            let nested = with_max_threads(1, max_threads);
            assert_eq!(nested, 1);
            max_threads()
        });
        assert_eq!(inner, 3);
        // the scoped override also beats the global test override, and
        // restores to it afterwards
        set_max_threads_override(Some(7));
        assert_eq!(with_max_threads(2, max_threads), 2);
        assert_eq!(max_threads(), 7);
        set_max_threads_override(None);
    }

    #[test]
    fn cancel_token_shared_across_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!t.is_cancelled() && !u.is_cancelled());
        u.cancel();
        assert!(t.is_cancelled() && u.is_cancelled());
    }

    #[test]
    fn epoch_cell_publishes_and_rejects_stale() {
        let cell: EpochCell<Vec<u64>> = EpochCell::new();
        assert!(cell.read().is_none());
        assert!(cell.publish(1, Arc::new(vec![1])));
        assert!(cell.publish(3, Arc::new(vec![3])));
        // stale and equal epochs are rejected, newest snapshot survives
        assert!(!cell.publish(2, Arc::new(vec![2])));
        assert!(!cell.publish(3, Arc::new(vec![99])));
        let (epoch, v) = cell.read().unwrap();
        assert_eq!(epoch, 3);
        assert_eq!(*v, vec![3]);
    }

    #[test]
    fn epoch_cell_snapshot_outlives_supersession() {
        let cell: EpochCell<Vec<u64>> = EpochCell::new();
        assert!(cell.publish(1, Arc::new(vec![1, 1, 1])));
        let (_, held) = cell.read().unwrap();
        // superseding publishes retire the old slot but the Arc snapshot
        // (and its contents) stay valid
        for e in 2..64 {
            assert!(cell.publish(e, Arc::new(vec![e, e, e])));
        }
        assert_eq!(*held, vec![1, 1, 1]);
        let (epoch, newest) = cell.read().unwrap();
        assert_eq!(epoch, 63);
        assert_eq!(*newest, vec![63, 63, 63]);
    }
}
