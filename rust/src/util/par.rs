//! Minimal data-parallel primitives over std::thread (offline build: no
//! rayon).
//!
//! Used by the planner to evaluate candidate deployment plans concurrently
//! ([`par_map`]) and to run the fused streaming plan search without a
//! collect-then-map barrier ([`par_fold`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Test-only worker-count override; 0 = none. See
/// [`set_max_threads_override`].
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Worker count for the parallel primitives: `LOBRA_NUM_THREADS` if set
/// (≥ 1; 0 or unset = auto), else available parallelism. Results never
/// depend on this — the executors reduce in input order (see
/// [`crate::exec::tree_reduce`]) and `par_map`/`par_fold` preserve it —
/// so the env var is a tuning and determinism-*testing* knob, not a
/// correctness one.
///
/// The env knob is read through the [`crate::util::env`] snapshot and
/// cached here once per process: a mid-run `set_var` cannot change
/// parallelism between two halves of a certificate test. (That race is
/// why `tests/par_determinism.rs` lives in its own test binary —
/// concurrent `set_var`/`getenv` is UB on glibc — and with the cache the
/// binary isolation is now belt-and-suspenders rather than load-bearing.)
pub fn max_threads() -> usize {
    let forced = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if forced > 0 {
        return forced;
    }
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        match crate::util::env::parse_or::<usize>("LOBRA_NUM_THREADS", 0) {
            0 => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            n => n,
        }
    })
}

/// Force the worker count for determinism tests (`None` restores the
/// cached env/auto value). The env snapshot is immutable by design
/// (rule R3), so tests that sweep thread counts — e.g.
/// `tests/par_determinism.rs` proving gradient reduction is
/// thread-count-invariant — use this instead of mutating
/// `LOBRA_NUM_THREADS` mid-process.
pub fn set_max_threads_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// Parallel map preserving input order. Spawns up to `max_threads()`
/// workers pulling items off a shared atomic cursor.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads().min(n);
    if threads <= 1 || n == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let results = run_stealing(&items, threads, &f);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in results {
        out[i] = Some(r);
    }
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Bounded work-stealing fold: `fold` maps each item to an accumulator
/// (items are claimed off a shared cursor, so idle workers steal the next
/// unprocessed item as soon as they finish), and the per-item accumulators
/// are merged with `merge` in *input order* — the combined result is
/// deterministic regardless of thread timing. Returns `None` for empty
/// input.
///
/// Peak memory is bounded by the live accumulators (one per item, each
/// typically already filtered/pruned by `fold`), never by a full map
/// output — this is what lets the planner fuse plan enumeration with
/// lower-bound filtering instead of materializing millions of plans.
pub fn par_fold<T, A, F, M>(items: Vec<T>, fold: F, mut merge: M) -> Option<A>
where
    T: Send + Sync,
    A: Send,
    F: Fn(&T) -> A + Sync,
    M: FnMut(A, A) -> A,
{
    let n = items.len();
    if n == 0 {
        return None;
    }
    let threads = max_threads().min(n);
    let mut accs: Vec<(usize, A)> = if threads <= 1 {
        items.iter().enumerate().map(|(i, t)| (i, fold(t))).collect()
    } else {
        run_stealing(&items, threads, &fold)
    };
    accs.sort_by_key(|&(i, _)| i);
    accs.into_iter().map(|(_, a)| a).reduce(|a, b| merge(a, b))
}

/// Shared work-stealing driver: apply `f` to every item, returning
/// `(index, result)` pairs in arbitrary completion order.
fn run_stealing<T, R, F>(items: &[T], threads: usize, f: &F) -> Vec<(usize, R)>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            handles.push(scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(&items[i])));
                }
                local
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(xs.clone(), |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(e, |&x| x).is_empty());
        assert_eq!(par_map(vec![7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn actually_parallel_under_load() {
        // smoke: heavy closure across many items completes correctly
        let xs: Vec<u64> = (0..64).collect();
        let ys = par_map(xs, |&x| (0..10_000u64).fold(x, |a, b| a.wrapping_add(b)));
        assert_eq!(ys.len(), 64);
    }

    #[test]
    fn fold_merges_in_input_order() {
        // merge is order-sensitive (string concat): the result must follow
        // input order no matter how the items were stolen
        let xs: Vec<u32> = (0..200).collect();
        let merged = par_fold(
            xs.clone(),
            |&x| x.to_string(),
            |a, b| format!("{a},{b}"),
        )
        .unwrap();
        let expect = xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        assert_eq!(merged, expect);
    }

    #[test]
    fn fold_empty_is_none() {
        let e: Vec<u32> = vec![];
        assert!(par_fold(e, |&x| x, |a, b| a + b).is_none());
    }

    #[test]
    fn fold_sums_match_sequential() {
        let xs: Vec<u64> = (0..10_000).collect();
        let total = par_fold(xs.clone(), |&x| x, |a, b| a + b).unwrap();
        assert_eq!(total, xs.iter().sum::<u64>());
    }
}
