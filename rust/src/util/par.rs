//! Minimal data-parallel map over std::thread (offline build: no rayon).
//!
//! Used by the planner to evaluate candidate deployment plans concurrently.

/// Parallel map preserving input order. Spawns up to `threads` workers
/// (default: available parallelism) chunking the input by atomic counter.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    if threads <= 1 || n == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = std::sync::Mutex::new(&mut out);
    // index-stamped results gathered through a channel-free design:
    // each worker writes directly into its slot via raw indexing guarded
    // by the disjointness of indices.
    let results: Vec<(usize, R)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let items = &items;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(&items[i])));
                }
                local
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    {
        let mut guard = slots.lock().unwrap();
        for (i, r) in results {
            guard[i] = Some(r);
        }
    }
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(xs.clone(), |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(e, |&x| x).is_empty());
        assert_eq!(par_map(vec![7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn actually_parallel_under_load() {
        // smoke: heavy closure across many items completes correctly
        let xs: Vec<u64> = (0..64).collect();
        let ys = par_map(xs, |&x| (0..10_000u64).fold(x, |a, b| a.wrapping_add(b)));
        assert_eq!(ys.len(), 64);
    }
}
