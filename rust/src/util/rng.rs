//! Deterministic, dependency-free RNG (SplitMix64 core + distributions).
//!
//! All stochastic components of the coordinator (batch sampling, synthetic
//! corpora, simulated jitter) draw from this generator so every experiment
//! in EXPERIMENTS.md is reproducible from a seed.

/// SplitMix64 PRNG. Passes BigCrush for our purposes; trivially seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough reduction (bias < 2^-53 for our n).
        (self.f64() * n as f64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent stream (for per-task samplers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(5, 10);
            assert!((5..10).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
