//! Process-environment confinement (lint rule **R3**): the one place the
//! tree reads `std::env` variables, snapshotted **once** per process.
//!
//! ## Why confinement
//!
//! `env::var` at call time is hidden mutable global state: two reads of
//! the same knob in one run can disagree if anything calls `set_var` in
//! between — and concurrent `set_var`/`getenv` is undefined behavior on
//! glibc. That is exactly the race that once forced the thread-count
//! determinism test into its own binary (see `tests/par_determinism.rs`),
//! and it is how a mid-run env mutation could change `util::par`
//! parallelism between the two halves of a certificate test. Confining
//! every read to this module and snapshotting at first access makes the
//! environment an immutable run-scoped *config*, not a channel: the value
//! a knob had when the process started deciding things is the value it
//! keeps. `detlint` (rule R3) rejects `env::var`/`set_var`/`remove_var`
//! tokens anywhere outside this file.
//!
//! Only `LOBRA_*` variables are captured — these are the repo's tuning
//! knobs (`LOBRA_NUM_THREADS`, the `LOBRA_BENCH_*` family). A variable
//! set to the empty string counts as unset, so CI matrix entries can pass
//! `""` to mean "use the built-in default" (see `.github/workflows/ci.yml`).

use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Every knob this module serves starts with this prefix.
pub const PREFIX: &str = "LOBRA_";

fn snapshot() -> &'static BTreeMap<String, String> {
    static SNAP: OnceLock<BTreeMap<String, String>> = OnceLock::new();
    SNAP.get_or_init(|| {
        std::env::vars()
            .filter(|(k, v)| k.starts_with(PREFIX) && !v.is_empty())
            .collect()
    })
}

/// The value `key` had at the process-wide snapshot (first access through
/// this module). Returns `None` for unset or empty variables. `key` must
/// start with [`PREFIX`] — anything else was never captured.
pub fn var(key: &str) -> Option<&'static str> {
    debug_assert!(
        key.starts_with(PREFIX),
        "util::env only snapshots {PREFIX}* variables (got {key})"
    );
    snapshot().get(key).map(String::as_str)
}

/// Parse `key` from the snapshot, falling back to `default` when the
/// variable is unset, empty, or unparseable (matching the benches' old
/// `env::var(..).ok().and_then(parse).unwrap_or(default)` idiom).
pub fn parse_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    var(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_keys_fall_back() {
        assert_eq!(var("LOBRA_TEST_NEVER_SET"), None);
        assert_eq!(parse_or("LOBRA_TEST_NEVER_SET", 7usize), 7);
        assert_eq!(parse_or("LOBRA_TEST_NEVER_SET", 1.5f64), 1.5);
    }

    #[test]
    fn snapshot_is_stable_across_reads() {
        // Whatever the first read observed is what every later read sees.
        let first = var("LOBRA_NUM_THREADS");
        let second = var("LOBRA_NUM_THREADS");
        assert_eq!(first, second);
    }
}
