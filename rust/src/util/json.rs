//! Minimal JSON parser (offline build: no serde_json available).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`:
//! objects, arrays, strings (with escapes), numbers, booleans, null. Not a
//! general-purpose library — errors carry byte offsets for debuggability
//! and parsing is strict.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- typed accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64).then_some(x as u64)
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| (x.fract() == 0.0).then_some(x as i64))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.field` chained lookup helper: `j.path(&["model", "vocab"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar; a corrupt profile or
                    // manifest on disk must come back as a parse error
                    // with an offset, never a panic (this path is
                    // reachable from `lobra serve`/`train` via --profile)
                    let s = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(s).map_err(|_| self.err("bad utf8"))?;
                    let ch = text.chars().next().ok_or_else(|| self.err("bad utf8"))?;
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str(), Some("hi"));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": 0.02}}"#).unwrap();
        assert_eq!(j.path(&["d", "e"]).unwrap().as_f64(), Some(0.02));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "preset": "tiny",
            "model": {"vocab": 2048, "pad_id": 0},
            "base_params": [
                {"name": "['embed']", "shape": [2048, 256], "offset": 0,
                 "size": 524288, "init": {"kind": "normal", "std": 0.02}}
            ],
            "artifacts": [{"file": "train_b16_s64.hlo.txt", "batch": 16, "seq": 64}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.path(&["model", "vocab"]).unwrap().as_u64(), Some(2048));
        let p = &j.get("base_params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("size").unwrap().as_u64(), Some(524288));
        assert_eq!(
            p.path(&["init", "kind"]).unwrap().as_str(),
            Some("normal")
        );
    }
}
