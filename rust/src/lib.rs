//! # LobRA — Multi-tenant LoRA Fine-tuning over Heterogeneous Data
//!
//! A reproduction of *LobRA* (Lin et al., PVLDB 18(8), 2025): jointly
//! fine-tune many LoRA adapters over one shared base model, attacking the two
//! data-heterogeneity problems of joint FT — sequence-length **variation**
//! across tasks and sequence-length **skewness** within each fused batch —
//! with (1) *heterogeneous FT replicas* (a deployment plan mixing parallel
//! configurations, solved once at startup) and (2) per-step
//! *workload-balanced data dispatching* plus *dynamic bucketing*.
//!
//! ## Architecture (three layers, Python never on the training path)
//!
//! * **L3 (this crate)** — the coordinator: deployment planner (paper Eq. 2),
//!   per-step dispatcher (Eq. 3), dynamic bucketing DP (Eq. 4), profiled cost
//!   model (Appendix D), cluster simulator, tenant manager, the PJRT
//!   runtime that executes AOT-compiled train steps, and the
//!   backend-agnostic execution layer ([`exec`]) that runs each step's
//!   dispatched replica workloads on either the cost-model clock
//!   (simulation) or the PJRT engine (real training) — both through the
//!   same dispatch pipeline.
//! * **L2** — `python/compile/model.py`: a transformer with fused multi-task
//!   LoRA, lowered once to HLO text by `make artifacts`.
//! * **L1** — `python/compile/kernels/multi_lora.py`: the fused multi-adapter
//!   Pallas kernel the L2 graph calls.
//!
//! ## Quick tour
//!
//! ```no_run
//! use lobra::prelude::*;
//!
//! // Describe the world: model, cluster, FT tasks.
//! let model = ModelDesc::llama2_7b();
//! let cluster = ClusterSpec::a100_40g(16);
//! let tasks = TaskSet::paper_7b_subset();
//!
//! // Stage 1 (once): plan heterogeneous FT replicas (paper Eq. 2).
//! let cost = CostModel::calibrated(&model, &cluster);
//! let planner = Planner::new(&cost, &cluster);
//! let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
//!
//! // Stage 2 (every step): bucket + balance the fused batch (Eq. 3 + Eq. 4).
//! let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
//! let report = sched.run_steps(100);
//! println!("{}", report.summary());
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod solver;
pub mod train;
pub mod util;

/// Convenience re-exports for the common workflow.
pub mod prelude {
    pub use crate::cluster::ClusterSpec;
    pub use crate::config::{ModelDesc, ParallelConfig, TaskSet, TaskSpec};
    pub use crate::coordinator::bucketing::{bucketize, BucketingOptions, Buckets};
    pub use crate::coordinator::dispatcher::{Dispatcher, DispatchPlan};
    pub use crate::coordinator::planner::{DeploymentPlan, Planner, PlannerOptions};
    pub use crate::coordinator::runtime::{ServeOptions, ServeReport, ServeRuntime};
    pub use crate::coordinator::scheduler::{Scheduler, SchedulerOptions, StepReport};
    pub use crate::coordinator::session::PlanningSession;
    pub use crate::coordinator::tasks::TaskManager;
    pub use crate::costmodel::{CostModel, CostTables};
    pub use crate::data::{DatasetProfile, LengthDistribution, MultiTaskSampler};
    pub use crate::exec::{
        ExecutionPlan, PjrtExecutor, ReplicaExecutor, SimExecutor, SimTrainLoop,
        StepExecution,
    };
    pub use crate::metrics::JointFtReport;
}
