//! `lobra` — the LobRA leader CLI (dependency-free arg parsing).
//!
//! Subcommands:
//! * `plan`      — compute the heterogeneous deployment plan (paper Eq. 2).
//! * `simulate`  — run the joint-FT scheduler on the simulated cluster and
//!                 report GPU-seconds (the paper's headline metric).
//! * `serve`     — event-driven serving runtime: replay a tenant churn
//!                 trace with training overlapped against budgeted anytime
//!                 replanning; report tenant-observed metrics.
//! * `calibrate` — sim-backed profiling run: execute dispatch steps, fit
//!                 `t(b,s)` per configuration from the executor's
//!                 microbatch observations, and write a reusable profile.
//! * `train`     — real PJRT-executed end-to-end training on the local CPU
//!                 (requires `make artifacts`).
//! * `info`      — show models, datasets, and feasible configurations.
//!
//! The shared `--model/--gpus/--cluster/--tasks/--profile` world flags are
//! parsed once by `World::parse` and reused by every subcommand.

// The CLI is the product's stdout surface (workspace lints deny
// `print_stdout` in library code).
#![allow(clippy::print_stdout)]

use anyhow::{anyhow, bail, Result};
use lobra::cluster::{ClusterSpec, VirtualCluster};
use lobra::config::ModelDesc;
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::runtime::{
    default_churn_trace, parse_trace_for, BudgetMeter, ServeOptions, ServeRuntime,
};
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::coordinator::shard::ShardManager;
use lobra::costmodel::{load_profile_or_analytic, CalibrationStore, CostModel};
use lobra::exec::profile_sim_steps;
use lobra::prelude::TaskSet;
use lobra::train::{Trainer, TrainerConfig};
use lobra::util::bench::Table;

const USAGE: &str = "\
lobra — multi-tenant LoRA fine-tuning coordinator (LobRA, PVLDB'25)

USAGE:
  lobra plan      [--model 7b|32b|70b|tiny] [--gpus N]
                  [--cluster a100|a800|h100|local|MIXED]
                  [--tasks all|7b-subset|scalability] [--profile PATH]
                  [--no-config-proposal] [--no-lower-bound]
                  (--cluster also takes a mixed-generation pool spec,
                   `+`-separated device:count segments — e.g.
                   --cluster a100:16+h100:8 — planning one shard per
                   device pool, tasks routed by the per-type bound)
  lobra simulate  [--model ...] [--gpus N] [--cluster ...] [--tasks ...]
                  [--steps N] [--seed N] [--task-fused] [--profile PATH]
  lobra serve     [--model ...] [--gpus N] [--cluster ...] [--tasks ...]
                  [--trace FILE] [--replan-budget SECS] [--slice-plans N]
                  [--sim-seconds-per-plan F] [--wall-meter] [--certify]
                  [--planner-threads N] [--spacing SECS] [--seed N]
                  [--shards N] [--rebalance-every K] [--profile PATH]
                  (replay an arrival/exit churn trace: training advances
                   under the current plan while a budgeted anytime replan
                   runs in the background; plans swap at step boundaries,
                   charging only the replica groups that changed.
                   --replan-budget 0 = unlimited; without --trace a
                   default churn trace over --tasks is replayed, arrivals
                   --spacing seconds apart. --planner-threads N > 0 moves
                   the search to a dedicated planner-service thread with N
                   slice workers: events cancel the in-flight search,
                   terminal plans publish through a lock-free epoch cell
                   and are adopted at step boundaries — plan-identical to
                   the sync path, but search time overlaps training even
                   on cold starts. --shards N > 1 partitions tenants into
                   planning shards by sequence-length profile: an event
                   replans only its own shard against that shard's GPU
                   capacity slice (O(change), not O(fleet)), arrivals that
                   do not fit queue per priority tier — preempting the
                   lowest tier when a higher one cannot be admitted — and
                   --rebalance-every K re-slices capacity across shards
                   every K training steps. A mixed --cluster spec runs
                   one planning shard and one training loop per device
                   pool (incompatible with --shards > 1). Trace lines
                   (grammar v2 — cluster events shrink/restore planner
                   capacity; preempted in-flight step work is charged):
                     <at> arrive  <name> <batch> <mean> <skew> <min> <max> [tier]
                     <at> exit    <name>
                     <at> leave   <server>        # whole server departs
                     <at> preempt <start> <end>   # GPUs [start, end) reclaimed
                     <at> join    <server>        # server's down GPUs restore)
  lobra calibrate [--model ...] [--gpus N] [--cluster ...] [--tasks ...]
                  [--steps N] [--seed N] [--out PATH]
                  [--native] [--warmup K] [--trim F]
                  (run profiling steps through the sim executor, fit
                   t(b,s) per config, write the calibration profile.
                   --warmup K discards the first K observations per config
                   (compile/cache warmup on real hardware) and --trim F
                   drops the F fraction of worst-residual observations
                   before the final fit. --native measures the pure-Rust
                   staged runtime instead of the sim clock: every (tp,pp)
                   cell with tp·pp ≤ --gpus runs a real 1F1B pipeline with
                   tp-sharded matmuls, per-microbatch wall-clocks feed the
                   fit with comm and bubble attributed; --steps sets the
                   rounds per cell)
  lobra train     [--artifacts DIR] [--steps N] [--lr F] [--seed N]
                  [--log-every K]
                  [--model 7b|32b|70b|tiny] [--gpus N]
                  [--cluster a100|a800|local]
                  [--tasks all|7b-subset|scalability]
                  [--profile PATH] [--save-profile PATH]
                  (with --model/--profile: plan a virtual cluster — from
                   measured times when --profile is given — and report the
                   real run's GPU-seconds under its MINMAX dispatch clock;
                   --save-profile persists the run's in-situ wall-clocks,
                   keyed to the local engine world: reload them with
                   --profile ... --model <engine model> --cluster local)
  lobra info      [--model ...] [--gpus N] [--cluster ...]
";

/// Tiny flag parser: `--key value` and boolean `--key` switches.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], booleans: &[&str]) -> Result<Self> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument: {a}\n{USAGE}");
            };
            if booleans.contains(&key) {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("missing value for --{key}\n{USAGE}"))?;
                flags.insert(key.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{key}: {v}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn tasks_for(name: &str) -> TaskSet {
    match name {
        "all" => TaskSet::paper_all(),
        "scalability" => TaskSet::paper_scalability_subset(),
        _ => TaskSet::paper_7b_subset(),
    }
}

fn model_for(args: &Args) -> Result<ModelDesc> {
    let name = args.get("model", "7b");
    ModelDesc::by_name(&name).ok_or_else(|| anyhow!("unknown model: {name}"))
}

/// Cost model for the `(model, cluster)` world: measured (from
/// `--profile PATH`, falling back to analytic with a warning when the file
/// is corrupt or from another world) or analytic.
fn cost_for(args: &Args, model: &ModelDesc, cluster: &ClusterSpec) -> CostModel {
    match args.flags.get("profile") {
        Some(path) => {
            let cost = load_profile_or_analytic(path, model, cluster);
            if let Some(p) = cost.profile() {
                println!(
                    "cost model: measured profile {path} (generation {}, {} configs)",
                    p.generation(),
                    p.n_configs()
                );
            }
            cost
        }
        None => CostModel::calibrated(model, cluster),
    }
}

/// The simulated world a subcommand plans against, parsed once from the
/// shared `--model/--gpus/--cluster/--tasks/--profile` flags (previously
/// copy-pasted across `plan`/`simulate`/`train`/`calibrate`).
struct World {
    model: ModelDesc,
    cluster: ClusterSpec,
    tasks: TaskSet,
    cost: CostModel,
    /// Extra device pools of a mixed `--cluster a100:16+h100:8` spec —
    /// empty for the classic single-pool worlds. A measured `--profile`
    /// describes one device world, so it applies to the first pool only;
    /// extra pools use their analytic cost models.
    extra: Vec<(CostModel, ClusterSpec)>,
}

impl World {
    /// `with_profile`: honor `--profile PATH` for a measured cost model.
    /// `calibrate` passes false (it *creates* profiles, so planning under
    /// one would be circular); `info` passes false (it describes the
    /// analytic world).
    fn parse(args: &Args, with_profile: bool) -> Result<World> {
        let model = model_for(args)?;
        let gpus = args.get_parse("gpus", 16u32)?;
        let spec = args.get("cluster", "a100");
        let fleet =
            VirtualCluster::parse(&spec, gpus).map_err(|e| anyhow!("{e}\n{USAGE}"))?;
        let mut pools = fleet.pools;
        let cluster = pools.remove(0);
        let tasks = tasks_for(&args.get("tasks", "7b-subset"));
        let cost = if with_profile {
            cost_for(args, &model, &cluster)
        } else {
            CostModel::calibrated(&model, &cluster)
        };
        let extra = pools
            .into_iter()
            .map(|p| (CostModel::calibrated(&model, &p), p))
            .collect();
        Ok(World { model, cluster, tasks, cost, extra })
    }

    fn is_mixed(&self) -> bool {
        !self.extra.is_empty()
    }

    /// Owned fleet geometry over all pools (server spans for trace
    /// validation, display name).
    fn fleet(&self) -> VirtualCluster {
        if self.extra.is_empty() {
            VirtualCluster::homogeneous(self.cluster.clone())
        } else {
            VirtualCluster::mixed(
                std::iter::once(self.cluster.clone())
                    .chain(self.extra.iter().map(|(_, p)| p.clone()))
                    .collect(),
            )
        }
    }

    /// Per-pool `(cost model, pool)` borrows for the fleet constructors.
    fn worlds(&self) -> Vec<(&CostModel, &ClusterSpec)> {
        std::iter::once((&self.cost, &self.cluster))
            .chain(self.extra.iter().map(|(c, p)| (c, p)))
            .collect()
    }
}

/// Per-config fit summary shared by the `calibrate` paths.
fn print_fit_table(store: &CalibrationStore) {
    for e in store.entries() {
        match (e.fitted, e.rms_rel_error()) {
            (Some(f), Some(rms)) => println!(
                "  {}: {:>4} obs  rms_rel_error {rms:.2e}  \
                 t(b,s) = {:.3e} + {:.3e}·bs + {:.3e}·bs²",
                e.config,
                e.observations.len(),
                f.beta0,
                f.beta1,
                f.beta2
            ),
            _ => println!(
                "  {}: {:>4} obs  underdetermined — analytic constants kept",
                e.config,
                e.observations.len()
            ),
        }
    }
}

/// `calibrate --native`: measure the pure-Rust staged runtime for real.
/// Every `(tp, pp)` cell with `tp·pp ≤ gpus` (powers of two; pp bounded by
/// the layer stack) runs `rounds` 1F1B microbatch sweeps, and the measured
/// per-microbatch timings — tp comm and pipeline-bubble share attributed
/// explicitly — feed the calibration store through the same hygiene
/// pipeline a real-hardware profile uses: the first `warmup` observations
/// per config are discarded and the fit trims a `trim` fraction of
/// outliers.
fn native_calibrate(
    gpus: u32,
    rounds: usize,
    seed: u64,
    warmup: u32,
    trim: f64,
    out: &str,
) -> Result<()> {
    use lobra::config::ParallelConfig;
    use lobra::costmodel::Observation;
    use lobra::data::SyntheticCorpus;
    use lobra::runtime::{NativeModel, NativeSpec, StageMb, StagedEngine};
    use std::sync::Arc;

    // The micro spec's default shapes share b·s, which underdetermines
    // the 3-parameter t(b,s) family; widen the sweep so each cell's
    // regression has full rank.
    let mut spec = NativeSpec::micro();
    spec.shapes = vec![(1, 8), (2, 8), (4, 8), (2, 16), (4, 16)];
    let n_tasks = spec.n_tasks;
    let vocab = spec.vocab as u32;
    let model = NativeModel::new(spec)?;
    let n_layers = model.n_layers();
    let shapes = model.shapes();
    let (base, lora) = model.init_params(seed);
    let model = Arc::new(model);
    let base = Arc::new(base);

    // The profile measures THIS runtime on the local host — key it to the
    // local world, never to whatever virtual pool the flags requested.
    let cluster = ClusterSpec::local_cpu(gpus);
    let cost = CostModel::calibrated(&ModelDesc::tiny(), &cluster);
    let mut store = CalibrationStore::new(&cost).with_hygiene(warmup, trim);

    let mut corpus = SyntheticCorpus::new(vocab, n_tasks, seed ^ 0xCA11B);
    let mut mbs: Vec<StageMb> = Vec::new();
    for &(b, s) in &shapes {
        let mut tokens = Vec::with_capacity((b * s) as usize);
        let mut seg_ids = Vec::with_capacity(b as usize);
        for row in 0..b as usize {
            // non-decreasing task ids (the sorted-seg-ids kernel contract)
            let task = row * n_tasks / b as usize;
            tokens.extend(corpus.sequence_exact(task, s as usize, s as usize));
            seg_ids.push(task as i32);
        }
        mbs.push(StageMb { shape: (b, s), tokens, seg_ids });
    }

    println!(
        "native staged sweep: {gpus} GPUs, {} shapes, {rounds} rounds/cell \
         ({warmup} warmup obs/config discarded, trim {trim:.2})",
        mbs.len()
    );
    let mut cells = 0u32;
    let mut pp = 1usize;
    while pp <= n_layers && (pp as u32) <= gpus {
        let mut tp = 1usize;
        while ((tp * pp) as u32) <= gpus {
            let staged =
                StagedEngine::new(Arc::clone(&model), Arc::clone(&base), tp, pp)?;
            let cfg = ParallelConfig::new(tp as u32, pp as u32);
            for _ in 0..rounds {
                let outs = staged.run(&lora, &mbs)?;
                for (mb, (_, t)) in mbs.iter().zip(outs) {
                    store.record_observation(
                        cfg,
                        Observation::with_overheads(
                            mb.shape.0, mb.shape.1, t.seconds, t.comm, t.bubble,
                        ),
                    );
                }
            }
            cells += 1;
            tp *= 2;
        }
        pp *= 2;
    }
    store.refit();
    println!(
        "{} measured observations across {cells} (tp,pp) cells, generation {}",
        store.n_observations(),
        store.generation()
    );
    print_fit_table(&store);
    store.save(out)?;
    println!(
        "profile written to {out} (world: model={} cluster={})",
        store.model(),
        store.cluster()
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "plan" => {
            let args = Args::parse(rest, &["no-config-proposal", "no-lower-bound"])?;
            let world = World::parse(&args, true)?;
            let mut opts = PlannerOptions::default();
            opts.config_proposal = !args.has("no-config-proposal");
            opts.lower_bound_filter = !args.has("no-lower-bound");
            if world.is_mixed() {
                // one planning shard per device pool: tasks route by the
                // per-type Theorem-1 bound and each pool plans against
                // its own device's cost table
                let mgr = ShardManager::new_fleet(world.worlds(), world.tasks.clone(), opts);
                println!(
                    "model={} fleet={} tasks={}",
                    world.model.name,
                    world.fleet().name,
                    world.tasks.len()
                );
                for p in 0..mgr.n_shards() {
                    let (_, pool) = mgr.shard_world(p);
                    match mgr.shard_plan(p) {
                        Some(plan) => println!(
                            "  {}: {} tasks | [{}] | gpus_used={} step={:.3}s",
                            pool.name,
                            mgr.shard_tasks(p).len(),
                            plan.notation(),
                            plan.gpus_used(),
                            plan.expected_step_time
                        ),
                        None => println!(
                            "  {}: {} tasks | no feasible plan",
                            pool.name,
                            mgr.shard_tasks(p).len()
                        ),
                    }
                }
                let plan = mgr.plan().ok_or_else(|| anyhow!("no feasible plan"))?;
                println!(
                    "fleet: {} replicas, step {:.3}s (slowest pool — LoRA \
                     gradients sync at the fleet step boundary)",
                    plan.n_replicas(),
                    plan.expected_step_time
                );
                return Ok(());
            }
            let World { model, cluster, tasks, cost, .. } = world;
            let planner = Planner::new(&cost, &cluster);
            let (plan, stats) = planner
                .plan_with_stats(&tasks, opts)
                .ok_or_else(|| anyhow!("no feasible plan"))?;
            println!("model={} cluster={} tasks={}", model.name, cluster.name, tasks.len());
            println!("plan: {}", plan.notation());
            println!(
                "gpus_used={} replicas={} expected_step_time={:.3}s",
                plan.gpus_used(),
                plan.n_replicas(),
                plan.expected_step_time
            );
            println!(
                "planning: candidates={} plans={} after_filter={} solve={:.2}s",
                stats.n_candidate_configs,
                stats.n_plans_enumerated,
                stats.n_plans_after_filter,
                stats.solve_seconds
            );
        }
        "simulate" => {
            let args = Args::parse(rest, &["task-fused"])?;
            let world = World::parse(&args, true)?;
            if world.is_mixed() {
                bail!("simulate models a single device pool; mixed --cluster specs are for plan/serve");
            }
            let World { cluster, tasks, cost, .. } = world;
            let steps = args.get_parse("steps", 100usize)?;
            let planner = Planner::new(&cost, &cluster);
            let plan = if args.has("task-fused") {
                planner.plan_homogeneous(&tasks, &PlannerOptions::default())
            } else {
                planner.plan(&tasks, PlannerOptions::default())
            }
            .ok_or_else(|| anyhow!("no feasible plan"))?;
            println!("plan: {}", plan.notation());
            let mut opts = SchedulerOptions::default();
            opts.seed = args.get_parse("seed", opts.seed)?;
            let mut sched = Scheduler::new(&cost, &plan, &tasks, opts);
            let report = sched.run_steps(steps);
            println!("{}", report.summary());
        }
        "serve" => {
            let args = Args::parse(rest, &["certify", "wall-meter"])?;
            let world = World::parse(&args, true)?;
            let fleet = world.fleet();
            let budget = args.get_parse("replan-budget", 180.0f64)?;
            let spacing = args.get_parse("spacing", 600.0f64)?;
            let per_plan = args.get_parse("sim-seconds-per-plan", 1e-4f64)?;
            let trace = match args.flags.get("trace") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| anyhow!("cannot read trace {path}: {e}"))?;
                    // validate cluster events against this fleet's
                    // geometry up front, not at delivery
                    parse_trace_for(&text, &fleet).map_err(|e| anyhow!("{e}"))?
                }
                None => default_churn_trace(&world.tasks, spacing),
            };
            if trace.is_empty() {
                bail!("empty churn trace");
            }
            let mut opts = ServeOptions::default();
            opts.replan_budget = (budget > 0.0).then_some(budget);
            opts.slice_plans = args.get_parse("slice-plans", opts.slice_plans)?.max(1);
            opts.meter = if args.has("wall-meter") {
                BudgetMeter::Wall
            } else {
                BudgetMeter::SimPerPlan(per_plan)
            };
            opts.seed = args.get_parse("seed", opts.seed)?;
            opts.certify_identity = args.has("certify");
            opts.planner_threads = args.get_parse("planner-threads", 0usize)?;
            opts.shards = args.get_parse("shards", 1usize)?.max(1);
            opts.rebalance_every = args.get_parse("rebalance-every", 0u64)?;
            if world.is_mixed() && opts.shards > 1 {
                bail!(
                    "a mixed --cluster runs one planning shard per device \
                     pool; drop --shards"
                );
            }
            println!(
                "serving model={} cluster={} | {} events | replan budget {} | \
                 slice {} plans | meter {:?} | planner {} | {}",
                world.model.name,
                fleet.name,
                trace.len(),
                match opts.replan_budget {
                    Some(b) => format!("{b:.0}s"),
                    None => "unlimited".into(),
                },
                opts.slice_plans,
                opts.meter,
                match opts.planner_threads {
                    0 => "sync (in-loop)".into(),
                    n => format!("async service ({n} threads)"),
                },
                match (world.is_mixed(), opts.shards, opts.rebalance_every) {
                    (true, ..) => {
                        format!("{} device pools (one shard each)", fleet.pools.len())
                    }
                    (false, 1, _) => "global (1 shard)".into(),
                    (false, s, 0) => format!("{s} planning shards"),
                    (false, s, k) => {
                        format!("{s} planning shards, rebalance every {k} steps")
                    }
                },
            );
            let n_shards = opts.shards;
            let mut rt = ServeRuntime::new_fleet(world.worlds(), opts);
            let report = rt.run_trace(&trace);

            let mut t = Table::new(&[
                "tenant", "arrived", "admitted", "tta", "steps", "exited",
            ]);
            for ten in &report.tenants {
                t.row(&[
                    ten.name.clone(),
                    format!("{:.0}s", ten.arrived_at),
                    ten.admitted_at.map_or("-".into(), |a| format!("{a:.0}s")),
                    ten.time_to_admission()
                        .map_or("-".into(), |d| format!("{d:.1}s")),
                    ten.steps_trained.to_string(),
                    ten.exited_at.map_or("-".into(), |e| format!("{e:.0}s")),
                ]);
            }
            t.print();
            println!(
                "\nsim horizon {:.0}s | {} steps ({} during replan windows; min {} per \
                 overlapped window) | {} replan windows, {} redeploys, {} identical \
                 swaps, {} budget-exhausted",
                report.sim_seconds,
                report.steps_total,
                report.steps_during_replan,
                report
                    .min_steps_in_replan_window
                    .map_or("-".into(), |m| m.to_string()),
                report.replan_windows,
                report.redeploys,
                report.plan_swaps_identical,
                report.budget_exhausted,
            );
            println!(
                "search time: {:.3}s total, {:.3}s unoverlapped (exposed on the \
                 serving clock)",
                report.search_seconds_total,
                report.search_seconds_unoverlapped,
            );
            println!(
                "GPU-seconds: {:.1} trained, {:.1} lost to redeploys (changed groups \
                 only) | mean time-to-admission {}",
                report.gpu_seconds_trained,
                report.gpu_seconds_lost_redeploy,
                report
                    .mean_time_to_admission()
                    .map_or("-".into(), |d| format!("{d:.1}s")),
            );
            println!(
                "replan search: {} slices, {} plans enumerated across {} windows",
                report.replan_slices_total,
                report.plans_enumerated_total,
                report.replan_windows,
            );
            if report.leave_events + report.preempt_events + report.join_events > 0 {
                let recs: Vec<String> =
                    report.recoveries.iter().map(|r| format!("{r:.0}s")).collect();
                println!(
                    "cluster churn: {} leaves, {} preempts, {} joins | {:.1} \
                     GPU·s of interrupted-step work lost | time-to-recover [{}]",
                    report.leave_events,
                    report.preempt_events,
                    report.join_events,
                    report.gpu_seconds_lost_preempt,
                    recs.join(" "),
                );
            }
            if n_shards > 1 {
                let ttas: Vec<String> = report
                    .tta_by_tier()
                    .into_iter()
                    .map(|(t, d)| format!("tier{t}={d:.1}s"))
                    .collect();
                println!(
                    "admission: {} queued, {} preemptions, {} rebalances | \
                     tta by tier [{}] | Jain fairness {}",
                    report.queued_admissions,
                    report.preemptions,
                    report.rebalances,
                    ttas.join(" "),
                    report
                        .jain_fairness()
                        .map_or("-".into(), |j| format!("{j:.3}")),
                );
            }
            if report.identity_checks > 0 {
                println!(
                    "anytime identity: {}/{} completed replans plan-identical to cold{}",
                    report.identity_checks - report.identity_failures,
                    report.identity_checks,
                    if report.identity_failures > 0 { " — BUG" } else { "" },
                );
            }
            if let Some(plan) = rt.manager().plan() {
                println!("final plan: [{}]", plan.notation());
            }
        }
        "calibrate" => {
            let args = Args::parse(rest, &["native"])?;
            // calibrate *creates* profiles — never plan under one
            let world = World::parse(&args, false)?;
            if world.is_mixed() {
                bail!("calibrate profiles one device world at a time; run one --cluster pool per profile");
            }
            let World { model, cluster, tasks, cost, .. } = world;
            let steps = args.get_parse("steps", 24usize)?;
            let seed = args.get_parse("seed", 7u64)?;
            let out = args.get("out", "lobra_profile.json");
            let warmup = args.get_parse("warmup", 2u32)?;
            let trim = args.get_parse("trim", 0.1f64)?;
            if args.has("native") {
                native_calibrate(cluster.n_gpus, steps, seed, warmup, trim, &out)?;
                return Ok(());
            }
            let plan = Planner::new(&cost, &cluster)
                .plan(&tasks, PlannerOptions::default())
                .ok_or_else(|| anyhow!("no feasible plan to profile under"))?;
            println!(
                "profiling {} on {} under plan [{}] for {steps} steps",
                model.name,
                cluster.name,
                plan.notation()
            );
            let mut store = CalibrationStore::new(&cost).with_hygiene(warmup, trim);
            let n = profile_sim_steps(&cost, &plan, &tasks, steps, seed, &mut store);
            store.refit();
            println!(
                "{n} microbatch observations, profile generation {}",
                store.generation()
            );
            print_fit_table(&store);
            store.save(&out)?;
            println!("profile written to {out}");
            // close the loop: a plan computed from the freshly measured
            // profile (what `lobra train --profile` will do)
            let profiled = CostModel::from_profile(
                &model,
                &cluster,
                CalibrationStore::load(&out)?.profile(),
            )?;
            let replan = Planner::new(&profiled, &cluster)
                .plan(&tasks, PlannerOptions::default())
                .ok_or_else(|| anyhow!("no feasible plan from the measured profile"))?;
            println!(
                "plan from measured profile: [{}] (analytic plan: [{}])",
                replan.notation(),
                plan.notation()
            );
        }
        "train" => {
            let args = Args::parse(rest, &[])?;
            let mut cfg = TrainerConfig::default();
            cfg.adam.lr = args.get_parse("lr", 2e-3)?;
            cfg.seed = args.get_parse("seed", 0u64)?;
            let steps = args.get_parse("steps", 50usize)?;
            // 0 would panic in the `% log_every` below — treat it as "every step"
            let log_every = args.get_parse("log-every", 10usize)?.max(1);
            let artifacts = args.get("artifacts", "artifacts");
            let mut trainer = Trainer::new(&artifacts, cfg)?;
            // --model (or --profile) attaches a *planned* virtual cluster:
            // the real run's microbatches are dispatched by the MINMAX
            // solve over the planned heterogeneous replicas, and
            // GPU-seconds are reported under that clock (the paper's
            // accounting). With --profile the plan comes from *measured*
            // microbatch times instead of the analytic constants.
            if args.has("model") || args.has("profile") {
                let world = World::parse(&args, true)?;
                if world.is_mixed() {
                    // the real PJRT engine is one device world; the virtual
                    // accounting clock follows its first pool
                    println!(
                        "mixed --cluster: accounting under the first pool \
                         ({}) — extra pools are ignored by `train`",
                        world.cluster.name
                    );
                }
                let World { model, cluster, tasks, cost, .. } = world;
                let plan = Planner::new(&cost, &cluster)
                    .plan(&tasks, PlannerOptions::default())
                    .ok_or_else(|| anyhow!("no feasible plan for the virtual cluster"))?;
                println!(
                    "virtual cluster: model={} cluster={} plan=[{}]",
                    model.name,
                    cluster.name,
                    plan.notation()
                );
                trainer = trainer.with_virtual_cluster(cost, plan);
            }
            println!(
                "engine up: platform={} shapes={:?} lora_params={}",
                trainer.platform(),
                trainer.shapes(),
                trainer.lora().len()
            );
            trainer.run(steps, |log| {
                if log.step as usize % log_every == 0 || log.step == 1 {
                    println!(
                        "step {:>4}  loss {:.4}  mb {}  wall {:.2}s  virtual {:.3}s ({:.2} GPU·s)",
                        log.step,
                        log.loss,
                        log.microbatches,
                        log.wall_seconds,
                        log.virtual_seconds,
                        log.virtual_gpu_seconds
                    );
                }
            })?;
            if let Some(last) = trainer.logs().last() {
                let virt_gpu: f64 =
                    trainer.logs().iter().map(|l| l.virtual_gpu_seconds).sum();
                println!("final loss: {:.4}", last.loss);
                println!(
                    "virtual cluster [{}]: {:.2} GPU·s over {} steps ({:.2}/step, MINMAX dispatch)",
                    trainer.virtual_plan().notation(),
                    virt_gpu,
                    trainer.logs().len(),
                    virt_gpu / trainer.logs().len() as f64
                );
            }
            if let Some(path) = args.flags.get("save-profile").cloned() {
                trainer.save_profile(&path)?;
                let calib = trainer.calibration();
                println!(
                    "in-situ calibration profile ({} microbatch observations, \
                     generation {}) written to {path}",
                    calib.n_observations(),
                    calib.generation()
                );
                // the profile describes the *local engine* world, not any
                // --model/--cluster virtual world this run was accounted
                // against — print the flags that load it back
                println!(
                    "profile world: model={} cluster={}; reload with: \
                     lobra train --profile {path} --model {} --cluster local --gpus 4",
                    calib.model(),
                    calib.cluster(),
                    calib.model()
                );
            }
        }
        "info" => {
            let args = Args::parse(rest, &[])?;
            let world = World::parse(&args, false)?;
            if world.is_mixed() {
                bail!("info describes a single device pool; mixed --cluster specs are for plan/serve");
            }
            let World { model, cluster, cost, .. } = world;
            let planner = Planner::new(&cost, &cluster);
            println!(
                "model={} params={:.1}B layers={} d={}",
                model.name,
                model.params as f64 / 1e9,
                model.n_layers,
                model.d_model
            );
            println!("cluster={} ({} servers)", cluster.name, cluster.n_servers());
            println!("feasible configs (max seq len, tokens/GPU/s @2K):");
            for c in planner.feasible_configs(true) {
                let cap = cost.max_seq_len(c);
                let b = (cost.max_chunk_tokens(c) / 2048).max(1);
                let thr = cost.throughput(c, b, 2048.min(cap));
                println!("  {c}: n={} max_len={} thr={:.0}", c.n(), cap, thr);
            }
            println!("\ndatasets (Table 4):");
            for p in lobra::data::DatasetProfile::all() {
                println!(
                    "  {:<28} avg={:<6} skew={:<6} kurt={:<7} batch={}",
                    p.name, p.avg_len, p.skewness, p.kurtosis, p.batch_size
                );
            }
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => bail!("unknown command: {other}\n{USAGE}"),
    }
    Ok(())
}
