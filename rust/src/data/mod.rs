//! Dataset substrate: synthetic sequence-length distributions matching the
//! paper's Table 4 profiles, and the fused multi-task batch sampler.
//!
//! The paper's dispatch/bucketing behaviour depends only on the *length
//! distribution* of each task's data (plus batch size); Table 4 pins those
//! down with mean / skewness / kurtosis per dataset, and Figure 2 shows the
//! resulting CDFs. `LengthDistribution` fits a (mixture of) lognormal(s) to
//! those moments, which reproduces both the skew ("most sequences short")
//! and the heavy tail that drives LobRA's whole design.

mod corpus;
mod datasets;
mod distribution;
pub mod packing;
mod sampler;

pub use corpus::{SyntheticCorpus, TaskCorpusSpec};
pub use datasets::DatasetProfile;
pub use distribution::LengthDistribution;
pub use packing::{pack_ffd, packing_efficiency, PackedChunk};
pub use sampler::{FusedBatch, MultiTaskSampler, Sequence};
