//! The paper's 12 FT datasets (Table 4), as length-distribution profiles.
//!
//! | dataset | avg len | skew | kurtosis | batch |
//! |---|---|---|---|---|
//! | databricks-dolly-15k | 207 | 7.11 | 95.43 | 256 |
//! | python_code_instructions | 269 | 10.01 | 121.55 | 128 |
//! | Evol-Instruct | 702 | 6.59 | 80.28 | 128 |
//! | CommitPackFt | 663 | 0.79 | 1.68 | 128 |
//! | MathInstruct | 252 | 3.03 | 12.72 | 128 |
//! | MetaMathQA | 236 | 2.56 | 14.56 | 128 |
//! | NuminaMath-CoT | 543 | 1.52 | 3.51 | 256 |
//! | PubMedQA | 371 | 0.73 | 3.29 | 64 |
//! | XSum | 526 | 7.49 | 371.80 | 128 |
//! | BillSum | 3903 | 0.85 | 0.30 | 32 |
//! | cnn_dailymail | 947 | 0.89 | 0.64 | 256 |
//! | MeetingBank | 3622 | 4.35 | 26.50 | 64 |
//!
//! We cannot ship the original corpora; instead each profile synthesizes a
//! distribution matching the reported moments (kurtosis beyond what the
//! skew-fitted lognormal yields is approximated with a heavy-tail mixture
//! component). This preserves exactly what LobRA's planner and dispatcher
//! observe: the bucket histogram of each task's batches.

use super::distribution::LengthDistribution;

/// Summary profile of one FT dataset (= one FT task).
#[derive(Debug, Clone, Copy)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub avg_len: f64,
    pub skewness: f64,
    pub kurtosis: f64,
    pub task_kind: &'static str,
    pub batch_size: u32,
    /// Longest sequence in the dataset (tokens). Table 4 only reports
    /// moments; these caps reflect where each corpus' CDF tops out in
    /// Figure 2 (instruction/QA data ends by 2-4K, summarization corpora
    /// reach 8-16K).
    pub max_len: u32,
}

impl DatasetProfile {
    /// All 12 datasets in the paper's Table 4 order.
    pub fn all() -> &'static [DatasetProfile] {
        &TABLE4
    }

    pub fn by_name(name: &str) -> Option<&'static DatasetProfile> {
        TABLE4.iter().find(|p| p.name == name)
    }

    /// Materialize the fitted length distribution.
    pub fn distribution(&self) -> LengthDistribution {
        // Kurtosis far above the lognormal's own (given skew) → add a tail
        // component. The lognormal's kurtosis grows ~skew²; use that as the
        // heuristic threshold.
        let ln_kurt_est = 3.0 * self.skewness * self.skewness;
        if self.kurtosis > ln_kurt_est + 20.0 {
            LengthDistribution::fit_heavy_tail(
                self.avg_len,
                self.skewness,
                0.015,
                10.0,
                16,
                self.max_len,
            )
        } else {
            LengthDistribution::fit(self.avg_len, self.skewness, 16, self.max_len)
        }
    }
}

const TABLE4: [DatasetProfile; 12] = [
    DatasetProfile { name: "databricks-dolly-15k", avg_len: 207.0, skewness: 7.11, kurtosis: 95.43, task_kind: "instruction", batch_size: 256, max_len: 2048 },
    DatasetProfile { name: "python_code_instructions", avg_len: 269.0, skewness: 10.01, kurtosis: 121.55, task_kind: "code-instruction", batch_size: 128, max_len: 2048 },
    DatasetProfile { name: "Evol-Instruct", avg_len: 702.0, skewness: 6.59, kurtosis: 80.28, task_kind: "code-instruction", batch_size: 128, max_len: 8192 },
    DatasetProfile { name: "CommitPackFt", avg_len: 663.0, skewness: 0.79, kurtosis: 1.68, task_kind: "code-instruction", batch_size: 128, max_len: 4096 },
    DatasetProfile { name: "MathInstruct", avg_len: 252.0, skewness: 3.03, kurtosis: 12.72, task_kind: "math-instruction", batch_size: 128, max_len: 2048 },
    DatasetProfile { name: "MetaMathQA", avg_len: 236.0, skewness: 2.56, kurtosis: 14.56, task_kind: "math-qa", batch_size: 128, max_len: 2048 },
    DatasetProfile { name: "NuminaMath-CoT", avg_len: 543.0, skewness: 1.52, kurtosis: 3.51, task_kind: "math-qa", batch_size: 256, max_len: 4096 },
    DatasetProfile { name: "PubMedQA", avg_len: 371.0, skewness: 0.73, kurtosis: 3.29, task_kind: "medical-qa", batch_size: 64, max_len: 2048 },
    DatasetProfile { name: "XSum", avg_len: 526.0, skewness: 7.49, kurtosis: 371.80, task_kind: "summarization", batch_size: 128, max_len: 8192 },
    DatasetProfile { name: "BillSum", avg_len: 3903.0, skewness: 0.85, kurtosis: 0.30, task_kind: "summarization", batch_size: 32, max_len: 16384 },
    DatasetProfile { name: "cnn_dailymail", avg_len: 947.0, skewness: 0.89, kurtosis: 0.64, task_kind: "summarization", batch_size: 256, max_len: 4096 },
    DatasetProfile { name: "MeetingBank", avg_len: 3622.0, skewness: 4.35, kurtosis: 26.50, task_kind: "summarization", batch_size: 64, max_len: 16384 },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{moments, quantile};
    use crate::util::Rng;

    #[test]
    fn twelve_datasets() {
        assert_eq!(DatasetProfile::all().len(), 12);
    }

    #[test]
    fn lookup() {
        assert!(DatasetProfile::by_name("XSum").is_some());
        assert!(DatasetProfile::by_name("nonesuch").is_none());
    }

    #[test]
    fn sampled_means_match_table4() {
        let mut rng = Rng::new(11);
        for p in DatasetProfile::all() {
            let d = p.distribution();
            let xs: Vec<f64> =
                d.sample_n(&mut rng, 60_000).into_iter().map(|x| x as f64).collect();
            let m = moments(&xs);
            let rel = (m.mean - p.avg_len).abs() / p.avg_len;
            assert!(rel < 0.2, "{}: mean {} vs {} ({rel:.2})", p.name, m.mean, p.avg_len);
        }
    }

    #[test]
    fn figure2_shape_holds() {
        // Paper Fig. 2 / §3: "more than half of the sequences are shorter
        // than 2K, whilst only a few are longer than 8K" over the corpus mix.
        let mut rng = Rng::new(13);
        let mut all = Vec::new();
        for p in DatasetProfile::all() {
            let d = p.distribution();
            for x in d.sample_n(&mut rng, 5_000 * p.batch_size as usize / 32) {
                all.push(x as f64);
            }
        }
        let med = quantile(&all, 0.5);
        assert!(med < 2048.0, "median {med}");
        let frac_over_8k = all.iter().filter(|&&x| x > 8192.0).count() as f64 / all.len() as f64;
        assert!(frac_over_8k < 0.05, "{frac_over_8k}");
    }
}
