//! Fused multi-task batch sampling — the per-step random draw whose
//! bucket-count fluctuations motivate the paper's per-step re-dispatch.

use crate::config::TaskSet;
use crate::util::Rng;

/// One training sequence in a fused batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sequence {
    /// Index of the owning FT task.
    pub task: u32,
    /// Token length (pre-padding).
    pub len: u32,
}

/// A fused batch: every task contributes its own batch size of sequences.
#[derive(Debug, Clone, Default)]
pub struct FusedBatch {
    pub sequences: Vec<Sequence>,
}

impl FusedBatch {
    pub fn len(&self) -> usize {
        self.sequences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sequences.is_empty()
    }

    pub fn total_tokens(&self) -> u64 {
        self.sequences.iter().map(|s| s.len as u64).sum()
    }

    /// Lengths only (for bucketing).
    pub fn lengths(&self) -> Vec<u32> {
        self.sequences.iter().map(|s| s.len).collect()
    }

    /// Histogram over `boundaries` (bucket j = lengths in (b_{j-1}, b_j]).
    pub fn bucket_counts(&self, boundaries: &[u32]) -> Vec<u64> {
        let mut counts = vec![0u64; boundaries.len()];
        for s in &self.sequences {
            let j = boundaries.partition_point(|&b| b < s.len);
            let j = j.min(boundaries.len() - 1);
            counts[j] += 1;
        }
        counts
    }
}

/// Draws fused batches from the task set's length distributions.
#[derive(Debug, Clone)]
pub struct MultiTaskSampler {
    tasks: TaskSet,
    rng: Rng,
}

impl MultiTaskSampler {
    pub fn new(tasks: &TaskSet, seed: u64) -> Self {
        Self { tasks: tasks.clone(), rng: Rng::new(seed) }
    }

    pub fn task_set(&self) -> &TaskSet {
        &self.tasks
    }

    /// Draw one fused batch (each task contributes `batch_size` sequences).
    pub fn next_batch(&mut self) -> FusedBatch {
        let mut sequences = Vec::with_capacity(self.tasks.joint_batch() as usize);
        for (ti, t) in self.tasks.tasks.iter().enumerate() {
            for _ in 0..t.batch_size {
                sequences.push(Sequence {
                    task: ti as u32,
                    len: t.lengths.sample(&mut self.rng),
                });
            }
        }
        FusedBatch { sequences }
    }

    /// Draw a large calibration sample of lengths (the paper uses 100×B at
    /// initialization to fix bucket boundaries for the deployment problem).
    pub fn calibration_lengths(&mut self, multiples_of_b: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for _ in 0..multiples_of_b {
            out.extend(self.next_batch().lengths());
        }
        out
    }

    /// Expected per-bucket fractions `f_j` estimated from a calibration
    /// sample, over the given boundaries.
    pub fn bucket_fractions(lengths: &[u32], boundaries: &[u32]) -> Vec<f64> {
        let mut counts = vec![0u64; boundaries.len()];
        for &l in lengths {
            let j = boundaries.partition_point(|&b| b < l).min(boundaries.len() - 1);
            counts[j] += 1;
        }
        let total = lengths.len().max(1) as f64;
        counts.iter().map(|&c| c as f64 / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TaskSet, TaskSpec};
    use crate::data::LengthDistribution;

    fn tiny_tasks() -> TaskSet {
        TaskSet::new(vec![
            TaskSpec::new("short", 8, LengthDistribution::fit(100.0, 2.0, 16, 2048)),
            TaskSpec::new("long", 4, LengthDistribution::fit(1500.0, 0.8, 16, 8192)),
        ])
    }

    #[test]
    fn batch_composition() {
        let mut s = MultiTaskSampler::new(&tiny_tasks(), 1);
        let b = s.next_batch();
        assert_eq!(b.len(), 12);
        assert_eq!(b.sequences.iter().filter(|s| s.task == 0).count(), 8);
        assert_eq!(b.sequences.iter().filter(|s| s.task == 1).count(), 4);
        assert!(b.total_tokens() > 0);
    }

    #[test]
    fn bucket_counts_sum_to_batch() {
        let mut s = MultiTaskSampler::new(&tiny_tasks(), 2);
        let b = s.next_batch();
        let counts = b.bucket_counts(&[256, 512, 1024, 8192]);
        assert_eq!(counts.iter().sum::<u64>(), b.len() as u64);
    }

    #[test]
    fn batches_vary_across_steps() {
        let mut s = MultiTaskSampler::new(&tiny_tasks(), 3);
        let b1 = s.next_batch();
        let b2 = s.next_batch();
        assert_ne!(b1.lengths(), b2.lengths());
    }

    #[test]
    fn fractions_normalize() {
        let mut s = MultiTaskSampler::new(&tiny_tasks(), 4);
        let lens = s.calibration_lengths(50);
        let f = MultiTaskSampler::bucket_fractions(&lens, &[256, 1024, 8192]);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(f[0] > 0.0);
    }
}
