//! Synthetic token corpora for the real (PJRT-executed) end-to-end runs.
//!
//! Each FT task gets its own learnable synthetic language — a task-specific
//! order-1 Markov chain over the vocabulary — so the e2e example can show
//! per-task loss curves actually descending, and adapters specializing per
//! task (the multi-tenant payoff the paper's setting assumes).

use crate::util::Rng;

/// Generation spec for one task's corpus.
#[derive(Debug, Clone)]
pub struct TaskCorpusSpec {
    /// First token of this task's vocabulary subrange.
    pub start: u32,
    /// Width of the subrange (tokens are `start .. start+span`). A narrow
    /// span gives each task a strong, low-rank unigram signature that a
    /// rank-8 adapter can capture quickly.
    pub span: u32,
    /// Task-specific stride of the underlying deterministic cycle.
    pub stride: u32,
    /// Probability of emitting a uniformly random in-span token instead of
    /// the chain's next token (controls achievable loss floor).
    pub noise: f64,
    /// Mean sequence length (lengths are jittered around it).
    pub mean_len: u32,
}

/// Deterministic synthetic corpus over `vocab` tokens (0 is reserved: PAD).
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: u32,
    specs: Vec<TaskCorpusSpec>,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: u32, n_tasks: usize, seed: u64) -> Self {
        assert!(vocab > 16);
        let mut rng = Rng::new(seed);
        let usable = vocab - 1;
        let span = (usable / n_tasks.max(2) as u32).clamp(16, 256);
        let specs = (0..n_tasks)
            .map(|t| TaskCorpusSpec {
                start: 1 + (t as u32 * span) % (usable - span + 1),
                span,
                // co-prime-ish strides so tasks are mutually unpredictable
                stride: 3 + 2 * t as u32 + (rng.below(5) as u32),
                noise: 0.05,
                mean_len: 48 + 24 * (t as u32 % 4),
            })
            .collect();
        Self { vocab, specs, rng }
    }

    pub fn with_specs(vocab: u32, specs: Vec<TaskCorpusSpec>, seed: u64) -> Self {
        Self { vocab, specs, rng: Rng::new(seed) }
    }

    pub fn n_tasks(&self) -> usize {
        self.specs.len()
    }

    /// Vocabulary size this corpus draws from (PAD = 0 reserved).
    pub fn vocab(&self) -> u32 {
        self.vocab
    }

    /// One sequence for `task`, padded with 0 (PAD) to exactly `seqlen`.
    /// Real length is sampled around the task's mean, in [8, seqlen].
    pub fn sequence(&mut self, task: usize, seqlen: usize) -> Vec<i32> {
        let spec = &self.specs[task];
        let mean = spec.mean_len.min(seqlen as u32) as f64;
        let len = (self.rng.normal_ms(mean, mean / 4.0).round() as i64)
            .clamp(8, seqlen as i64) as usize;
        self.sequence_exact(task, len, seqlen)
    }

    /// One sequence for `task` with exactly `len.min(seqlen)` real tokens,
    /// padded with 0 (PAD) to `seqlen`. Used by the execution layer, where
    /// the length was already drawn by the coordinator's sampler — the
    /// corpus must not second-guess the dispatched workload.
    pub fn sequence_exact(&mut self, task: usize, len: usize, seqlen: usize) -> Vec<i32> {
        let spec = &self.specs[task];
        let len = len.min(seqlen);
        let (start, span, stride) = (spec.start, spec.span, spec.stride);
        let mut off = self.rng.below(span as u64) as u32;
        let mut out = Vec::with_capacity(seqlen);
        for _ in 0..len {
            out.push((start + off) as i32);
            off = if self.rng.f64() < spec.noise {
                self.rng.below(span as u64) as u32
            } else {
                (off + stride) % span
            };
        }
        out.resize(seqlen, 0);
        out
    }

    /// A microbatch: `bsz` sequences all belonging to `task`.
    pub fn microbatch(&mut self, task: usize, bsz: usize, seqlen: usize) -> Vec<i32> {
        let mut toks = Vec::with_capacity(bsz * seqlen);
        for _ in 0..bsz {
            toks.extend(self.sequence(task, seqlen));
        }
        toks
    }

    /// A *fused* microbatch with explicit per-sequence task ids (sorted, as
    /// the L1 kernel requires). Returns (tokens [bsz*seqlen], seg_ids [bsz]).
    pub fn fused_microbatch(
        &mut self,
        tasks: &[usize],
        seqlen: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut sorted = tasks.to_vec();
        sorted.sort_unstable();
        let mut toks = Vec::with_capacity(sorted.len() * seqlen);
        for &t in &sorted {
            toks.extend(self.sequence(t, seqlen));
        }
        let segs = sorted.iter().map(|&t| t as i32).collect();
        (toks, segs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_padding() {
        let mut c = SyntheticCorpus::new(512, 3, 1);
        let s = c.sequence(0, 64);
        assert_eq!(s.len(), 64);
        assert!(s.iter().all(|&t| (0..512).contains(&t)));
        // padding is a suffix
        let first_pad = s.iter().position(|&t| t == 0).unwrap_or(64);
        assert!(s[first_pad..].iter().all(|&t| t == 0));
        assert!(first_pad >= 8);
    }

    #[test]
    fn fused_batch_sorted() {
        let mut c = SyntheticCorpus::new(512, 4, 2);
        let (toks, segs) = c.fused_microbatch(&[3, 0, 2, 0], 32);
        assert_eq!(toks.len(), 4 * 32);
        assert_eq!(segs, vec![0, 0, 2, 3]);
    }

    #[test]
    fn tasks_are_distinguishable() {
        // Disjoint vocabulary subranges ⇒ tasks never share tokens.
        let mut c = SyntheticCorpus::new(2048, 4, 3);
        let toks = |s: &[i32]| -> std::collections::BTreeSet<i32> {
            s.iter().copied().filter(|&t| t != 0).collect()
        };
        let t0 = toks(&c.sequence(0, 128));
        let t1 = toks(&c.sequence(1, 128));
        assert!(t0.is_disjoint(&t1), "task vocabularies overlap");
    }

    #[test]
    fn tokens_stay_in_span() {
        let mut c = SyntheticCorpus::new(2048, 6, 9);
        for t in 0..6 {
            let s = c.sequence(t, 64);
            let spec = &c.specs[t];
            for &tok in s.iter().filter(|&&t| t != 0) {
                assert!(
                    (spec.start..spec.start + spec.span).contains(&(tok as u32)),
                    "task {t} token {tok} outside span"
                );
            }
        }
    }

    #[test]
    fn sequence_exact_honors_requested_length() {
        let mut c = SyntheticCorpus::new(512, 3, 5);
        for len in [1usize, 8, 17, 64] {
            let s = c.sequence_exact(1, len, 64);
            assert_eq!(s.len(), 64);
            let real = s.iter().take_while(|&&t| t != 0).count();
            assert_eq!(real, len, "requested {len}");
            assert!(s[real..].iter().all(|&t| t == 0));
        }
        // over-long requests truncate to the pad length
        let s = c.sequence_exact(0, 100, 32);
        assert_eq!(s.len(), 32);
        assert!(s.iter().all(|&t| t != 0));
    }

    #[test]
    fn microbatch_layout() {
        let mut c = SyntheticCorpus::new(512, 2, 4);
        let mb = c.microbatch(1, 3, 16);
        assert_eq!(mb.len(), 48);
    }
}
